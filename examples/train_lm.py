"""End-to-end LM training driver (example b of the deliverables).

Trains a ~100M-parameter olmo-family model for a few hundred steps on the
synthetic token pipeline, with checkpointing every 50 steps. On CPU this is
slow but real; on TPU the same script scales by passing --production-mesh.

Run (quick smoke):   PYTHONPATH=src python examples/train_lm.py --steps 30
Run (full example):  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.launch import train as train_launcher


def hundred_m_config():
    """olmo-family, ~100M params: 8L x d512 x 8H, vocab 32k."""
    base = get_config("olmo-1b")
    return dataclasses.replace(
        base,
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="exact")
    ap.add_argument("--ckpt-dir", default="/tmp/carmen_lm_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config()
    from repro.models import get_model

    print(f"model: {cfg.name}-100m  params={get_model(cfg).count_params()/1e6:.1f}M")

    # reuse the production launcher with our config injected
    import repro.configs as configs

    configs.ARCHS["olmo-100m"] = cfg
    sys.argv = [
        "train",
        "--arch", "olmo-100m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--mode", args.mode,
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--lr", "3e-4",
    ]
    losses = train_launcher.main(sys.argv[1:])
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK: loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
