"""Paper Table V — vector-engine scaling (64 PE vs 256 PE).

Claim C4: throughput scales near-linearly with PE count at comparable
efficiency. The PE-lane axis maps to the output-channel axis of the MAC
kernel; we measure work/time at 64/128/256 lanes (fixed K, fixed token
count) and derive the scaling exponent. The TPU-cluster analogue (model-axis
scaling 256 -> 512 chips) is covered by the single- vs multi-pod roofline
table in EXPERIMENTS.md.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.core import FXP8, FXP8_UNIT, carmen_matmul_fast, full_depth

from ._common import timed

M, K = 4096, 512  # large enough that CPU work dominates dispatch overhead
LANES = (64, 128, 256)


def run():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (M, K)).astype(np.float32)
    # one jitted fn reused across lane counts: each N still triggers one
    # compile (shape specialization), but re-jitting per lane would also
    # rebuild the trace cache and skew the first timed rep
    f = jax.jit(lambda a, b: carmen_matmul_fast(
        a, b, full_depth(FXP8_UNIT), FXP8, FXP8_UNIT))
    rows = []
    times = {}
    for n in LANES:
        w = rng.uniform(-1, 1, (K, n)).astype(np.float32)
        timed(lambda: f(x, w))  # compile this N's specialization off-clock
        dt = float(np.mean([timed(lambda: f(x, w), warmup=0)[0]
                            for _ in range(10)]))
        times[n] = dt
        macs = M * K * n
        rows.append((f"table5.lanes_{n}", dt * 1e6, f"GMAC/s={macs/dt/1e9:.2f}"))
    # scaling exponent between 64 and 256 lanes (1.0 = perfectly linear)
    alpha = math.log(times[256] / times[64]) / math.log(256 / 64)
    eff = (256 / 64) / (times[256] / times[64])
    rows.append(
        ("table5.scaling_64_to_256", 0.0,
         f"time_exponent={alpha:.2f};throughput_scaling={eff:.2f}x_of_4x "
         f"(CPU wall-clock, cache effects; paper: near-linear)")
    )
    rows.extend(_mesh_scaling_rows())
    return rows


def _mesh_scaling_rows():
    """Structural C4 evidence: per-chip work at 256 vs 512 chips from the
    dry-run artifacts (perfect scaling => flops/dev halves pod->multi-pod)."""
    import glob
    import json
    import os

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    rows = []
    for arch, shape in (("qwen3-8b", "train_4k"), ("zamba2-7b", "train_4k")):
        try:
            with open(os.path.join(art, f"{arch}__{shape}__single.json")) as f:
                s = json.load(f)
            with open(os.path.join(art, f"{arch}__{shape}__multi.json")) as f:
                m = json.load(f)
            if s["status"] != "ok" or m["status"] != "ok":
                continue
            ratio = s["flops_dev"] / max(m["flops_dev"], 1.0)
            rows.append(
                (f"table5.mesh_scaling_{arch}", 0.0,
                 f"flops/dev 256->512 chips ratio={ratio:.2f}x (2.0=perfect; dry-run)")
            )
        except (FileNotFoundError, json.JSONDecodeError):
            continue
    return rows
