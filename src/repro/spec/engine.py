"""SpeculativeDecoder: the jitted draft/verify pair bound to a weight bank.

One decoder owns one compiled draft loop and one compiled verify step (both
keyed on the static draft length); the draft *tree* is an argument, so an
attached mode controller can hand a different resident bank tree each round
with zero recompilation beyond the first visit to each point.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import EngineContext
from repro.models import ModelApi
from repro.runtime.bank import MultiPointBank

from .config import SpecConfig
from .decoding import make_draft_loop, make_verify_step
from .telemetry import SpecTelemetry


class SpeculativeDecoder:
    """Draft-k-then-verify serving rounds over a multi-point weight bank."""

    def __init__(self, model: ModelApi, ctx: EngineContext,
                 bank: MultiPointBank, cfg: Optional[SpecConfig] = None, *,
                 shardings=None):
        self.cfg = cfg or SpecConfig()
        self.bank = bank
        self.verify_point = self.cfg.verify_point or bank.reference
        for name in (self.cfg.draft_point, self.verify_point):
            if name is not None and name not in bank.names:
                raise ValueError(
                    f"unknown execution point {name!r}; bank has {bank.names}"
                )
        # default draft point: the cheapest rung of the ladder
        self.default_draft_point = self.cfg.draft_point or bank.names[0]
        if self.default_draft_point == self.verify_point:
            # catches the post-resolution collisions SpecConfig cannot see
            # (draft_point == bank reference, or verify_point == cheapest)
            raise ValueError(
                f"draft point {self.default_draft_point!r} is the verify "
                "point: every round would pay k full-cost draft passes on "
                "top of the verify pass — pick a cheaper draft point"
            )
        # the cache is donated through both halves of the round (draft writes
        # scratch rows in place, verify overwrites them and rolls back), so a
        # round never copies the KV buffers; emit/accept/margin buffers stay
        # on device until the caller's single host transfer. With a sharded
        # server (``shardings`` = the partition.ServingShardings bundle), the
        # cache is pinned to its serving placement through both jits so the
        # donated carry never reshards mid-round; everything else is inferred
        # from the committed bank trees / slot state.
        draft_kwargs, verify_kwargs = {}, {}
        if shardings is not None:
            c = shardings.cache
            draft_kwargs = dict(
                in_shardings=(None, None, c, None, None, None, None),
                out_shardings=(None, None, c),
            )
            verify_kwargs = dict(
                in_shardings=(None, None, None, None, c, None, None, None,
                              None, None),
                out_shardings=(None, None, None, None, None, c),
            )
        self.draft_loop = jax.jit(
            make_draft_loop(model, ctx, self.cfg.draft_len), donate_argnums=(2,),
            **draft_kwargs,
        )
        self.verify = jax.jit(
            make_verify_step(model, ctx, self.cfg.draft_len), donate_argnums=(4,),
            **verify_kwargs,
        )
        self.telemetry = SpecTelemetry.for_bank(bank, self.cfg.draft_len)
        # optional repro.obs.ServingObserver: draft/verify dispatch spans and
        # the rollback commit land on the serving trace (the server wires
        # this per run)
        self.observer = None
        self._round = 0

    @property
    def draft_len(self) -> int:
        return self.cfg.draft_len

    def reset(self) -> None:
        """Fresh telemetry and round counter (PRNG folds restart), so
        consecutive ``BatchedServer.run`` calls are reproducible."""
        self.telemetry.reset()
        self._round = 0

    def round(self, tokens, cache, base_keys, counts, temps, start, *,
              draft_point: Optional[str] = None):
        """One draft+verify round over the whole slot batch.

        ``tokens`` (B,1) pending token per slot, ``start`` (B,) committed row
        counts, ``counts`` (B,) generated-token indices (PRNG folds). Returns
        ``(emitted (B,k+1) np, accepted (B,) np, margins (B,k+1) np,
        draft_fault (B,) np, verify_fault (B,) np, cache, point)`` with the
        cache rolled back to ``start + accepted + 1`` rows per slot. The
        emit and fault buffers come back in ONE host transfer; the cache
        stays resident (and is donated through draft + verify — no copies).
        The caller records telemetry (it knows which slots are active) and
        acts on the fault flags (draft fault: the lane already degraded to
        plain accurate decode this round; verify fault: quarantine).
        """
        point = draft_point or self.default_draft_point
        obs = self.observer
        round_idx = jnp.int32(self._round)
        self._round += 1
        counts = jnp.asarray(counts, jnp.int32)
        temps = jnp.asarray(temps, jnp.float32)
        start = jnp.asarray(start, jnp.int32)
        if obs is not None:
            obs.spec_stage_begin("draft", point)
        draft_toks, draft_probs, cache = self.draft_loop(
            self.bank.tree(point), tokens, cache, base_keys, counts, temps,
            round_idx,
        )
        if obs is not None:
            obs.spec_stage_end("draft", point)
            obs.spec_stage_begin("verify", self.verify_point)
        emitted, accepted, margins, draft_fault, verify_fault, cache = self.verify(
            self.bank.tree(self.verify_point), tokens, draft_toks, draft_probs,
            cache, start, base_keys, counts, temps, round_idx,
        )
        if obs is not None:
            obs.spec_stage_end("verify", self.verify_point)
        emitted, accepted, margins, draft_fault, verify_fault = jax.device_get(
            (emitted, accepted, margins, draft_fault, verify_fault))
        if obs is not None:
            obs.spec_commit(accepted)
        return emitted, accepted, margins, draft_fault, verify_fault, cache, point
