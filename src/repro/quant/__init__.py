from .qat import (
    QuantizedLinear,
    calibrate_activation_scales,
    dequantize_params,
    fake_quant,
    quantize_params_int8,
)

__all__ = [
    "QuantizedLinear",
    "calibrate_activation_scales",
    "dequantize_params",
    "fake_quant",
    "quantize_params_int8",
]
