"""zamba2-7b [arXiv:2411.15242; unverified] — Mamba2 backbone + shared attention.

81 Mamba2 (SSD) layers with a weight-shared full-attention block applied every
9 SSM layers (the paper's shared transformer blocks, adapted to a scan-friendly
9x9 grouping — DESIGN.md §4). ssm_state=64 per the assignment.
"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=1e4,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    hybrid=HybridConfig(attn_every=9, shared_attn_blocks=1),
    subquadratic=True,
)
