"""Fault-tolerance benchmark: isolation, shedding, graceful degradation.

Three configs, each with a CI gate (``--smoke`` exits nonzero on violation):

* **fault_isolation** — dense and MoE+MLA, adaptive-burst and speculative
  serving: the same workload runs fault-free and with a NaN-poisoned KV slot
  (``resilience.inject.NaNCacheFault``, deterministic round/rid from config).
  Gate: every unaffected slot's greedy stream is bit-identical to the
  fault-free run, the faulted slot is quarantined with a structured reason,
  and its committed tokens are exactly the clean prefix of the fault-free
  stream. Healthy-run tok/s is recorded for the trend gate.

* **overload_shedding** — offered load far above capacity, bounded vs
  unbounded admission queue. Gate: with shedding on, every rejected request
  carries a shed reason and the p99 queue-wait does not exceed the
  unbounded server's (the bounded queue serves a prefix of the same arrival
  order, so waiting is structurally bounded).

* **degradation** — the same overload served by a pinned-accurate server
  and by a ``DegradationPolicy`` wrapper that demotes the batch down the
  depth ladder under queue pressure. Deadline-met fractions are measured in
  **modeled PE cycles** (the bank's per-token cycle table walked over the
  serving trace): the software emulation's masked full-depth loop makes
  every depth cost identical *wall* time by design — one compiled program
  serves every point — so the silicon currency, where approx mode really is
  cheaper, is the honest clock (it is exactly what ``sim/replay.py``
  prices). The deadline is calibrated to the pinned run's median modeled
  completion. Gate: the degrading server's deadline-met fraction strictly
  exceeds the pinned one's at the same offered load.

    PYTHONPATH=src python -m benchmarks.bench_robustness --smoke
"""
from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.obs import ServingObserver
from repro.resilience import (
    DegradationConfig,
    DegradationPolicy,
    FaultInjector,
    NaNCacheFault,
    ResilienceConfig,
)
from repro.runtime import (
    ControllerConfig,
    ModeController,
    build_bank,
    default_points,
)
from repro.serve.engine import BatchedServer, Request
from repro.spec import SpecConfig

from ._common import (
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    timed,
)

ISOLATION_ARCHS = {
    "dense": "olmo-1b",
    "mla_moe": "deepseek-v3-671b",
}
FAULT_RID = 1
FAULT_ROUND = 1


def _workload(cfg, n, *, max_new, seed=7):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 5).astype(np.int32), max_new)
        for i in range(n)
    ]


def _gen_tokens(out):
    return sum(len(v) for v in out.values())


# ---------------------------------------------------------------------------
# fault isolation
# ---------------------------------------------------------------------------


def _isolation_config(arch, args, *, speculative):
    cfg, model, params = load_model(arch, full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    max_len = 16 + args.max_new + (3 if speculative else 0)
    kw = dict(slots=args.slots, max_len=max_len, bank=bank,
              resilience=ResilienceConfig())
    if speculative:
        kw.update(speculate=SpecConfig(draft_len=3))
    else:
        kw.update(burst=args.burst,
                  controller=ModeController(
                      bank, ControllerConfig(pin=bank.reference)))

    ref = BatchedServer(model, ctx, params, **kw)
    work = lambda: _workload(cfg, args.requests, max_new=args.max_new)
    dt, ref_out = timed(lambda: ref.run(work()))

    srv = BatchedServer(
        model, ctx, params,
        injector=FaultInjector(NaNCacheFault(rid=FAULT_RID,
                                             at_round=FAULT_ROUND)),
        **kw)
    out = srv.run(work())

    clean = [r for r in ref_out if r != FAULT_RID]
    o = srv.outcomes.get(FAULT_RID)
    row = {
        "arch": arch,
        "mode": "speculative" if speculative else "adaptive_burst",
        "tok_s": round(_gen_tokens(ref_out) / max(dt, 1e-9), 1),
        "fault_fired": bool(srv.injector.fired),
        "unaffected_bit_identical": all(out[r] == ref_out[r] for r in clean),
        "faulted_quarantined": o is not None and o.status == "faulted",
        "fault_reason": o.reason if o is not None else None,
        "clean_prefix_ok": (
            out[FAULT_RID] == ref_out[FAULT_RID][:len(out[FAULT_RID])]
        ),
        "faulted_tokens": len(out.get(FAULT_RID, [])),
    }
    row["isolation_ok"] = (row["fault_fired"]
                           and row["unaffected_bit_identical"]
                           and row["faulted_quarantined"]
                           and row["clean_prefix_ok"])
    return row


# ---------------------------------------------------------------------------
# overload shedding
# ---------------------------------------------------------------------------


def _overload_config(args):
    cfg, model, params = load_model("olmo-1b", full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    max_len = 16 + args.max_new

    def serve(resilience):
        srv = BatchedServer(model, ctx, params, slots=args.slots,
                            max_len=max_len, burst=args.burst,
                            resilience=resilience)
        srv.observer = ServingObserver(trace=False)
        work = lambda: _workload(cfg, args.overload_requests,
                                 max_new=args.max_new)
        dt, out = timed(lambda: srv.run(work()))
        return srv, dt, out

    unbounded, dt_u, out_u = serve(ResilienceConfig())
    bounded, dt_b, out_b = serve(
        ResilienceConfig(queue_limit=args.queue_limit,
                         shed_policy=args.shed_policy))

    def p99(srv):
        block = latency_block(srv.observer)
        qw = block.get("queue_wait_s")
        return qw["p99"] if qw else 0.0

    shed = {r: o for r, o in bounded.outcomes.items() if o.status == "shed"}
    return {
        "offered": args.overload_requests,
        "slots": args.slots,
        "queue_limit": args.queue_limit,
        "shed_policy": args.shed_policy,
        "unbounded": {
            "tok_s": round(_gen_tokens(out_u) / max(dt_u, 1e-9), 1),
            "queue_wait_p99_s": round(p99(unbounded), 6),
            "served": sum(o.status == "ok"
                          for o in unbounded.outcomes.values()),
        },
        "bounded": {
            "tok_s": round(_gen_tokens(out_b) / max(dt_b, 1e-9), 1),
            "queue_wait_p99_s": round(p99(bounded), 6),
            "served": sum(o.status == "ok" for o in bounded.outcomes.values()),
            "shed": len(shed),
            "shed_reasons": sorted({o.reason for o in shed.values()}),
            "all_sheds_attributed": all(o.reason for o in shed.values()),
        },
    }


# ---------------------------------------------------------------------------
# graceful degradation (modeled-cycle deadlines)
# ---------------------------------------------------------------------------


def _modeled_completions(events, cycles_per_token, reference):
    """Walk a serving trace; return {rid: modeled completion time} in PE
    cycles. Each prefill charges its bucket and each decode burst its steps
    at the executed point's per-token cost — the same currency
    ``sim/replay.py`` prices, reduced to what the deadline gate needs."""
    cum = 0.0
    open_args = {}
    done = {}
    for ev in events:
        name, ph = ev["name"], ev["ph"]
        args = ev.get("args", {})
        if ph == "B" and name in ("prefill", "burst", "spec"):
            open_args[name] = args
        elif ph == "E" and name in ("prefill", "burst", "spec"):
            merged = {**open_args.pop(name, {}), **args}
            point = merged.get("point") or reference
            per_tok = cycles_per_token.get(point, cycles_per_token[reference])
            units = (int(merged.get("bucket", 1)) if name == "prefill"
                     else int(merged.get("steps", 1)))
            cum += per_tok * units
        elif ph == "I" and name == "request_completed":
            done[int(args["rid"])] = cum
    return done


def _degradation_config(args):
    cfg, model, params = load_model("olmo-1b", full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    max_len = 16 + args.max_new

    def serve(controller):
        srv = BatchedServer(model, ctx, params, slots=args.slots,
                            max_len=max_len, burst=args.burst, bank=bank,
                            controller=controller,
                            resilience=ResilienceConfig())
        srv.observer = ServingObserver()
        work = lambda: _workload(cfg, args.overload_requests,
                                 max_new=args.max_new)
        dt, out = timed(lambda: srv.run(work()))
        comp = _modeled_completions(srv.observer.trace.events,
                                    bank.cycles_per_token, bank.reference)
        return srv, dt, out, comp

    pinned = ModeController(bank, ControllerConfig(pin=bank.reference))
    _, dt_p, out_p, comp_p = serve(pinned)
    degrade = DegradationPolicy(
        ModeController(bank, ControllerConfig(pin=bank.reference)),
        DegradationConfig(demote_hysteresis=1))
    srv_d, dt_d, out_d, comp_d = serve(degrade)

    # deadline = the pinned run's median modeled completion: pinned meets
    # roughly half by construction, so any cycle savings show up as met
    deadline = float(np.median(sorted(comp_p.values())))
    met_p = sum(c <= deadline for c in comp_p.values()) / max(len(comp_p), 1)
    met_d = sum(c <= deadline for c in comp_d.values()) / max(len(comp_d), 1)
    return {
        "offered": args.overload_requests,
        "deadline_cycles": round(deadline, 1),
        "clock": "modeled_pe_cycles",
        "pinned": {
            "tok_s": round(_gen_tokens(out_p) / max(dt_p, 1e-9), 1),
            "deadline_met_frac": round(met_p, 4),
        },
        "degrade": {
            "tok_s": round(_gen_tokens(out_d) / max(dt_d, 1e-9), 1),
            "deadline_met_frac": round(met_d, 4),
            "demotions": degrade.demotions,
            "promotions": degrade.promotions,
            "final_cap": degrade.cap,
        },
    }


# ---------------------------------------------------------------------------


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_robustness.json")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--requests", type=int, default=4,
                    help="isolation workload size (>= 3 so slots neighbor "
                         "the faulted one)")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--overload-requests", type=int, default=16,
                    help="offered load for the shedding/degradation configs")
    ap.add_argument("--queue-limit", type=int, default=6)
    ap.add_argument("--shed-policy", default="reject_newest",
                    choices=["reject_newest", "reject_largest",
                             "deadline_aware"])
    ap.add_argument("--d-model", type=int, default=64)
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.max_new = 8
        args.requests = 4
        args.overload_requests = 12
        args.slots = 2

    record = base_record(args, configs={})
    record["configs"]["fault_isolation"] = {
        "fault": {"kind": "nan_kv_cache", "rid": FAULT_RID,
                  "at_round": FAULT_ROUND},
        "rows": [
            _isolation_config(arch, args, speculative=spec)
            for arch in ISOLATION_ARCHS.values()
            for spec in (False, True)
        ],
    }
    record["configs"]["overload_shedding"] = _overload_config(args)
    record["configs"]["degradation"] = _degradation_config(args)
    emit_record(record, args.out)

    failures = []
    for row in record["configs"]["fault_isolation"]["rows"]:
        if not row["isolation_ok"]:
            failures.append(
                f"fault isolation violated for {row['arch']}/{row['mode']}: "
                f"{ {k: row[k] for k in ('fault_fired', 'unaffected_bit_identical', 'faulted_quarantined', 'clean_prefix_ok')} }"
            )
    ov = record["configs"]["overload_shedding"]
    if not ov["bounded"]["all_sheds_attributed"] or ov["bounded"]["shed"] == 0:
        failures.append("overload: sheds missing or unattributed")
    if ov["bounded"]["queue_wait_p99_s"] > ov["unbounded"]["queue_wait_p99_s"] * 1.05:
        failures.append(
            f"overload: bounded p99 queue-wait "
            f"{ov['bounded']['queue_wait_p99_s']}s exceeds unbounded "
            f"{ov['unbounded']['queue_wait_p99_s']}s"
        )
    dg = record["configs"]["degradation"]
    if not dg["degrade"]["deadline_met_frac"] > dg["pinned"]["deadline_met_frac"]:
        failures.append(
            f"degradation: met fraction {dg['degrade']['deadline_met_frac']} "
            f"does not strictly improve on pinned "
            f"{dg['pinned']['deadline_met_frac']}"
        )
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    print("robustness gates passed")
    return record


if __name__ == "__main__":
    main()
