"""Import shim: the property-test suite degrades gracefully without hypothesis.

``from _hypothesis_compat import given, settings, st, arrays`` gives the real
hypothesis API when the package is installed (requirements-dev.txt pins it).
When it is absent — minimal containers, bare CI runners — property tests
become individually-skipped tests instead of collection errors, and every
plain test in the same module still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra.numpy import arrays

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Any strategy constructor returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def arrays(*a, **k):
        return None

    def given(*a, **k):
        def deco(fn):
            def skipper():  # parameterless: no fixture resolution happens
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*a, **k):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "arrays", "given", "settings", "st"]
