"""Predicted-vs-measured gate for the PE-array simulator.

The simulator (``repro.sim``) claims its cycle model predicts serving cost.
This benchmark makes that claim falsifiable: it serves the same workload
under several configs (per-token burst=1, burst=8, free adaptive
controller, speculative), records a serve trace + wall-clock for each,
calibrates the array model against this machine (Tables 2/3/5 protocol),
replays every trace, and gates on three predictions:

* **cost ordering** — the simulator's host-attributed cycles (round-trips
  x the fitted dispatch floor) must order the burst-family configs the same
  way measured wall-clock does. The key is host cycles, not total cycles,
  deliberately: on this CPU the array back-end is emulated by vectorized
  matmuls whose wall time is insensitive to CORDIC depth and to drain
  padding, so config-level wall differences are dispatch-bound — exactly
  the term the calibration fits from this machine's dispatch floor. The
  array-compute half of the model (which dominates on the paper's actual
  hardware) is validated by the savings and scaling gates instead. Only
  pairs whose predicted costs differ by more than ``--ordering-margin``
  are comparable; near-ties are excluded rather than letting scheduler
  noise flip the gate.
* **savings agreement** — the simulator's ``est_cycle_savings_frac`` for
  the adaptive (and speculative) config must land within ``--savings-tol``
  relative of the value the serving loop itself reported. The serving bank
  is built WITH the calibration, so the ModeController and the simulator
  price cost identically — this gate isolates the *replay* accounting, not
  token counting.
* **PE scaling** — the simulated 64→256-lane time exponent over the
  Table 5 protocol (full cost model: waves + AF contention + weight
  stream + the fitted parallel penalty) must match the measured exponent
  within ``--scaling-tol``. The penalty constant comes from the same
  measurement, so this checks that the *rest* of the cost model (stalls,
  wave quantization) does not break the fitted scaling.

    PYTHONPATH=src python -m benchmarks.bench_sim --smoke \
        --trace artifacts/obs/trace.jsonl

``--smoke`` shrinks the workload for CI, writes
``artifacts/bench/BENCH_sim.json``, and exits nonzero on any gate failure.
``--trace PATH`` additionally replays an externally produced trace (CI
feeds it the obs-smoke serve trace) and applies the savings gate to it.
"""
from __future__ import annotations

import json
import os
import sys

import jax.numpy as jnp

from repro.core import EngineContext, FXP8, PrecisionPolicy
from repro.runtime import ControllerConfig, ModeController, build_bank, default_points
from repro.serve.engine import BatchedServer
from repro.sim import ArrayConfig, dot_pass_cost, replay_trace, run_calibration
from repro.sim.analyze import ordering_inversions, report_dict, savings_drift
from repro.spec import SpecConfig

from ._common import (
    ARTIFACTS,
    base_record,
    bench_parser,
    emit_record,
    load_model,
    make_requests,
    timed,
)


def _serve_traced(make_server, cfg, *, label, trace_dir, requests, prompt_len,
                  max_new, reps=3):
    """One config's measurement: warmup run (compile lands off-clock), then
    best-of-``reps`` traced timed runs — each with a fresh observer so every
    trace holds exactly one run, keeping the fastest run's trace so the
    measured wall and the replayed trace describe the same run. Returns
    (trace_path, row) where row carries the measured side of the
    comparison."""
    from repro.obs import ServingObserver

    srv = make_server()
    work = lambda: make_requests(cfg, requests, prompt_len=prompt_len,
                                 max_new=max_new)
    srv.run(work())  # warmup: jit compile + bucket tracing
    path = os.path.join(trace_dir, f"trace_{label}.jsonl")
    best = float("inf")
    for _ in range(reps):
        observer = ServingObserver(trace=True)
        srv.observer = observer
        dt, out = timed(lambda: srv.run(work()), warmup=0)
        if dt < best:
            best = dt
            observer.trace.write_jsonl(path)
            tokens = sum(len(v) for v in out.values())
    return path, {
        "config": label,
        "measured_wall_s": round(best, 4),
        "tok_s": round(tokens / max(best, 1e-9), 1),
        "tokens": tokens,
    }


def _replayed(path, row, calibration):
    """Attach the predicted side of one config's row from a replay."""
    result = replay_trace(path, calibration=calibration)
    t = result.totals
    row.update(
        predicted_cycles=round(t["total_cycles"], 1),
        predicted_wall_s=(round(t["predicted_wall_s"], 4)
                          if t.get("predicted_wall_s") is not None else None),
        pe_occupancy=round(t["pe_occupancy"], 4),
        host_sync_cycles=round(t["host_sync_cycles"], 1),
        savings=result.savings["est_cycle_savings_frac"],
        savings_rel_diff=savings_drift(result),
        spec_savings_rel_diff=(
            result.savings["speculative"]["rel_diff_vs_reported"]
            if result.savings.get("speculative") else None),
    )
    return result


def _sim_scaling_exponent(calibration, *, m=4096, k=512):
    """The Table 5 protocol run through the full cost model: an N-lane dot
    on an N-PE array at 64 and 256 lanes (work scales with N, like the
    measured sweep). Perfect scaling => time exponent 0."""
    import math

    cost = {}
    for n in (64, 256):
        cfg = ArrayConfig.from_calibration(calibration, n_pes=n)
        cost[n] = dot_pass_cost(cfg, k, n, 7, positions=m, bits=8).total
    return math.log(cost[256] / cost[64]) / math.log(256 / 64)


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_sim.json")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--cycle-budget", type=float, default=0.75)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also replay this serve trace (CI: the obs-smoke "
                         "trace) and apply the savings gate to it")
    ap.add_argument("--trace-dir", default=os.path.join(
        os.path.dirname(ARTIFACTS), "sim"))
    ap.add_argument("--ordering-margin", type=float, default=0.10,
                    help="predicted gaps at or below this relative margin "
                         "are near-ties, excluded from the ordering gate")
    ap.add_argument("--savings-tol", type=float, default=0.15,
                    help="max |simulated - reported| / |reported| savings")
    ap.add_argument("--scaling-tol", type=float, default=0.20,
                    help="max |simulated - measured| 64->256 PE exponent")
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.requests = 4
        args.max_new = 12

    os.makedirs(args.trace_dir, exist_ok=True)
    calibration = run_calibration(smoke=args.smoke)
    print(f"calibration {calibration['id']}:",
          json.dumps(calibration["constants"]))

    cfg, model, params = load_model(args.arch, full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    # the bank carries the calibration: controller, telemetry, and simulator
    # all price points with the same constants
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs(), calibration=calibration)
    max_len = args.prompt_len + args.max_new + SpecConfig().draft_len + 2

    def pinned(burst):
        return lambda: BatchedServer(
            model, ctx, params, slots=args.slots, max_len=max_len, burst=burst,
            controller=ModeController(bank, ControllerConfig(pin=bank.reference)),
        )

    configs = {
        "burst1": pinned(1),
        "burst8": pinned(8),
        "adaptive": lambda: BatchedServer(
            model, ctx, params, slots=args.slots, max_len=max_len, burst=4,
            controller=ModeController(
                bank, ControllerConfig(cycle_budget=args.cycle_budget)),
        ),
        "speculative": lambda: BatchedServer(
            model, ctx, params, slots=args.slots, max_len=max_len, bank=bank,
            speculate=SpecConfig(draft_len=3),
        ),
    }

    rows = []
    for label, make in configs.items():
        path, row = _serve_traced(
            make, cfg, label=label, trace_dir=args.trace_dir,
            requests=args.requests, prompt_len=args.prompt_len,
            max_new=args.max_new)
        _replayed(path, row, calibration)
        rows.append(row)
        print(f"{label}: predicted {row['predicted_cycles']:.3g} cycles, "
              f"measured {row['measured_wall_s']}s ({row['tok_s']} tok/s), "
              f"savings={row['savings']}")

    sim_exp = _sim_scaling_exponent(calibration)
    measured_exp = calibration["fit"]["measured_scaling_exponent"]
    scaling = {
        "sim_exponent": round(sim_exp, 4),
        "measured_exponent": round(measured_exp, 4),
        "abs_diff": round(abs(sim_exp - measured_exp), 4),
        "tolerance": args.scaling_tol,
    }
    print("scaling:", json.dumps(scaling))

    external = None
    if args.trace:
        result = replay_trace(args.trace, calibration=calibration)
        external = {
            "path": args.trace,
            "savings": result.savings["est_cycle_savings_frac"],
            "savings_rel_diff": savings_drift(result),
            "report": report_dict(result),
        }
        print(f"external trace {args.trace}: savings={external['savings']} "
              f"rel_diff={external['savings_rel_diff']}")

    # ordering over the pinned burst pair only: identical workload, identical
    # executed point — the configs differ in host round-trips alone, the one
    # axis the model and this machine agree on. Adaptive executes different
    # points (near-free on this CPU, expensive on the model's hardware) and
    # speculative restructures the rounds themselves; both are gated via
    # savings instead, where their trace carries a reported value to match.
    inversions = ordering_inversions(
        [(r["config"], r["host_sync_cycles"], r["measured_wall_s"])
         for r in rows if r["config"] in ("burst1", "burst8")],
        margin=args.ordering_margin)

    record = base_record(
        args,
        slots=args.slots, requests=args.requests, max_new=args.max_new,
        calibration={"id": calibration["id"],
                     "constants": calibration["constants"],
                     "fit": calibration["fit"]},
        configs=rows,
        scaling=scaling,
        ordering={"margin": args.ordering_margin, "inversions": inversions},
        external_trace=(
            {k: external[k] for k in ("path", "savings", "savings_rel_diff")}
            if external else None),
    )
    emit_record(record, args.out)

    failures = []
    for inv in inversions:
        failures.append(
            f"ordering: {inv['pair']} predicted {inv['predicted']} but "
            f"measured {inv['measured']}")
    for row in rows:
        for key, what in (("savings_rel_diff", "adaptive"),
                          ("spec_savings_rel_diff", "speculative")):
            drift = row.get(key)
            if drift is not None and drift > args.savings_tol:
                failures.append(
                    f"{row['config']}: simulated {what} savings drifts "
                    f"{drift:.3f} from reported (> {args.savings_tol})")
    if external and external["savings_rel_diff"] is not None \
            and external["savings_rel_diff"] > args.savings_tol:
        failures.append(
            f"external trace: savings drift {external['savings_rel_diff']:.3f} "
            f"(> {args.savings_tol})")
    if scaling["abs_diff"] > args.scaling_tol:
        failures.append(
            f"scaling: simulated exponent {sim_exp:.3f} vs measured "
            f"{measured_exp:.3f} (|diff| > {args.scaling_tol})")
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    print("bench_sim gates passed")
    return record


if __name__ == "__main__":
    main()
