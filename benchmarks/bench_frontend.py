"""Continuous-batching frontend benchmark: identity, interleaving, arrivals.

Three configs, each with a CI gate (``--smoke`` exits nonzero on violation):

* **identity** — the same greedy workload served by ``BatchedServer.run()``
  (monolithic prefill, batch admission) and through the
  :class:`~repro.serve.frontend.ContinuousScheduler` with a deliberately
  tiny chunk budget, per model family (attention chunking and the recurrent
  scan carry are different programs). Gate: token streams bit-identical —
  chunked prefill is a scheduling change, never a numerics change.

* **interleave** — short requests are decoding on every slot when one long
  prompt is admitted mid-run. Chunked arm vs ``monolithic_prefill`` arm on
  the same scheduler. Gates: the chunked arm's
  ``max_prefill_rows_between_bursts`` stays within one chunk budget (the
  structural no-stall bound: decoding slots wait at most ``chunk_tokens``
  prefill rows between bursts), and its p99 inter-token latency does not
  exceed the monolithic arm's *max* inter-token stall — the stall the
  monolithic arm takes in one tick is exactly what chunking amortizes.

* **arrival** — a seeded Poisson arrival process at a fixed offered rate
  through the scheduler with per-request deadlines and a bounded queue.
  Records TTFT / inter-token / queue-wait percentiles (submission-anchored:
  TTFT includes queue time) next to tok/s. Gates: every offered request
  settles with an attributed outcome, every served request has a TTFT
  sample, and the structural interleaving bound holds under load.

    PYTHONPATH=src python -m benchmarks.bench_frontend --smoke
"""
from __future__ import annotations

import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext
from repro.resilience import ResilienceConfig
from repro.serve.engine import BatchedServer, Request
from repro.serve.frontend import ContinuousScheduler, FrontendConfig

from ._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    make_requests,
    timed,
)

IDENTITY_ARCHS = {
    "dense": "olmo-1b",
    "ssm": "mamba2-780m",
    "mla_moe": "deepseek-v3-671b",
}


def _build(arch, args, *, max_len, resilience=None):
    cfg, model, params = load_model(arch, full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
    srv = BatchedServer(model, ctx, params, slots=args.slots, max_len=max_len,
                        burst=args.burst, resilience=resilience)
    return cfg, srv


def _frontend_run(server, reqs, *, chunk_tokens, monolithic=False):
    """Serve ``reqs`` through the scheduler (all submitted up front);
    returns (seconds, results, stats)."""
    sched = ContinuousScheduler(
        server, FrontendConfig(chunk_tokens=chunk_tokens,
                               monolithic_prefill=monolithic))
    t0 = time.perf_counter()
    with sched:
        for r in reqs:
            sched.submit(r)
        out = sched.drain()
    return time.perf_counter() - t0, out, dict(sched.stats)


# ---------------------------------------------------------------------------
# identity: chunked frontend streams == run() streams, per family
# ---------------------------------------------------------------------------


def _identity_config(args):
    rows = []
    for family, arch in IDENTITY_ARCHS.items():
        if args.smoke and family == "mla_moe":
            continue
        cfg, srv = _build(arch, args,
                          max_len=args.prompt_len + args.max_new + 2)
        work = lambda: make_requests(cfg, args.requests,
                                     prompt_len=args.prompt_len,
                                     max_new=args.max_new)
        dt_ref, ref = timed(lambda: srv.run(work()))
        dt_fe, out, stats = _frontend_run(srv, work(),
                                          chunk_tokens=args.chunk_tokens)
        total = sum(len(v) for v in ref.values())
        rows.append({
            "family": family,
            "arch": arch,
            "chunk_tokens": args.chunk_tokens,
            "run_tok_s": round(total / max(dt_ref, 1e-9), 1),
            "frontend_tok_s": round(total / max(dt_fe, 1e-9), 1),
            "prefill_chunks_per_prompt": round(
                stats["prefill_rows"] / max(args.prompt_len, 1)
                / max(args.requests, 1), 3),
            "bit_identical": out == ref,
        })
    return {"rows": rows}


# ---------------------------------------------------------------------------
# interleave: a long prompt admitted mid-run must not stall decode
# ---------------------------------------------------------------------------


def _interleave_config(args):
    long_len = args.long_prompt

    def serve(monolithic):
        cfg, srv = _build(
            "olmo-1b", args,
            max_len=max(args.prompt_len, long_len) + args.max_new + 2)
        obs = attach_observer(srv)
        short = make_requests(cfg, args.slots, prompt_len=args.prompt_len,
                              max_new=args.max_new)
        rng = np.random.default_rng(3)
        late = Request(
            99, rng.integers(0, cfg.vocab_size, long_len).astype(np.int32),
            args.max_new)
        sched = ContinuousScheduler(
            srv, FrontendConfig(chunk_tokens=args.chunk_tokens,
                                monolithic_prefill=monolithic))
        with sched:
            for r in short:
                sched.submit(r)
            # one tick so every slot is mid-decode, then the long prompt —
            # its prefill now interleaves (or, monolithic, stalls) decoding
            sched.step()
            sched.submit(late)
            out = sched.drain()
        block = latency_block(obs)
        return out, dict(sched.stats), block

    out_c, stats_c, lat_c = serve(False)
    out_m, stats_m, lat_m = serve(True)
    it_c, it_m = lat_c["intertoken_s"], lat_m["intertoken_s"]
    return {
        "long_prompt": long_len,
        "chunk_tokens": args.chunk_tokens,
        "streams_match_monolithic": out_c == out_m,
        "chunked": {
            "max_prefill_rows_between_bursts":
                stats_c["max_prefill_rows_between_bursts"],
            "intertoken_p99_s": it_c["p99"] if it_c else None,
            "tok_s": lat_c["tok_s"],
        },
        "monolithic": {
            "max_prefill_rows_between_bursts":
                stats_m["max_prefill_rows_between_bursts"],
            "intertoken_max_s": lat_m["intertoken_s"] and round(max(
                it_m["p99"], it_m["mean"]), 6),
            "tok_s": lat_m["tok_s"],
        },
    }


# ---------------------------------------------------------------------------
# arrival: Poisson offered load with deadlines + bounded admission
# ---------------------------------------------------------------------------


def _arrival_config(args):
    cfg, srv = _build(
        "olmo-1b", args, max_len=args.prompt_len + args.max_new + 2,
        resilience=ResilienceConfig(queue_limit=args.queue_limit,
                                    default_deadline_s=args.deadline_s))
    obs = attach_observer(srv)
    reqs = make_requests(cfg, args.arrival_requests,
                         prompt_len=args.prompt_len, max_new=args.max_new)
    rng = np.random.default_rng(11)
    gaps = rng.exponential(1.0 / args.arrival_rate, size=len(reqs))
    arrive = np.cumsum(gaps).tolist()

    sched = ContinuousScheduler(srv, FrontendConfig(
        chunk_tokens=args.chunk_tokens))
    pending = list(zip(arrive, reqs))
    t0 = time.perf_counter()
    with sched:
        while pending or not sched.idle:
            now = time.perf_counter() - t0
            while pending and pending[0][0] <= now:
                sched.submit(pending.pop(0)[1])
            if not sched.step() and pending:
                time.sleep(min(0.005, max(0.0, pending[0][0] - now)))
        out = dict(sched.results)
    dt = time.perf_counter() - t0

    statuses: dict = {}
    for o in srv.outcomes.values():
        statuses[o.status] = statuses.get(o.status, 0) + 1
    block = latency_block(obs)
    total = sum(len(v) for v in out.values())
    return {
        "offered": args.arrival_requests,
        "arrival_rate_hz": args.arrival_rate,
        "queue_limit": args.queue_limit,
        "deadline_s": args.deadline_s,
        "chunk_tokens": args.chunk_tokens,
        "tok_s": round(total / max(dt, 1e-9), 1),
        "outcomes": statuses,
        "outcomes_attributed": len(srv.outcomes) == args.arrival_requests,
        "ttft_samples": (block["ttft_s"] or {}).get("count", 0),
        "max_prefill_rows_between_bursts":
            sched.stats["max_prefill_rows_between_bursts"],
        "latency": block,
    }


# ---------------------------------------------------------------------------


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_frontend.json")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=4)
    ap.add_argument("--long-prompt", type=int, default=48,
                    help="interleave config: the mid-run long prompt length")
    ap.add_argument("--arrival-requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=40.0,
                    help="arrival config: offered Poisson rate (req/s)")
    ap.add_argument("--queue-limit", type=int, default=8)
    ap.add_argument("--deadline-s", type=float, default=30.0)
    ap.add_argument("--d-model", type=int, default=64)
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.requests = 4
        args.max_new = 8
        args.slots = 2
        args.long_prompt = 32
        args.arrival_requests = 10

    record = base_record(args, configs={})
    record["configs"]["identity"] = _identity_config(args)
    record["configs"]["interleave"] = _interleave_config(args)
    record["configs"]["arrival"] = _arrival_config(args)
    emit_record(record, args.out)

    failures = []
    for row in record["configs"]["identity"]["rows"]:
        if not row["bit_identical"]:
            failures.append(
                f"identity violated for {row['family']}: chunked frontend "
                "stream diverged from run()")
    il = record["configs"]["interleave"]
    if not il["streams_match_monolithic"]:
        failures.append("interleave: chunked streams diverged from "
                        "monolithic prefill")
    if il["chunked"]["max_prefill_rows_between_bursts"] > args.chunk_tokens:
        failures.append(
            f"interleave: {il['chunked']['max_prefill_rows_between_bursts']} "
            f"prefill rows between bursts exceeds the chunk budget "
            f"{args.chunk_tokens}")
    if il["monolithic"]["max_prefill_rows_between_bursts"] < args.long_prompt:
        failures.append("interleave: monolithic arm did not take the "
                        "one-tick stall the gate contrasts against")
    p99_c = il["chunked"]["intertoken_p99_s"]
    max_m = il["monolithic"]["intertoken_max_s"]
    if p99_c is not None and max_m is not None and p99_c > max_m * 1.5:
        failures.append(
            f"interleave: chunked p99 inter-token {p99_c}s exceeds the "
            f"monolithic arm's worst stall {max_m}s — chunking is not "
            "amortizing the long prompt")
    ar = record["configs"]["arrival"]
    if not ar["outcomes_attributed"]:
        failures.append("arrival: not every offered request settled with an "
                        "outcome")
    if ar["ttft_samples"] != ar["outcomes"].get("ok", 0):
        failures.append(
            f"arrival: {ar['ttft_samples']} TTFT samples for "
            f"{ar['outcomes'].get('ok', 0)} served requests")
    if ar["max_prefill_rows_between_bursts"] > args.chunk_tokens:
        failures.append("arrival: interleaving bound violated under load")
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    print("frontend gates passed")
    return record


if __name__ == "__main__":
    main()
