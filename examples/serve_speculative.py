"""Self-speculative serving under the CARMEN engine: draft shallow, verify deep.

CORDIC iteration depth trades accuracy for cycles on the SAME weights — the
exact draft/verify split speculative decoding needs, with zero extra model.
This demo serves a high-confidence greedy workload twice:

* **accurate-only**: every token through the deep (full-depth) execution
  point, one decode step per token — the baseline;
* **self-speculative**: a jitted draft loop rolls the shallow (approx-depth)
  point ``k`` tokens forward, then ONE accurate multi-token forward verifies
  all ``k+1`` positions, commits the accepted prefix + a corrected/bonus
  token, and rolls the KV cache back per slot.

Greedy speculative output is bit-identical to the baseline by construction
(asserted below); the win is the acceptance rate — on high-confidence tokens
the shallow point almost always agrees with the deep one (PR 2 measured 100%
teacher-forced greedy agreement there), so each verify round commits several
tokens for one accurate weight pass plus k cheap draft passes.

Run:  PYTHONPATH=src python examples/serve_speculative.py [--adaptive]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.runtime import ControllerConfig, ModeController, build_bank, default_points
from repro.serve.engine import BatchedServer, Request
from repro.spec import SpecConfig


def workload(cfg, n, max_new):
    rng = np.random.default_rng(7)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9))).astype(np.int32),
                max_new)
        for i in range(n)
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--adaptive", action="store_true",
                    help="let a mode controller pick the draft point per round")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fmt = FXP16  # approx depth 8 vs full depth 13: drafts at ~64% pass cost
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(fmt),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(fmt, hifi_fmt=None),
                      specs=model.specs())
    max_len = 8 + args.max_new + args.draft_len + 2

    ref_server = BatchedServer(model, ctx, bank.tree("accurate"),
                               slots=args.slots, max_len=max_len,
                               prepare_weights=False)
    t0 = time.time()
    ref_out = ref_server.run(workload(cfg, args.requests, args.max_new))
    ref_dt = time.time() - t0

    controller = None
    if args.adaptive:
        controller = ModeController(bank, ControllerConfig(start=bank.names[0]))
    spec_server = BatchedServer(
        model, ctx, params, slots=args.slots, max_len=max_len,
        speculate=SpecConfig(draft_len=args.draft_len),
        bank=bank, controller=controller,
    )
    t0 = time.time()
    spec_out = spec_server.run(workload(cfg, args.requests, args.max_new))
    spec_dt = time.time() - t0
    tele = spec_server.spec_telemetry.summary()

    gen_tokens = sum(len(v) for v in ref_out.values())
    print(f"bank: draft point {bank.names[0]!r} at "
          f"{bank.rel_cycles(bank.names[0]):.0%} of an accurate weight pass, "
          f"verify point {bank.reference!r}")
    print(f"accurate-only: {gen_tokens} tokens in {ref_dt:.1f}s; "
          f"speculative: {spec_dt:.1f}s (draft_len={args.draft_len})")
    print(f"acceptance: {tele['acceptance_rate']:.1%} of drafted tokens, "
          f"{tele['mean_accepted_per_step']:.2f} accepted / verify step, "
          f"{tele['tokens_per_step']:.2f} tokens committed / verify step")
    print(f"estimated weight-pass cycle savings vs accurate-only: "
          f"{tele['est_cycle_savings_frac']:.1%}")
    if controller is not None:
        print(f"draft-point occupancy (controller-picked): "
              f"{tele['rounds_by_draft_point']}")

    identical = all(spec_out[r] == ref_out[r] for r in ref_out)
    print(f"greedy output bit-identical to accurate-only: {identical}")
    assert identical, "speculative greedy output diverged from accurate-only"
    assert tele["mean_accepted_per_step"] >= 2.0, (
        f"mean accepted {tele['mean_accepted_per_step']:.2f} < 2 — the "
        "shallow point disagrees with the deep one too often on this workload"
    )
    return tele


if __name__ == "__main__":
    main()
