"""Shared benchmark plumbing: argparse boilerplate, model setup, timing, JSON.

Every JSON benchmark (``bench_prepared`` / ``bench_adaptive`` /
``bench_speculative`` / ``bench_serving``) shares the same skeleton:
``--arch/--full-size/--out`` (+ optional ``--smoke`` for the CI variant), a
reduced-model build, the :func:`timed` helper (warmup iteration +
``block_until_ready`` so records never include compile time or pending
dispatches), and a print-and-write JSON record. It lives here once.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.models import get_model
from repro.serve.engine import Request

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def bench_parser(description: str, *, default_out: str,
                 smoke: bool = True) -> argparse.ArgumentParser:
    """The common benchmark CLI: --arch / --full-size / --out [/ --smoke]."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--full-size", action="store_true",
                    help="benchmark the unreduced config")
    ap.add_argument("--out", default=os.path.join(ARTIFACTS, default_out))
    if smoke:
        ap.add_argument("--smoke", action="store_true",
                        help="tiny CI workload (reduced model, short generations)")
    return ap


def load_model(arch: str, *, full_size: bool = False, layers: int = 2,
               d_model: int = 128):
    """(cfg, model, params) for the benchmark workload (reduced by default;
    ``layers``/``d_model`` shrink the reduced config further for
    dispatch-bound smoke runs)."""
    cfg = get_config(arch)
    if not full_size:
        cfg = reduce_cfg(cfg, layers=layers, d_model=d_model)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def base_record(args, **extra):
    """The fields every benchmark record leads with."""
    rec = {
        "arch": args.arch,
        "reduced": not args.full_size,
        "backend": jax.default_backend(),
    }
    rec.update(extra)
    return rec


def make_requests(cfg, n, *, prompt_len, max_new, seed=1, temperature=0.0):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
                max_new, temperature=temperature)
        for i in range(n)
    ]


def timed(fn, *, warmup: int = 1):
    """Honest wall-clock for ``fn``: ``(seconds, result)``.

    Runs ``warmup`` discarded iterations first (jit compilation, bucket
    tracing, autotuning all land there), then times one call with
    ``jax.block_until_ready`` on the result so async dispatch cannot leak
    pending work past the clock. Every benchmark's timing goes through here;
    callers that want best-of-N (``bench_serving``) loop over
    ``timed(fn, warmup=0)`` themselves so they can interleave contenders.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return time.perf_counter() - t0, out


def attach_observer(server):
    """Attach a metrics-only :class:`repro.obs.ServingObserver` to a server.

    Trace recording stays off — benchmarks time the serving loop, and the
    metrics half is the part whose overhead CI bounds (``bench_serving``'s
    observability gate). Returns the observer; ``latency_block`` turns its
    last run into the BENCH-record block.
    """
    from repro.obs import ServingObserver

    server.observer = ServingObserver(trace=False)
    return server.observer


def latency_block(observer):
    """The SLO-latency block every serving BENCH record embeds.

    Percentile summaries (p50/p90/p99 from the streaming histograms) of the
    observer's most recent run: time-to-first-token, inter-token latency,
    queue wait, plus run throughput — latency percentiles next to tok/s, not
    instead of it.
    """
    snap = observer.metrics.snapshot()
    hists, gauges = snap["histograms"], snap["gauges"]

    def pct(name):
        h = hists.get(name)
        if not h or not h.get("count"):
            return None
        return {k: round(h[k], 6) for k in ("count", "mean", "p50", "p90", "p99")}

    return {
        "ttft_s": pct("ttft_s"),
        "intertoken_s": pct("intertoken_s"),
        "queue_wait_s": pct("queue_wait_s"),
        "tok_s": gauges.get("tok_s"),
    }


def emit_record(record, out: str):
    """Print the JSON record and (if ``out``) persist it for CI artifacts."""
    payload = json.dumps(record, indent=1)
    print(payload)
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            f.write(payload + "\n")
    return record
