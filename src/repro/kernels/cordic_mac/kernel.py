"""Pallas TPU kernel: CARMEN CORDIC-MAC as a blocked fixed-point matmul.

TPU-native adaptation of the paper's iterative CORDIC MAC (DESIGN.md §2):
the depth-d signed-digit rounding of the weights — the *entire* arithmetic
content of a depth-d linear-CORDIC multiplier — is applied to the weight
memory bank once (ops.py), and the MAC array itself is the MXU: an
int8/int16 x int8/int16 -> int32 blocked matmul. The epilogue fuses the
requantization stage and (optionally) the ReLU bypass of the multi-AF block,
mirroring the silicon pipeline MAC -> requant -> AF.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost; partial products accumulate
in an int32 VMEM scratch tile that lives across the K steps (the PE's wide
accumulator register). Block shapes are MXU-aligned (128 multiples; int8
native tile is (32, 128)).

VMEM budget at defaults bm=bn=bk=256:
    x tile   256*256*1B  =  64 KiB
    w tile   256*256*1B  =  64 KiB
    acc      256*256*4B  = 256 KiB
    out      256*256*4B  = 256 KiB   (dequantized f32)
    total ~= 640 KiB << 16 MiB VMEM (leaves room for double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def _mac_kernel(x_ref, w_ref, xscale_ref, wscale_ref, out_ref, acc_ref, *, n_k: int, fuse_relu: bool):
    """One (bm, bn) output tile; K-step ``pl.program_id(2)`` accumulates."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU path: integer dot with int32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        # requant stage: int32 accumulator -> float via the per-tile scales
        # (xscale: per-row of this tile; wscale: per-column of this tile).
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * xscale_ref[...] * wscale_ref[...]
        if fuse_relu:
            out = jnp.maximum(out, 0.0)
        out_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "fuse_relu", "interpret"),
)
def mac_matmul(
    x_q,
    w_q,
    x_scale,
    w_scale,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    fuse_relu: bool = False,
    interpret: bool = False,
):
    """Blocked integer matmul with fused requant (+ReLU) epilogue.

    x_q: (M, K) int8/int16 quantized activations.
    w_q: (K, N) int8/int16 signed-digit weights.
    x_scale: (M, 1) f32 per-row scales;  w_scale: (1, N) f32 per-col scales.
    Returns (M, N) f32.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes must be tile-aligned: {(m, k, n)} vs {(bm, bk, bn)}"
    )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_mac_kernel, n_k=n_k, fuse_relu=fuse_relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu_vmem((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation (TPU backend); plain scratch in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except ImportError:  # pragma: no cover - CPU-only environments
        return pl.MemorySpace.ANY(shape, dtype)
