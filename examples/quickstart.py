"""Quickstart: CARMEN's core idea in 60 lines.

The CORDIC iteration depth is a runtime accuracy knob: fewer iterations =
faster approximate compute, more = accurate compute, same hardware (here:
same compiled program).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    FXP8,
    FXP8_UNIT,
    af_ref,
    approx_depth,
    carmen_matmul_fast,
    cordic_mul,
    dequantize,
    full_depth,
    mac_cycles,
    multi_af_float,
    quantize,
)

rng = np.random.default_rng(0)

# --- 1. a single CORDIC multiply at different depths ------------------------
x, w = np.float32(1.375), np.float32(0.8125)
xq, wq = quantize(x, FXP8), quantize(w, FXP8_UNIT)
print(f"x*w = {x*w:.4f} (float)")
for depth in (full_depth(FXP8_UNIT), approx_depth(FXP8_UNIT), 3, 2):
    y = float(dequantize(cordic_mul(xq, wq, depth, FXP8_UNIT), FXP8))
    print(f"  depth {depth}: {y:+.4f}  err {abs(y - x*w):.4f}  cycles/MAC {depth + 1}")

# --- 2. matmul through the vector engine ------------------------------------
a = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
b = rng.uniform(-1, 1, (64, 8)).astype(np.float32)
exact = a @ b
for depth in (full_depth(FXP8_UNIT), approx_depth(FXP8_UNIT)):
    out = np.asarray(carmen_matmul_fast(a, b, depth, FXP8, FXP8_UNIT))
    rel = np.abs(out - exact).max() / np.abs(exact).max()
    saving = 1 - mac_cycles(64, depth) / mac_cycles(64, full_depth(FXP8_UNIT))
    print(f"matmul depth {depth}: rel_err {rel:.4f}, cycle saving {saving:.0%}")

# --- 3. the time-multiplexed multi-AF block ---------------------------------
xs = rng.uniform(-1.9, 1.9, 1000).astype(np.float32)
print("multi-AF block max |err| vs float reference (FxP8 I/O):")
for mode in ("relu", "gelu", "tanh", "sigmoid", "swish", "selu"):
    out = np.asarray(multi_af_float(xs, mode, full_depth(FXP8), FXP8))
    err = np.abs(out - np.asarray(af_ref(xs, mode))).max()
    print(f"  {mode:8s} {err:.4f}  ({err / FXP8.scale:.1f} LSB)")
