"""Request outcomes, admission control, and load-shedding policies.

Every request a resilient :class:`~repro.serve.engine.BatchedServer` run
touches ends in exactly one structured :class:`RequestOutcome`:

=========== ================================================================
``ok``       ran to its token budget (``max_new``) and was returned
``expired``  missed its deadline mid-decode; evicted at a burst boundary
             with the tokens it had committed so far
``shed``     never admitted — rejected at the queue with an attributable
             ``reason`` (``queue_full`` / ``too_long`` / ``empty_prompt`` /
             ``deadline_expired``)
``faulted``  produced non-finite or saturated logits; quarantined and
             evicted at the burst boundary so its slot state never corrupts
             neighbors (clean tokens committed before the fault are kept)
``aborted``  the run itself died mid-flight (filled in by ``_end_run`` so a
             crashed run is still fully attributable), or — on the streaming
             frontend — the client cancelled / disconnected (reason
             ``cancelled``, partial tokens kept)
=========== ================================================================

:class:`ResilienceConfig` switches the server from the legacy fail-stop
contract (oversized prompt raises, NaN poisons the batch silently) to the
shed/quarantine contract above. ``resilience=None`` keeps the legacy
behavior byte-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["OUTCOME_STATUSES", "RequestOutcome", "ResilienceConfig",
           "SHED_POLICIES", "shed_overflow"]

OUTCOME_STATUSES = ("ok", "expired", "shed", "faulted", "aborted")

# shed policies: how a bounded queue picks victims when it overflows
SHED_POLICIES = ("reject_newest", "reject_largest", "deadline_aware")


@dataclasses.dataclass
class RequestOutcome:
    """The structured terminal state of one request in one run."""

    rid: int
    status: str                        # one of OUTCOME_STATUSES
    reason: Optional[str] = None       # shed/fault attribution
    tokens: int = 0                    # tokens committed (partial for expired/faulted)
    deadline_s: Optional[float] = None # the request's deadline (run-relative)
    wall_s: Optional[float] = None     # run entry -> outcome decision

    def __post_init__(self):
        if self.status not in OUTCOME_STATUSES:
            raise ValueError(
                f"unknown outcome status {self.status!r}; expected one of "
                f"{OUTCOME_STATUSES}"
            )

    @property
    def deadline_met(self) -> bool:
        """Completed with its full budget inside its deadline (requests
        without a deadline count as met when they complete)."""
        if self.status != "ok":
            return False
        if self.deadline_s is None or self.wall_s is None:
            return True
        return self.wall_s <= self.deadline_s

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["deadline_met"] = self.deadline_met
        return d


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Fault-tolerance knobs for one :class:`BatchedServer`.

    ``queue_limit`` bounds the admission queue: overflow is shed per
    ``shed_policy`` with reason ``queue_full`` instead of waiting unboundedly.
    ``fault_isolation`` turns on the per-slot non-finite-logit flag in the
    decode burst carry (detection itself is always compiled in — it rides the
    burst's existing host transfer — this switches whether the host acts on
    it). ``logit_limit`` additionally treats ``|logit| > limit`` as a
    saturated accumulator. ``default_deadline_s`` applies to requests that
    carry no ``deadline_s`` of their own.
    """

    queue_limit: Optional[int] = None
    shed_policy: str = "reject_newest"
    fault_isolation: bool = True
    logit_limit: Optional[float] = None
    default_deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"unknown shed policy {self.shed_policy!r}; expected one of "
                f"{SHED_POLICIES}"
            )
        if self.queue_limit is not None and self.queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {self.queue_limit}")
        if self.logit_limit is not None and self.logit_limit <= 0:
            raise ValueError(f"logit_limit must be > 0, got {self.logit_limit}")
        if self.default_deadline_s is not None and self.default_deadline_s <= 0:
            raise ValueError(
                f"default_deadline_s must be > 0, got {self.default_deadline_s}"
            )


def shed_overflow(queue: List, limit: int, policy: str,
                  deadline_of=None) -> Tuple[List, List]:
    """Shrink ``queue`` to ``limit`` requests; returns ``(kept, shed)``.

    ``kept`` preserves arrival order (admission fairness is FIFO among the
    survivors regardless of policy). Policies pick the victims:

    * ``reject_newest`` — drop from the tail (arrival order is priority);
    * ``reject_largest`` — drop the largest prompts first (one oversized
      prompt costs more prefill than several small ones);
    * ``deadline_aware`` — drop the requests with the least deadline slack
      first (they are the least likely to finish in time anyway; requests
      without a deadline have infinite slack and shed last).

    ``deadline_of`` lets the caller supply resolved deadlines (e.g. the
    server's run-local resolution of ``default_deadline_s``) instead of the
    raw ``request.deadline_s`` field the request happens to carry.
    """
    if deadline_of is None:
        deadline_of = lambda r: r.deadline_s
    if len(queue) <= limit:
        return list(queue), []
    if policy == "reject_newest":
        return list(queue[:limit]), list(queue[limit:])
    if policy == "reject_largest":
        # stable sort: ties shed newest-first
        order = sorted(range(len(queue)), key=lambda i: (-len(queue[i].prompt), -i))
    elif policy == "deadline_aware":
        inf = float("inf")
        order = sorted(
            range(len(queue)),
            key=lambda i: (
                deadline_of(queue[i]) if deadline_of(queue[i]) is not None
                else inf,
                i,
            ),
        )
    else:
        raise ValueError(f"unknown shed policy {policy!r}")
    victims = set(order[: len(queue) - limit])
    kept = [r for i, r in enumerate(queue) if i not in victims]
    shed = [r for i, r in enumerate(queue) if i in victims]
    return kept, shed
