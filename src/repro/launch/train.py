"""End-to-end training driver.

Runs real training (CPU: reduced configs; TPU: full configs) with the complete
substrate: sharded params/optimizer, deterministic data pipeline, CARMEN
engine modes, checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --batch 8 --seq 64 --mode exact --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.core import EngineContext, FXP8, FXP16, PrecisionPolicy
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.sharding import partition
from repro.train import checkpoint, optimizer as opt
from repro.train.train_loop import TrainConfig, make_train_step


def engine_ctx(mode: str, compute_dtype) -> EngineContext:
    if mode == "exact":
        return EngineContext(mode="exact", compute_dtype=compute_dtype)
    fmt = FXP16 if mode.endswith("16") else FXP8
    return EngineContext(
        mode=mode.replace("16", ""), policy=PrecisionPolicy.accurate(fmt),
        compute_dtype=compute_dtype,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", help="small-config CPU run")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mode", choices=["exact", "carmen", "carmen16", "int8"], default="exact")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = get_model(cfg)
    dtype = jnp.float32 if args.reduced else cfg.compute_dtype
    ctx = engine_ctx(args.mode, dtype)
    tcfg = TrainConfig(
        optimizer=opt.AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        microbatches=args.microbatches,
        remat=not args.reduced,
    )

    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    pipe = TokenPipeline(cfg, args.seq, args.batch)
    with mesh:
        specs = model.specs()
        param_sh, _ = partition.param_shardings(specs, mesh)
        params = jax.jit(
            lambda k: model.init(k, dtype), out_shardings=param_sh
        )(jax.random.PRNGKey(0))
        opt_state = opt.init_state(params)
        start_step = 0
        if args.resume and args.ckpt_dir:
            latest = checkpoint.latest_step(args.ckpt_dir)
            if latest is not None:
                params = checkpoint.restore(args.ckpt_dir, latest, params, shardings=param_sh)
                opt_state = checkpoint.restore(
                    args.ckpt_dir + "/opt", latest, opt_state
                )
                start_step = latest
                print(f"resumed from step {latest}")

        step_fn = jax.jit(make_train_step(model, ctx, tcfg), donate_argnums=(0, 1))
        t0, losses = time.time(), []
        for step in range(start_step, args.steps):
            batch = pipe.batch(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step + 1, params, background=True)
                checkpoint.save(args.ckpt_dir + "/opt", step + 1, opt_state)
        dt = time.time() - t0
        tok_s = args.batch * args.seq * (args.steps - start_step) / max(dt, 1e-9)
        print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
              f"({tok_s:.0f} tok/s), loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
