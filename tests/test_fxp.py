"""Property tests for the fixed-point substrate."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import FXP8, FXP16, FxPFormat, dequantize, quantize
from repro.core.fxp import requantize, saturate

FORMATS = [FXP8, FXP16, FxPFormat(8, 4), FxPFormat(16, 14), FxPFormat(12, 8)]


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_roundtrip_error_half_lsb(fmt, rng):
    x = rng.uniform(fmt.min_value, fmt.max_value, 4096).astype(np.float32)
    back = np.asarray(dequantize(quantize(x, fmt), fmt))
    assert np.max(np.abs(back - x)) <= fmt.scale / 2 + 1e-7


@pytest.mark.parametrize("fmt", FORMATS, ids=str)
def test_saturation(fmt):
    big = np.array([1e9, -1e9], np.float32)
    q = np.asarray(quantize(big, fmt))
    assert q[0] == fmt.qmax and q[1] == fmt.qmin


@given(
    val=st.floats(-1.875, 1.875, allow_nan=False, width=32),
    frac_a=st.integers(4, 14),
    frac_b=st.integers(4, 14),
)
@settings(max_examples=200, deadline=None)
def test_requantize_preserves_value(val, frac_a, frac_b):
    a, b = FxPFormat(16, frac_a), FxPFormat(16, frac_b)
    qa = quantize(np.float32(val), a)
    qb = requantize(qa, a, b)
    va, vb = float(dequantize(qa, a)), float(dequantize(qb, b))
    assert abs(va - vb) <= max(a.scale, b.scale) / 2 + 1e-7


def test_format_invariants():
    assert FXP8.one == 64 and FXP8.qmax == 127 and FXP8.qmin == -128
    assert str(FXP8) == "Q1.6" and str(FXP16) == "Q3.12"
    assert FXP8.storage_dtype.__name__ == "int8"
    assert FXP16.storage_dtype.__name__ == "int16"


def test_saturate_raw():
    import jax.numpy as jnp

    raw = jnp.array([1000, -1000, 5], jnp.int32)
    out = np.asarray(saturate(raw, FXP8))
    assert list(out) == [127, -128, 5]
