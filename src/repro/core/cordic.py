"""Unified iterative CORDIC (Walther 1971) — bit-faithful fixed-point simulation.

This module is the algorithmic heart of CARMEN. Everything is carried as raw
int32 fixed-point values (binary point given by an ``FxPFormat``) and iterated
with shift-add updates exactly as the RTL datapath would execute them:

* **linear rotation**      — multiply-accumulate: ``y <- y0 + x0 * z0``
* **linear vectoring**     — divide:              ``z <- z0 + y0 / x0``
* **hyperbolic rotation**  — ``(x, y) <- A_h * (cosh z0, sinh z0)`` (gain
  pre-compensated), from which ``exp = cosh + sinh``

The paper's key insight — *iteration depth directly governs accuracy* — is the
``depth`` argument on every entry point. One CORDIC iteration contributes one
signed digit ``d_k 2^-k``, so ``depth = d`` bounds the multiplier residual by
``2^-(d-1)``: depth is a runtime precision knob requiring no datapath change.

All loops are ``lax.fori_loop``/``lax.scan`` so depth can be large without HLO
blow-up, and every function is shape-polymorphic over the input arrays (the
vector-engine lanes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .fxp import FxPFormat, saturate

__all__ = [
    "full_depth",
    "approx_depth",
    "linear_rotate",
    "linear_vectoring",
    "hyperbolic_rotate",
    "hyperbolic_sequence",
    "cordic_mul",
    "cordic_div",
    "cordic_exp",
    "signed_digit_round",
]


def full_depth(fmt: FxPFormat) -> int:
    """Iterations for 'accurate' mode: one per fractional bit plus the sign digit."""
    return fmt.frac + 1


def approx_depth(fmt: FxPFormat) -> int:
    """'Approximate' mode: 2/3 of full depth — the paper's 33% cycle reduction."""
    return max(2, (2 * full_depth(fmt)) // 3)


# ---------------------------------------------------------------------------
# Linear mode
# ---------------------------------------------------------------------------


def linear_rotate(x, y, z, depth: int, z_fmt: FxPFormat):
    """Linear-mode rotation: drive z -> 0, accumulating ``y += x * z``.

    x, y: raw int32 in the *data* format (binary point irrelevant to the
    recurrence — x enters linearly). z: raw int32 in ``z_fmt`` with |value| < 2
    (one integer bit) for convergence.

    Returns (y_out, z_residual). After ``depth`` iterations
    ``y_out ~= y + x * value(z)`` with multiplier error ``<= 2^-(depth-1)``
    plus shift-truncation error ``< depth`` LSBs of x.
    """
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    z = jnp.asarray(z, jnp.int32)

    def body(k, carry):
        y, z = carry
        d = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        y = y + d * (x >> k)
        z = z - d * (jnp.int32(z_fmt.one) >> k)
        return (y, z)

    y, z = jax.lax.fori_loop(0, depth, body, (y, z))
    return y, z


def linear_vectoring(x, y, z, depth: int, z_fmt: FxPFormat):
    """Linear-mode vectoring: drive y -> 0, accumulating ``z += y / x``.

    Requires |y/x| <= 2. x, y share a binary point; the quotient lands in
    ``z_fmt``. Returns (z_out, y_residual).
    """
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    z = jnp.asarray(z, jnp.int32)

    def body(k, carry):
        y, z = carry
        # choose the digit that shrinks |y|
        d = jnp.where((y >= 0) == (x >= 0), jnp.int32(-1), jnp.int32(1))
        y = y + d * (x >> k)
        z = z - d * (jnp.int32(z_fmt.one) >> k)
        return (y, z)

    y, z = jax.lax.fori_loop(0, depth, body, (y, z))
    return z, y


# ---------------------------------------------------------------------------
# Hyperbolic mode
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def hyperbolic_sequence(depth: int) -> tuple:
    """Shift sequence 1,2,3,4,4,5,...,13,13,... (repeat k=4,13,40,... = 3k+1)."""
    seq = []
    k, next_repeat = 1, 4
    while len(seq) < depth:
        seq.append(k)
        if k == next_repeat and len(seq) < depth:
            seq.append(k)  # repeated iteration
            next_repeat = 3 * k + 1
        k += 1
    return tuple(seq[:depth])


@functools.lru_cache(maxsize=None)
def _hyperbolic_tables(depth: int, frac: int):
    seq = hyperbolic_sequence(depth)
    gain = 1.0
    for k in seq:
        gain *= math.sqrt(1.0 - 2.0 ** (-2 * k))
    atanh = np.round(np.array([math.atanh(2.0 ** -k) for k in seq]) * (1 << frac))
    inv_gain = int(round((1.0 / gain) * (1 << frac)))
    max_angle = float(np.sum([math.atanh(2.0 ** -k) for k in seq]))
    return (
        np.array(seq, np.int32),
        np.array(atanh, np.int32),
        inv_gain,
        max_angle,
    )


def hyperbolic_rotate(z, depth: int, fmt: FxPFormat):
    """Hyperbolic rotation from (x0, y0) = 1/A_h: returns (cosh z, sinh z) raw.

    Convergence requires |z| <= ~1.118 (callers range-reduce; we clip as the
    silicon saturation stage would).
    """
    seq, atanh_tab, inv_gain, max_angle = _hyperbolic_tables(depth, fmt.frac)
    zmax = int(max_angle * (1 << fmt.frac))
    z = jnp.clip(jnp.asarray(z, jnp.int32), -zmax, zmax)
    x = jnp.full(z.shape, inv_gain, jnp.int32)
    y = jnp.zeros(z.shape, jnp.int32)

    # Unrolled over the static shift schedule (depth <= ~20): the shift amounts
    # and atanh constants embed as scalar literals, which keeps the loop valid
    # inside Pallas kernel bodies (array-constant capture is rejected there).
    for k, a in zip(seq.tolist(), atanh_tab.tolist()):
        d = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        x, y = x + d * (y >> k), y + d * (x >> k)
        z = z - d * a
    return x, y


# ---------------------------------------------------------------------------
# High-level ops used by the MAC / AF blocks
# ---------------------------------------------------------------------------


def cordic_mul(x_raw, w_raw, depth: int, w_fmt: FxPFormat):
    """Elementwise fixed-point multiply via linear rotation: value(x) * value(w).

    ``w`` is the multiplier (|value| < 2 — weight formats are Q1.f). The result
    carries x's binary point. Broadcasts like ``x * w``.
    """
    x_b, w_b = jnp.broadcast_arrays(jnp.asarray(x_raw, jnp.int32), jnp.asarray(w_raw, jnp.int32))
    y, _ = linear_rotate(x_b, jnp.zeros_like(x_b), w_b, depth, w_fmt)
    return y


def cordic_div(num_raw, den_raw, depth: int, out_fmt: FxPFormat):
    """Fixed-point divide via linear vectoring: value(num)/value(den) in out_fmt.

    Requires |num/den| <= 2 and den > 0 (callers guarantee both — AF ratios are
    <= 1 by construction). num/den share a binary point.
    """
    num_b, den_b = jnp.broadcast_arrays(
        jnp.asarray(num_raw, jnp.int32), jnp.asarray(den_raw, jnp.int32)
    )
    z, _ = linear_vectoring(den_b, num_b, jnp.zeros_like(num_b), depth, out_fmt)
    return z


_LN2 = math.log(2.0)


def cordic_exp(x_raw, depth: int, fmt: FxPFormat):
    """exp(value(x)) in ``fmt`` via range reduction + hyperbolic rotation.

    x = Q ln2 + r with |r| <= ln2/2; exp(x) = 2^Q (cosh r + sinh r). The 2^Q
    factor is a barrel shift. Saturates on overflow (Q > int_bits).
    """
    x = jnp.asarray(x_raw, jnp.int32)
    ln2_raw = jnp.int32(int(round(_LN2 * (1 << fmt.frac))))
    # round-to-nearest integer quotient (floor division handles negatives)
    q = (2 * x + ln2_raw) // (2 * ln2_raw)
    r = x - q * ln2_raw
    c, s = hyperbolic_rotate(r, depth, fmt)
    e = c + s  # exp(r), raw in fmt; e_raw < 2^(frac+1) since exp(ln2/2) < 2
    # barrel shift by q with saturation; bound shift amounts for lax validity
    q = jnp.clip(q, -31, 29 - fmt.frac)
    e = jnp.where(q >= 0, e << jnp.where(q >= 0, q, 0), e >> jnp.where(q < 0, -q, 0))
    return saturate(e, FxPFormat(32, fmt.frac))


def signed_digit_round(w, depth: int, w_fmt: FxPFormat):
    """Fast CORDIC error model: the effective multiplier after ``depth`` iterations.

    Linear rotation multiplies by ``z_hat = sum_{k<depth} d_k 2^-k`` — i.e. the
    true multiplier rounded to a depth-digit signed-digit number. Simulating
    only the z-recurrence (cheap, elementwise, cacheable per weight tensor)
    gives z_hat exactly; ``x @ dequant(z_hat)`` then reproduces CORDIC matmul
    up to shift-truncation error (< depth LSBs of x, validated in tests).

    Input/output: float32 *values* (not raw).
    """
    z = jnp.round(jnp.asarray(w, jnp.float32) * (1 << w_fmt.frac)).astype(jnp.int32)
    z = jnp.clip(z, w_fmt.qmin, w_fmt.qmax)

    def body(k, carry):
        z, acc = carry
        d = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        step = jnp.int32(w_fmt.one) >> k
        return (z - d * step, acc + d * step)

    _, acc = jax.lax.fori_loop(0, depth, body, (z, jnp.zeros_like(z)))
    return acc.astype(jnp.float32) * np.float32(w_fmt.scale)
