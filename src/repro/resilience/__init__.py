"""Fault-tolerant serving: outcomes, admission control, degradation, injection.

The resilience layer turns the serving engine's fail-stop contract into a
shed/quarantine/degrade contract (see ``docs/robustness.md``):

* :class:`ResilienceConfig` — deadlines, bounded admission queue with
  pluggable shed policies, per-slot numeric fault isolation;
* :class:`RequestOutcome` — every request ends in exactly one structured
  outcome (``ok`` / ``expired`` / ``shed`` / ``faulted`` / ``aborted``);
* :class:`DegradationPolicy` — overload-driven cap on the controller's
  CORDIC-depth ladder: demote the whole batch before shedding, promote back
  with hysteresis;
* :class:`FaultInjector` — deterministic NaN-cache / NaN-weight / delay
  faults pinned to decode-round indices, for tests and
  ``benchmarks/bench_robustness.py``.
"""
from .degrade import DegradationConfig, DegradationPolicy
from .inject import (DelayFault, FaultInjector, NaNCacheFault, NaNWeightFault,
                     oversized_request, poison_cache_slot, poison_tree)
from .outcome import (OUTCOME_STATUSES, RequestOutcome, ResilienceConfig,
                      SHED_POLICIES, shed_overflow)

__all__ = [
    "DegradationConfig",
    "DegradationPolicy",
    "DelayFault",
    "FaultInjector",
    "NaNCacheFault",
    "NaNWeightFault",
    "OUTCOME_STATUSES",
    "RequestOutcome",
    "ResilienceConfig",
    "SHED_POLICIES",
    "oversized_request",
    "poison_cache_slot",
    "poison_tree",
    "shed_overflow",
]
