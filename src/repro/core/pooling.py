"""AAD (Average-Absolute-Deviation) pooling unit (paper §II-C, ref [14]).

AAD pooling replaces max/average pooling with a robust statistic: within each
window, elements whose deviation from the window mean is at most the mean
absolute deviation are averaged; outliers are excluded. Khalil et al. [14]
report it recovers 0.5-1% accuracy in approximate-arithmetic accelerators
because quantization outliers no longer dominate the pooled value — which is
why CARMEN pairs it with the CORDIC MAC.

The "on-the-fly" hardware form streams the window twice (mean pass, select
pass); functionally identical to the batched form implemented here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["aad_pool", "aad_pool_1d", "avg_pool", "max_pool"]


def _window_reduce(x, window, stride, fn, init):
    return jax.lax.reduce_window(
        x, init, fn, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    )


def _patches(x, window: int, stride: int):
    """(B, H, W, C) -> (B, Ho, Wo, window*window, C) via gather of strided slices."""
    b, h, w, c = x.shape
    ho = (h - window) // stride + 1
    wo = (w - window) // stride + 1
    idx_h = (jnp.arange(ho) * stride)[:, None] + jnp.arange(window)[None, :]  # (Ho, win)
    idx_w = (jnp.arange(wo) * stride)[:, None] + jnp.arange(window)[None, :]
    rows = x[:, idx_h]  # (B, Ho, win, W, C)
    pat = rows[:, :, :, idx_w]  # (B, Ho, win, Wo, win, C)
    pat = jnp.moveaxis(pat, 3, 2)  # (B, Ho, Wo, win, win, C)
    return pat.reshape(b, ho, wo, window * window, c)


def aad_pool(x, window: int = 2, stride: int | None = None):
    """AAD pooling over NHWC feature maps."""
    stride = stride or window
    pat = _patches(jnp.asarray(x), window, stride)  # (..., K, C)
    mean = jnp.mean(pat, axis=-2, keepdims=True)
    dev = jnp.abs(pat - mean)
    aad = jnp.mean(dev, axis=-2, keepdims=True)
    keep = (dev <= aad + 1e-12).astype(pat.dtype)
    ksum = jnp.sum(keep, axis=-2)
    out = jnp.sum(pat * keep, axis=-2) / jnp.maximum(ksum, 1.0)
    # empty-selection fallback (cannot happen for real windows, kept for safety)
    return jnp.where(ksum > 0, out, jnp.squeeze(mean, -2))


def aad_pool_1d(x, window: int, stride: int | None = None):
    """AAD pooling over (..., T, C) sequences (used by the audio frontend stub)."""
    stride = stride or window
    t = x.shape[-2]
    to = (t - window) // stride + 1
    idx = (jnp.arange(to) * stride)[:, None] + jnp.arange(window)[None, :]
    pat = jnp.take(x, idx, axis=-2)  # (..., To, win, C)
    mean = jnp.mean(pat, axis=-2, keepdims=True)
    dev = jnp.abs(pat - mean)
    aad = jnp.mean(dev, axis=-2, keepdims=True)
    keep = (dev <= aad + 1e-12).astype(pat.dtype)
    ksum = jnp.sum(keep, axis=-2)
    out = jnp.sum(pat * keep, axis=-2) / jnp.maximum(ksum, 1.0)
    return jnp.where(ksum > 0, out, jnp.squeeze(mean, -2))


def avg_pool(x, window: int = 2, stride: int | None = None):
    stride = stride or window
    s = _window_reduce(jnp.asarray(x, jnp.float32), window, stride, jax.lax.add, 0.0)
    return s / float(window * window)


def max_pool(x, window: int = 2, stride: int | None = None):
    stride = stride or window
    return _window_reduce(jnp.asarray(x), window, stride, jax.lax.max, -jnp.inf)
