"""Normalization unit (paper §II-C).

The accuracy-sensitivity metric pins normalization to the accurate path (it is
variance-dominated and catastrophically amplifies LSB noise), so the unit
computes in fp32 regardless of the surrounding FxP precision — mirroring the
paper's dedicated normalization block sitting outside the quantized MAC array.

Provides every variant the assigned architectures need:
  rmsnorm            (llama-family, qwen, yi, zamba2, mamba2)
  layernorm          (seamless, internvl backbone)
  nonparametric_ln   (olmo-1b: LN without affine params)
  qk_norm            (qwen3: per-head RMS norm of q/k)
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rmsnorm", "layernorm", "nonparametric_ln", "qk_norm", "l2norm"]


def rmsnorm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without affine parameters."""
    return layernorm(x, None, None, eps)


def qk_norm(q, weight, eps: float = 1e-6):
    """Per-head RMS norm over head_dim (qwen3). q: (..., heads, head_dim)."""
    return rmsnorm(q, weight, eps)


def l2norm(x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jnp.reciprocal(jnp.sqrt(jnp.sum(xf * xf, -1, keepdims=True) + eps))).astype(x.dtype)
