"""Property tests for the logical-axis sharding rules (sharding/partition.py).

``param_pspec`` / ``_resolve`` only read ``mesh.axis_names`` and
``mesh.shape``, so a duck-typed stand-in mesh drives them through thousands
of (rule, dim, mesh-extent) combinations without any devices:

* the divisibility fallback NEVER shards a non-dividing dim;
* the rule preference order is respected (first dividing group wins);
* ``report`` records EVERY dropped rule (each group tried before the
  winner, with the extent that failed to divide);
* one mesh axis is never claimed by two dims of the same spec.

Uses ``tests/_hypothesis_compat.py``: without hypothesis installed the
property tests skip individually and the plain tests still run.
"""
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.models.params import ParamSpec
from repro.sharding import partition


class FakeMesh:
    """Duck-typed mesh: axis_names + shape mapping, no devices needed."""

    def __init__(self, sizes):
        self.shape = dict(sizes)
        self.axis_names = tuple(self.shape)


RULE_AXES = [a for a in partition.PARAM_RULES if a is not None]

if HAVE_HYPOTHESIS:
    mesh_sizes = st.fixed_dictionaries({
        "pod": st.sampled_from([1, 2]),
        "data": st.sampled_from([1, 2, 3, 4, 8, 16]),
        "model": st.sampled_from([1, 2, 3, 4, 8, 16]),
    })
    dims = st.integers(min_value=1, max_value=512)
    axes = st.sampled_from(RULE_AXES)
else:  # placeholders; @given replaces the bodies with skippers
    mesh_sizes = dims = axes = None


def _extent(mesh, group):
    return int(np.prod([mesh.shape[a] for a in group]))


@given(axes, dims, mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_resolve_never_shards_non_dividing_dim(axis, dim, sizes):
    mesh = FakeMesh(sizes)
    report = []
    group = partition._resolve(axis, dim, mesh, report)
    if group is not None:
        assert dim % _extent(mesh, group) == 0


@given(axes, dims, mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_resolve_respects_preference_order(axis, dim, sizes):
    """The winner is the FIRST candidate group (restricted to present mesh
    axes) whose extent divides the dim."""
    mesh = FakeMesh(sizes)
    group = partition._resolve(axis, dim, mesh, [])
    candidates = []
    for g in partition.PARAM_RULES[axis]:
        g = tuple(a for a in g if a in mesh.axis_names)
        if g:
            candidates.append(g)
    dividing = [g for g in candidates if dim % _extent(mesh, g) == 0]
    assert group == (dividing[0] if dividing else None)


@given(axes, dims, mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_resolve_reports_every_dropped_rule(axis, dim, sizes):
    """Each candidate tried before the winner that failed divisibility lands
    in the report as (axis, dim, group, extent)."""
    mesh = FakeMesh(sizes)
    report = []
    group = partition._resolve(axis, dim, mesh, report)
    expected = []
    for g in partition.PARAM_RULES[axis]:
        g = tuple(a for a in g if a in mesh.axis_names)
        if not g:
            continue
        if dim % _extent(mesh, g) == 0:
            break  # the winner: nothing after it is tried
        expected.append((axis, dim, g, _extent(mesh, g)))
    assert report == expected
    for a, d, g, e in report:
        assert d % e != 0  # a dropped rule is always a non-dividing one


@given(
    st.lists(st.tuples(axes, dims), min_size=1, max_size=5),
    mesh_sizes,
)
@settings(max_examples=200, deadline=None)
def test_param_pspec_no_duplicate_mesh_axes(dims_axes, sizes):
    mesh = FakeMesh(sizes)
    spec = ParamSpec(
        tuple(d for _, d in dims_axes), tuple(a for a, _ in dims_axes)
    )
    ps = partition.param_pspec(spec, mesh)
    assert len(ps) <= len(spec.shape)  # trailing Nones trimmed
    used = []
    for entry in ps:
        if entry is None:
            continue
        used.extend(entry if isinstance(entry, tuple) else (entry,))
    assert len(used) == len(set(used))
    if tuple(ps):
        assert ps[-1] is not None  # trimmed


@given(st.lists(st.tuples(axes, dims), min_size=1, max_size=5), mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_param_pspec_entries_divide_dims(dims_axes, sizes):
    mesh = FakeMesh(sizes)
    spec = ParamSpec(
        tuple(d for _, d in dims_axes), tuple(a for a, _ in dims_axes)
    )
    ps = partition.param_pspec(spec, mesh)
    for dim, entry in zip(spec.shape, tuple(ps)):
        if entry is None:
            continue
        group = entry if isinstance(entry, tuple) else (entry,)
        assert dim % _extent(mesh, group) == 0


# ---------------------------------------------------------------------------
# plain (non-hypothesis) regressions
# ---------------------------------------------------------------------------


def test_param_pspec_known_case():
    mesh = FakeMesh({"data": 4, "model": 2})
    spec = ParamSpec((64, 16, 7), ("embed", "heads", "head_dim"))
    ps = partition.param_pspec(spec, mesh)
    # single-axis groups enter as bare strings (P normalization on this jax
    # treats ("data",) and "data" as distinct specs)
    assert ps == P("data", "model")


def test_param_pspec_fallback_reported():
    mesh = FakeMesh({"data": 4, "model": 16})
    report = []
    spec = ParamSpec((40,), ("heads",))  # 40 heads on a 16-way model axis
    assert partition.param_pspec(spec, mesh, report) == P()
    assert report == [("heads", 40, ("model",), 16)]


def test_slot_pspec_divisibility():
    mesh = FakeMesh({"data": 4, "model": 2})
    assert partition.slot_pspec((8, 3), mesh) == P(("data",))
    assert partition.slot_pspec((6, 3), mesh) == P()  # 6 % 4 != 0
    assert partition.slot_pspec((), mesh) == P()
    assert partition.slot_pspec((8,), FakeMesh({"model": 2})) == P()
