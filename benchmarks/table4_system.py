"""Paper Table IV — system-level engine throughput across execution modes.

FPGA Watts/LUTs have no software analogue; the algorithmic content is
throughput of the full engine under each execution mode on the same model.
Measures end-to-end forward tokens/s (reduced olmo-1b on CPU) for
exact / carmen(FxP8) / int8, and derives GOPS = 2*N_active*tokens / time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP8, PrecisionPolicy
from repro.models import get_model

B, S = 8, 128


def run():
    cfg = reduced(get_config("olmo-1b"), layers=4, d_model=256)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_active = model.count_params() - cfg.vocab_size * cfg.d_model
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    rows = []
    for mode in ("exact", "carmen", "int8"):
        ctx = (
            EngineContext(mode="exact", compute_dtype=jnp.float32)
            if mode == "exact"
            else EngineContext(mode=mode, policy=PrecisionPolicy.accurate(FXP8),
                               compute_dtype=jnp.float32)
        )
        f = jax.jit(lambda p, t: model.forward(p, {"tokens": t}, ctx)[0])
        jax.block_until_ready(f(params, toks))
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(f(params, toks))
        dt = (time.perf_counter() - t0) / reps
        tok_s = B * S / dt
        gops = 2 * n_active * B * S / dt / 1e9
        rows.append((f"table4.forward_{mode}", dt * 1e6, f"tok/s={tok_s:.0f};GOPS={gops:.2f}"))
    return rows
