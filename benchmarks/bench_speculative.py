"""Self-speculative serving benchmark: acceptance, tokens/step, cycle cost.

The same greedy workload is served twice — all-accurate (the bank's
reference tree, classic one-token decode steps) and self-speculatively
(draft ``k`` tokens on the approximate execution point, verify all ``k+1`` in
one accurate multi-token forward) — per draft length. The record captures
the quantities the draft/verify split trades in:

* **acceptance_rate** / **mean_accepted_per_step** — how often the shallow
  CORDIC point agrees with the deep one;
* **tokens_per_step** — committed tokens per verify round (the latency
  leverage: one weight pass now yields several tokens);
* **est_cycle_savings_frac** — weight-pass cycles saved under the
  ``K*(depth+1)`` iterative-PE model, where a multi-token verify streams the
  resident weight bank once (see ``repro.spec.telemetry``);
* **sequence_agreement** — MUST be 1.0: greedy speculative output is
  bit-identical to accurate-only decoding by construction.

    PYTHONPATH=src python -m benchmarks.bench_speculative --arch olmo-1b \
        --draft-lens 2,4,6 --requests 6 --max-new 24

``--smoke`` shrinks the workload for CI and writes the same JSON shape to
``artifacts/bench/BENCH_speculative.json``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, FXP8, FXP16, PrecisionPolicy
from repro.runtime import build_bank, default_points
from repro.serve.engine import BatchedServer
from repro.spec import SpecConfig

from ._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    make_requests,
    timed,
)


def bench_accurate_only(model, cfg, bank, ctx, *, requests, slots,
                        prompt_len, max_new, max_len):
    """The baseline run, shared across the draft-length sweep (the cache's
    max_len does not affect generated tokens — rows past the write index are
    exactly masked)."""
    ref_server = BatchedServer(model, ctx, bank.tree(bank.reference),
                               slots=slots, max_len=max_len,
                               prepare_weights=False)
    ref_dt, ref_out = timed(lambda: ref_server.run(make_requests(
        cfg, requests, prompt_len=prompt_len, max_new=max_new)))
    return ref_out, ref_dt


def bench_draft_len(model, cfg, params, bank, ctx, k, ref_out, ref_dt, *,
                    requests, slots, prompt_len, max_new, max_len):
    spec_server = BatchedServer(model, ctx, params, slots=slots,
                                max_len=max_len, bank=bank,
                                speculate=SpecConfig(draft_len=k))
    obs = attach_observer(spec_server)
    spec_dt, spec_out = timed(lambda: spec_server.run(make_requests(
        cfg, requests, prompt_len=prompt_len, max_new=max_new)))
    tele = spec_server.spec_telemetry.summary()

    agree = float(np.mean([
        np.mean(np.array(spec_out[r]) == np.array(ref_out[r])) for r in ref_out
    ]))
    gen_toks = sum(len(v) for v in ref_out.values())
    return {
        "draft_len": k,
        "accurate_tok_s": round(gen_toks / max(ref_dt, 1e-9), 1),
        "speculative_tok_s": round(gen_toks / max(spec_dt, 1e-9), 1),
        "acceptance_rate": tele["acceptance_rate"],
        "mean_accepted_per_step": tele["mean_accepted_per_step"],
        "tokens_per_step": tele["tokens_per_step"],
        "est_cycle_savings_frac": tele["est_cycle_savings_frac"],
        "est_weight_pass_cycles": tele["est_weight_pass_cycles"],
        "accurate_only_cycles": tele["accurate_only_cycles"],
        "verify_rounds": tele["rounds"],
        "sequence_agreement": round(agree, 4),
        "latency": latency_block(obs),
    }


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_speculative.json")
    ap.add_argument("--mode", choices=["carmen", "int8", "kernel"], default="carmen")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--draft-lens", default="2,4,6",
                    help="comma-separated draft lengths to sweep")
    ap.add_argument("--fxp8", action="store_true",
                    help="FxP8 operand ladder (default FxP16)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.requests = 3
        args.slots = 2
        args.max_new = 12
        args.draft_lens = "3"

    cfg, model, params = load_model(args.arch, full_size=args.full_size)
    fmt = FXP8 if args.fxp8 else FXP16
    bank = build_bank(params, args.mode, default_points(fmt, hifi_fmt=None),
                      specs=model.specs())

    record = base_record(
        args,
        mode=args.mode,
        fmt=f"FXP{fmt.bits}",
        slots=args.slots,
        requests=args.requests,
        max_new=args.max_new,
        draft_point=bank.names[0],
        verify_point=bank.reference,
        rel_draft_cycles=round(bank.rel_cycles(bank.names[0]), 4),
        sweeps=[],
    )
    draft_lens = [int(x) for x in args.draft_lens.split(",")]
    ctx = EngineContext(mode=bank.mode, policy=PrecisionPolicy.accurate(fmt),
                        compute_dtype=jnp.float32)
    # one cache geometry for the whole sweep: the baseline is served once
    max_len = args.prompt_len + args.max_new + max(draft_lens) + 2
    ref_out, ref_dt = bench_accurate_only(
        model, cfg, bank, ctx, requests=args.requests, slots=args.slots,
        prompt_len=args.prompt_len, max_new=args.max_new, max_len=max_len,
    )
    for k in draft_lens:
        record["sweeps"].append(bench_draft_len(
            model, cfg, params, bank, ctx, k, ref_out, ref_dt,
            requests=args.requests, slots=args.slots,
            prompt_len=args.prompt_len, max_new=args.max_new, max_len=max_len,
        ))
    return emit_record(record, args.out)


if __name__ == "__main__":
    main()
