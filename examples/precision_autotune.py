"""The paper's §III workflow: accuracy-sensitivity-driven depth assignment.

1. Train a small model.
2. Run the sensitivity scan (JVP of the output w.r.t. per-layer LSB noise).
3. ``assign_depths`` demotes the least-sensitive layers to approximate mode
   until the cycle-reduction budget (~33%) is met; critical layers pinned.
4. Compare accuracy: all-accurate vs auto-assigned mixed policy vs
   all-approximate — the mixed policy should sit near the accurate one at
   ~2/3 the MAC cycles.

Run:  PYTHONPATH=src python examples/precision_autotune.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FXP8,
    FXP8_UNIT,
    LayerPrecision,
    PrecisionPolicy,
    approx_depth,
    assign_depths,
    carmen_matmul_fast,
    full_depth,
    mac_cycles,
    sensitivity_scan,
)
from repro.core.activations import af_ref
from repro.data.pipeline import ClusterPipeline

SIZES = (196, 64, 32, 32, 10)
ACT = "sigmoid"

# --- train in float ----------------------------------------------------------
pipe = ClusterPipeline(spread=2.25)
X, Y = pipe.dataset(10_000)
xtr, ytr, xte, yte = X[:8000], Y[:8000], X[8000:], Y[8000:]
rng = np.random.default_rng(0)
params = {
    f"l{i}": (
        jnp.asarray(rng.normal(0, np.sqrt(2 / a), (a, b)).astype(np.float32)),
        jnp.zeros(b, jnp.float32),
    )
    for i, (a, b) in enumerate(zip(SIZES[:-1], SIZES[1:]))
}


def fwd(ps, x, noise={}):
    h = x
    for i in range(len(SIZES) - 1):
        w, b = ps[f"l{i}"]
        h = h @ w + b
        h = h + noise.get(f"l{i}", 0.0) * jnp.ones_like(h)
        if i < len(SIZES) - 2:
            h = af_ref(h, ACT)
    return h


def loss_fn(ps, xb, yb):
    return -jnp.take_along_axis(jax.nn.log_softmax(fwd(ps, xb)), yb[:, None], 1).mean()


grad = jax.jit(jax.grad(loss_fn))
for s in range(2000):
    i = (s * 256) % 7744
    g = grad(params, jnp.asarray(xtr[i : i + 256]), jnp.asarray(ytr[i : i + 256]))
    params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)

# --- sensitivity scan --------------------------------------------------------
taps = [f"l{i}" for i in range(len(SIZES) - 1)]
sens = sensitivity_scan(
    lambda ps, batch, noise: fwd(ps, batch, noise), params, jnp.asarray(xte[:256]), taps, fmt=FXP8
)
print("accuracy sensitivity per layer (output perturbation per LSB of noise):")
for k, v in sorted(sens.items()):
    print(f"  {k}: {v:.4f}")

# 20% budget: less than the 33% max, so the scheduler must CHOOSE which
# layers stay accurate — the most-sensitive (output) layer is kept.
policy = assign_depths(sens, fmt=FXP8, cycle_reduction_target=0.20)
print("assigned depths:", {k: lp.depth for k, lp in policy.overrides.items()},
      "default:", policy.default.depth)


# --- evaluate policies -------------------------------------------------------
def fwd_carmen(ps, x, policy):
    h = jnp.asarray(x)
    total_cycles = 0
    for i in range(len(SIZES) - 1):
        w, b = ps[f"l{i}"]
        lp = policy.for_layer(f"l{i}")
        h = carmen_matmul_fast(h, w, lp.depth, FXP8, FXP8_UNIT) + b
        total_cycles += mac_cycles(w.shape[0], lp.depth) * w.shape[1]
        if i < len(SIZES) - 2:
            h = af_ref(h, ACT)  # AF cost negligible (2-5% of ops, paper §I)
    return np.asarray(h), total_cycles


acc = lambda lo: float((lo.argmax(-1) == yte).mean())
for name, pol in (
    ("all-accurate", PrecisionPolicy.accurate(FXP8)),
    ("auto-mixed", policy),
    ("all-approximate", PrecisionPolicy.approximate(FXP8)),
):
    logits, cycles = fwd_carmen(params, xte, pol)
    print(f"{name:16s}: acc {acc(logits):.4f}  MAC-cycles {cycles/1e6:.2f}M")
