"""int8 backend: real int8 x int8 -> int32 dot (production MXU path).

Per-call path: per-output-channel weight scales recomputed every call (the
seed behaviour — kept for calibration sweeps and as the parity oracle).

Prepared path: ``prepare`` quantizes the weight bank once — int8 qvalues with
per-channel scales, CORDIC depth pre-applied as trailing-bit zeroing — so the
serving forward only computes the dynamic per-token activation scale. This
absorbs what ``quant/qat.py`` used to do standalone (``quantize_params_int8``
and ``QuantizedLinear`` now delegate here).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import cordic
from .base import Backend, PreparedWeight

__all__ = ["Int8Backend", "effective_bits", "int8_dot", "quantize_weight"]


def effective_bits(lp) -> int:
    """CORDIC depth -> effective weight bits (the int8 incarnation of depth)."""
    return max(2, min(8, int(np.ceil(lp.depth * 8 / cordic.full_depth(lp.fmt)))))


def quantize_weight(w, *, per_channel: bool = True, stacked_axes: int = 0,
                    eff_bits: int = 8,
                    in_axes: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """One-time weight-bank quantization: int8 qvalues + float scales.

    ``per_channel`` reduces over the contraction axes (keepdims): the
    ``in_axes`` axes that fold into the matmul's input dim (default: all but
    the last axis). Leading ``stacked_axes`` axes (stacked layer banks
    consumed by ``lax.scan``) keep their extent so the scale slices alongside
    the qvalues. ``eff_bits < 8`` zeroes trailing bits of the grid — reduced
    CORDIC depth, baked in.
    """
    wf = jnp.asarray(w, jnp.float32)
    if in_axes is None:
        in_axes = wf.ndim - stacked_axes - 1
    axes = tuple(range(stacked_axes, stacked_axes + in_axes)) if per_channel else None
    amax = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    wq = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    if eff_bits < 8:
        drop = 8 - eff_bits
        wq = ((wq.astype(jnp.int32) >> drop) << drop).astype(jnp.int8)
    return wq, scale.astype(jnp.float32)


def int8_dot(x, w, *, effective_bits: int = 8, w_scale=None):
    """int8 x int8 -> int32 dot with per-output-channel weight scales.

    ``effective_bits < 8`` zeroes trailing bits of the weight grid — the int8
    incarnation of reduced CORDIC depth. ``w_scale`` may be precomputed
    (serving: weights stored quantized once).
    """
    xf = x.astype(jnp.float32)
    # per-token (per-row) dynamic activation scale — broadcasts over the N axis
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    x_scale = jnp.maximum(amax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(xf / x_scale), -127, 127).astype(jnp.int8)

    if w_scale is None:
        wf = w.astype(jnp.float32)
        w_scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=0, keepdims=True), 1e-8) / 127.0
        wq = jnp.clip(jnp.round(wf / w_scale), -127, 127).astype(jnp.int8)
    else:
        wq = w  # already int8
    if effective_bits < 8:
        drop = 8 - effective_bits
        wq = ((wq.astype(jnp.int32) >> drop) << drop).astype(jnp.int8)

    acc = jax.lax.dot_general(
        xq, wq, (((xq.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * x_scale * w_scale


class Int8Backend(Backend):
    name = "int8"

    def prepare(self, w, lp, *, stacked_axes: int = 0, in_axes: Optional[int] = None):
        eff = effective_bits(lp)
        wq, scale = quantize_weight(
            w, stacked_axes=stacked_axes, eff_bits=eff, in_axes=in_axes
        )
        # depth recorded for the runtime cycle model (repro.runtime.telemetry);
        # the arithmetic consumes only the pre-baked effective_bits grid
        return PreparedWeight(
            wq, scale, self.name,
            (("effective_bits", eff), ("depth", int(lp.depth))),
        )

    def dot(self, ctx, x, w, *, name: str = ""):
        if isinstance(w, PreparedWeight):
            # depth already baked into the stored grid — activation side only
            out = int8_dot(x, w.data, effective_bits=8, w_scale=w.scale)
        else:
            lp = ctx.layer_precision(name)
            out = int8_dot(x, w, effective_bits=effective_bits(lp))
        return out.astype(ctx.compute_dtype)
