"""Structured serving traces: versioned JSONL + Chrome-trace/Perfetto export.

A :class:`TraceRecorder` accumulates timestamped events during one serving
run. Events are recorded host-side at the engine's existing synchronization
points, so tracing never changes a compiled program or adds a device
round-trip; span durations therefore measure what the *host* observed —
dispatch plus any device wait the call already contained. (The draft/verify
spans inside a speculative round are dispatch-only: jax dispatch is async and
the round synchronizes once, at its single host transfer.)

Two exports from the same event list:

* **JSONL** (:meth:`TraceRecorder.write_jsonl` / :func:`read_trace`): the
  replayable serving-telemetry format. Line 1 is the header
  (``schema``/``version``, wall-clock anchor, run metadata, optional sharding
  report and collective-bytes snapshot); every following line is one event
  ``{"ts": seconds-since-run-start, "ph": "B"|"E"|"I", "name": ...,
  "track": ..., "args": {...}}``. This is the trace the ROADMAP's
  cycle-accurate PE-array simulator replays — treat field removals as a
  version bump.
* **Chrome trace** (:meth:`TraceRecorder.to_chrome`): the same events as a
  Chrome ``traceEvents`` JSON (load in Perfetto / ``chrome://tracing``).
  Tracks map to tids — one lane per serving slot plus ``engine`` (bursts,
  prefills, spec rounds), ``sched`` (admission), and ``run``.

B/E spans must nest per track; :meth:`end` enforces it at record time so an
exported trace is always well-formed, and :meth:`close_open` settles any
spans left open by an aborted run.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

__all__ = ["TRACE_SCHEMA", "TRACE_VERSION", "TraceRecorder", "iter_trace",
           "read_trace"]

TRACE_SCHEMA = "carmen-serve-trace"
TRACE_VERSION = 1


class TraceRecorder:
    """Append-only event recorder for one serving run.

    ``sink`` names a JSONL path the recorder can always flush to. Used as a
    context manager, a recorder with a sink is crash-safe: if the ``with``
    body raises, ``__exit__`` settles the open spans (:meth:`close_open`)
    and writes the JSONL tail anyway, so the trace of a crashed or aborted
    run is still complete, well-formed, and replayable by ``sim/replay.py``
    (``meta.aborted`` is set so the replay report names it). A normal exit
    flushes too — ``flush()`` is idempotent and explicit calls remain fine.
    """

    def __init__(self, clock=time.perf_counter,
                 sink: Optional[str] = None) -> None:
        self._clock = clock
        self._t0 = clock()
        self.sink = sink
        self.header: Dict = {
            "schema": TRACE_SCHEMA,
            "version": TRACE_VERSION,
            "t0_unix": time.time(),
            "meta": {},
        }
        self.events: List[Dict] = []
        self._open: Dict[str, List[str]] = {}  # track -> stack of open spans

    def now(self) -> float:
        """Seconds since recorder creation (the trace time base)."""
        return self._clock() - self._t0

    def at(self, clock_value: float) -> float:
        """Convert a raw reading of the recorder's clock into trace time —
        how streaming submit timestamps (stamped on the caller's thread)
        land on the same time base as every other event."""
        return clock_value - self._t0

    def attach(self, key: str, value) -> None:
        """Attach a header field (sharding report, collective bytes, ...)."""
        self.header[key] = value

    def _emit(self, ph: str, name: str, track: str, args: Dict,
              ts: Optional[float] = None) -> None:
        self.events.append({
            "ts": self.now() if ts is None else ts,
            "ph": ph,
            "name": name,
            "track": track,
            "args": args,
        })

    def instant(self, name: str, track: str = "engine", **args) -> None:
        self._emit("I", name, track, args)

    def begin(self, name: str, track: str = "engine", **args) -> None:
        self._open.setdefault(track, []).append(name)
        self._emit("B", name, track, args)

    def end(self, name: str, track: str = "engine", **args) -> None:
        stack = self._open.get(track, [])
        if not stack or stack[-1] != name:
            raise ValueError(
                f"trace span mismatch on track {track!r}: ending {name!r}, "
                f"open spans are {stack}"
            )
        stack.pop()
        self._emit("E", name, track, args)

    def close_open(self, **args) -> None:
        """End every open span (innermost first) — aborted-run cleanup, so
        exports are always nesting-consistent."""
        for track, stack in self._open.items():
            while stack:
                self._emit("E", stack.pop(), track, args)

    def flush(self, path: Optional[str] = None) -> Optional[str]:
        """Settle open spans and write the JSONL trace to ``path`` (default:
        the configured ``sink``). Returns the written path, or None when
        neither is set. Safe to call repeatedly — the exports rewrite."""
        target = path or self.sink
        if target is None:
            return None
        self.close_open()
        return self.write_jsonl(target)

    # -- context manager: flush-on-exception ----------------------------------

    def __enter__(self) -> "TraceRecorder":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.header.setdefault("meta", {})["aborted"] = True
        self.flush()

    # -- exports --------------------------------------------------------------

    def to_chrome(self) -> Dict:
        """Chrome trace-event JSON (open in Perfetto / chrome://tracing)."""
        tids: Dict[str, int] = {}
        out = []
        for ev in self.events:
            tid = tids.setdefault(ev["track"], len(tids))
            out.append({
                "name": ev["name"],
                "ph": {"B": "B", "E": "E", "I": "i"}[ev["ph"]],
                "ts": ev["ts"] * 1e6,  # chrome wants microseconds
                "pid": 1,
                "tid": tid,
                "cat": "serving",
                "args": ev["args"],
            })
        meta = [
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": track}}
            for track, tid in tids.items()
        ]
        return {
            "traceEvents": meta + out,
            "displayTimeUnit": "ms",
            "metadata": self.header,
        }

    def write_chrome(self, path: str) -> str:
        _ensure_dir(path)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    def write_jsonl(self, path: str) -> str:
        """The versioned replayable trace: header line, then one event/line."""
        _ensure_dir(path)
        with open(path, "w") as f:
            f.write(json.dumps(self.header) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)


def _checked_header(path: str, header: Dict) -> Dict:
    if header.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"{path}: not a {TRACE_SCHEMA} trace (schema={header.get('schema')!r})"
        )
    if header.get("version", 0) > TRACE_VERSION:
        raise ValueError(
            f"{path}: trace version {header['version']} is newer than this "
            f"reader ({TRACE_VERSION})"
        )
    return header


class TraceReader:
    """Streaming JSONL trace reader: header eagerly, events lazily.

    The header line is read and schema-checked at construction; iterating
    yields one validated event dict per JSONL line without ever holding the
    whole file — a multi-hundred-MB serving trace replays in O(1) memory.
    Single-pass: iterate once (the PE-array simulator's replay is a single
    forward sweep by design).
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self._f = open(path)
        first = self._f.readline()
        if not first.strip():
            self._f.close()
            raise ValueError(f"{path}: empty trace")
        self.header: Dict = _checked_header(path, json.loads(first))

    def __iter__(self):
        return self

    def __next__(self) -> Dict:
        for line in self._f:
            if not line.strip():
                continue
            ev = json.loads(line)
            if "ts" not in ev or "ph" not in ev or "name" not in ev:
                self._f.close()
                raise ValueError(f"{self.path}: malformed event {ev!r}")
            return ev
        self._f.close()
        raise StopIteration

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "TraceReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def iter_trace(path: str) -> TraceReader:
    """Open a JSONL trace for streaming replay.

    Returns a :class:`TraceReader`: ``reader.header`` is the schema-checked
    header (validated before the first event is touched, same checks as
    :func:`read_trace`), and iterating the reader yields events one line at a
    time. Use as an iterator or a context manager::

        with iter_trace(path) as tr:
            for ev in tr: ...
    """
    return TraceReader(path)


def read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """Load a JSONL trace fully: ``(header, events)``, schema-checked.

    Thin wrapper over :func:`iter_trace` that materializes the event list —
    convenient for tests and small traces; the simulator streams instead.
    """
    with iter_trace(path) as tr:
        return tr.header, list(tr)
