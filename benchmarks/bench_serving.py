"""Decode-burst serving benchmark: tokens/sec + host round-trips per burst size.

The decode hot loop's cost on small models is dominated by what happens
BETWEEN engine steps — Python dispatch, (B, 1) token transfers, numpy
bookkeeping — not by the steps themselves. This benchmark measures exactly
that: the same workload served at burst sizes {1, 4, 8, 16} (``burst=1`` is
the per-token loop the seed shipped), for a dense model, a MoE model, an MLA
latent-cache model, and the adaptive-controller machinery, plus one
speculative run. Each record carries tokens/sec, the server's counted host
round-trips, and a bit-identity flag against the burst=1 greedy output —
bursts are a pure scheduling change, so any token drift is a bug.

    PYTHONPATH=src python -m benchmarks.bench_serving --bursts 1,4,8,16

``--smoke`` shrinks the workload for CI, writes
``artifacts/bench/BENCH_serving.json``, and exits nonzero if burst=8 is
slower than burst=1 (``--min-speedup``) or any config loses bit-identity —
the CI gate that keeps the burst path honest.

The ``observability`` config serves the same workload on two identical
servers — one with a metrics-only :class:`repro.obs.ServingObserver`
attached, one without, interleaved best-of — and records the throughput
ratio plus the observer's SLO latency block (TTFT / inter-token / queue-wait
percentiles). With ``--smoke`` the run exits nonzero if the observed server
falls below ``--min-obs-ratio`` (default 0.95) of the plain one: the
"observability costs ≤5% tok/s" gate.

``--devices 1,2,4,8`` switches to the SHARDED sweep instead: one fresh
subprocess per host device count (XLA locks the device count at first init,
so it cannot vary in-process), each forcing
``--xla_force_host_platform_device_count=N``, serving the same greedy
workload on ``mesh=None`` and on ``make_host_mesh()`` (4x2 at N=8), and
recording tok/s for both, bit-identity between them, and the collective
bytes of the compiled decode burst (``launch.hlo_analysis``). The record
lands in ``BENCH_sharded.json``; with ``--smoke`` the run exits nonzero if
any row loses bit-identity or the 1-device mesh path falls below
``--min-mesh-ratio`` of the ``mesh=None`` throughput (the "sharding must be
free when it is a no-op" gate).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.serve.engine import BatchedServer, Request

from ._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    timed,
)

CONFIG_ARCHS = {
    "dense": "olmo-1b",
    "moe": "llama4-maverick-400b-a17b",
    "mla": "deepseek-v3-671b",
}


def _workload(cfg, n, *, max_new, seed=1):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, int(rng.integers(3, 9))).astype(np.int32),
                max_new)
        for i in range(n)
    ]


def _gen_tokens(out):
    return sum(len(v) for v in out.values())


def bench_bursts(make_server, cfg, bursts, *, requests, max_new, reps=3):
    """Sweep burst sizes over one server config; burst=1 is the reference.

    Reps are interleaved across burst sizes (A/B/A/B, best-of per burst) so
    machine-load drift hits every burst size equally instead of biasing
    whichever happened to run during a quiet stretch.
    """
    servers = {burst: make_server(burst) for burst in bursts}
    run = lambda srv: srv.run(_workload(cfg, requests, max_new=max_new))
    outs, best = {}, {b: float("inf") for b in bursts}
    for burst, srv in servers.items():  # warmup: compile + first dispatch
        outs[burst] = run(srv)
    for _ in range(reps):
        for burst, srv in servers.items():
            dt, outs[burst] = timed(lambda: run(srv), warmup=0)
            best[burst] = min(best[burst], dt)
    ref = outs[bursts[0]]
    rows = [{
        "burst": burst,
        "tok_s": round(_gen_tokens(outs[burst]) / max(best[burst], 1e-9), 1),
        "host_transfers": servers[burst].host_transfers,
        "bit_identical": outs[burst] == ref,
    } for burst in bursts]
    base = rows[0]["tok_s"]
    for row in rows:
        row["speedup"] = round(row["tok_s"] / max(base, 1e-9), 2)
    return rows


def _sharded_worker(args):
    """One device-count probe (run in a fresh process with XLA_FLAGS set):
    mesh=None vs make_host_mesh() on the same greedy workload."""
    import jax

    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    mesh = make_host_mesh()
    data_extent = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    # smallest multiple of the data extent >= requested slots, so the slot
    # state and cache batch dim actually shard (recorded per row)
    slots = -(-max(args.slots, 1) // data_extent) * data_extent
    max_len = 16 + args.max_new + args.draft_len
    cfg, model, params = load_model("olmo-1b", full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
    work = lambda: _workload(cfg, args.requests, max_new=args.max_new)

    none_srv = BatchedServer(model, ctx, params, slots=slots, max_len=max_len)
    mesh_srv = BatchedServer(model, ctx, params, slots=slots, max_len=max_len,
                             mesh=mesh)
    # warmup (compile) once each, then interleave best-of-3 so load drift
    # hits both paths equally — the mesh-ratio gate is a timing comparison
    t_none, out_none = timed(lambda: none_srv.run(work()))
    t_mesh, out_mesh = timed(lambda: mesh_srv.run(work()))
    for _ in range(2):
        t_none = min(t_none, timed(lambda: none_srv.run(work()), warmup=0)[0])
        t_mesh = min(t_mesh, timed(lambda: mesh_srv.run(work()), warmup=0)[0])

    # collective bytes of the compiled greedy decode burst on the mesh —
    # lowered under the server's scope so the analyzed program is the one
    # that executed (ambient mesh + the mesh-specific cache-write lowering)
    with mesh_srv._scope():
        hlo = (
            mesh_srv.decode_burst(False)
            .lower(mesh_srv._serving_tree(), mesh_srv.cache, mesh_srv._state)
            .compile()
            .as_text()
        )
    costs = hlo_analysis.analyze(hlo)
    row = {
        "devices": n,
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "slots": slots,
        "tok_s_none": round(_gen_tokens(out_none) / max(t_none, 1e-9), 1),
        "tok_s_mesh": round(_gen_tokens(out_mesh) / max(t_mesh, 1e-9), 1),
        "bit_identical": out_mesh == out_none,
        "collective_bytes": costs.collective_bytes,
        "collective_by_kind": costs.collective_by_kind,
    }
    row["mesh_ratio"] = round(row["tok_s_mesh"] / max(row["tok_s_none"], 1e-9), 2)
    print("::SHARDED::" + json.dumps(row))


def _sharded_sweep(args):
    """Fan the device-count sweep out to fresh subprocesses (the forced host
    device count is locked at first jax init) and gate on the results."""
    devices = [int(x) for x in args.devices.split(",")]
    passthrough = ["--_sharded-worker",
                   "--slots", str(args.slots),
                   "--requests", str(args.requests),
                   "--max-new", str(args.max_new),
                   "--d-model", str(args.d_model)]
    if args.full_size:
        passthrough.append("--full-size")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + env.get("XLA_FLAGS", "")
        ).strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = (
            os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
        )
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serving"] + passthrough,
            env=env, capture_output=True, text=True, cwd=repo,
        )
        payload = [l for l in proc.stdout.splitlines()
                   if l.startswith("::SHARDED::")]
        if proc.returncode != 0 or not payload:
            print(proc.stdout)
            print(proc.stderr, file=sys.stderr)
            raise SystemExit(f"sharded worker for {n} devices failed")
        rows.append(json.loads(payload[0][len("::SHARDED::"):]))

    one = next((r for r in rows if r["devices"] == 1), rows[0])
    base = one["tok_s_mesh"]
    for row in rows:
        row["scaling_vs_1dev"] = round(row["tok_s_mesh"] / max(base, 1e-9), 2)
    record = base_record(args, sweep="sharded", devices=devices, rows=rows)
    out = args.out
    if out and os.path.basename(out) == "BENCH_serving.json":
        out = os.path.join(os.path.dirname(out), "BENCH_sharded.json")
    emit_record(record, out)

    failures = []
    for row in rows:
        if not row["bit_identical"]:
            failures.append(f"{row['devices']} devices: mesh output drifted "
                            "from mesh=None")
    one = next((r for r in rows if r["devices"] == 1), None)
    if one is not None and one["mesh_ratio"] < args.min_mesh_ratio:
        failures.append(
            f"1-device mesh path at {one['mesh_ratio']}x of mesh=None "
            f"(< {args.min_mesh_ratio}x): sharding must be free when it is "
            "a no-op"
        )
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    return record


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_serving.json")
    ap.add_argument("--bursts", default="1,4,8,16",
                    help="comma-separated burst sizes (first is the reference)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--draft-len", type=int, default=3)
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced-model width (smoke shrinks it so the "
                         "per-token loop's dispatch overhead is visible)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="CI gate: burst=8 must reach this speedup over "
                         "burst=1 (checked when 1 and 8 are both swept)")
    ap.add_argument("--min-obs-ratio", type=float, default=0.95,
                    help="CI gate: an attached metrics observer must keep "
                         "this fraction of the plain server's tok/s")
    ap.add_argument("--devices", default=None,
                    help="comma-separated host device counts: run the "
                         "SHARDED sweep (mesh=None vs make_host_mesh per "
                         "count, fresh subprocess each) instead of the "
                         "burst sweep; writes BENCH_sharded.json")
    ap.add_argument("--min-mesh-ratio", type=float, default=0.85,
                    help="sharded-sweep CI gate: the 1-device mesh path "
                         "must reach this fraction of mesh=None tok/s")
    ap.add_argument("--_sharded-worker", action="store_true",
                    help="(internal) run one device-count probe in-process")
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.slots = 2
        args.requests = 8
        args.max_new = 32
        args.d_model = 64

    if getattr(args, "_sharded_worker"):
        return _sharded_worker(args)
    if args.devices:
        return _sharded_sweep(args)

    bursts = [int(x) for x in args.bursts.split(",")]
    max_len = 16 + args.max_new + args.draft_len
    record = base_record(args, slots=args.slots, requests=args.requests,
                         max_new=args.max_new, bursts=bursts, configs={})

    for name, arch in CONFIG_ARCHS.items():
        cfg, model, params = load_model(arch, full_size=args.full_size,
                                        d_model=args.d_model)
        ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
        make = lambda burst: BatchedServer(model, ctx, params, slots=args.slots,
                                           max_len=max_len, burst=burst)
        record["configs"][name] = {
            "arch": arch,
            "sweep": bench_bursts(make, cfg, bursts, requests=args.requests,
                                  max_new=args.max_new),
        }

    # adaptive machinery under bursts: pinned controller (bank tree per burst,
    # telemetry live) so the output stays comparable across burst sizes —
    # free-controller trajectories legitimately differ with observation
    # cadence and are bench_adaptive's subject
    from repro.runtime import ControllerConfig, ModeController, build_bank, default_points

    cfg, model, params = load_model("olmo-1b", full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    make = lambda burst: BatchedServer(
        model, ctx, params, slots=args.slots, max_len=max_len, burst=burst,
        controller=ModeController(bank, ControllerConfig(pin="accurate")),
    )
    record["configs"]["adaptive"] = {
        "arch": "olmo-1b", "pin": "accurate",
        "sweep": bench_bursts(make, cfg, bursts, requests=args.requests,
                              max_new=args.max_new),
    }

    # speculative serving (its round structure subsumes bursting; one run,
    # identity vs the accurate-only burst=1 output)
    from repro.spec import SpecConfig

    ref_server = BatchedServer(model, ctx, bank.tree(bank.reference),
                               slots=args.slots, max_len=max_len, burst=1,
                               prepare_weights=False)
    _, ref_out = timed(lambda: ref_server.run(
        _workload(cfg, args.requests, max_new=args.max_new)))
    spec_server = BatchedServer(model, ctx, params, slots=args.slots,
                                max_len=max_len, bank=bank,
                                speculate=SpecConfig(draft_len=args.draft_len))
    spec_obs = attach_observer(spec_server)
    dt, out = timed(lambda: spec_server.run(
        _workload(cfg, args.requests, max_new=args.max_new)))
    record["configs"]["speculative"] = {
        "arch": "olmo-1b", "draft_len": args.draft_len,
        "tok_s": round(_gen_tokens(out) / max(dt, 1e-9), 1),
        "host_transfers": spec_server.host_transfers,
        "bit_identical": out == ref_out,
        "acceptance_rate": spec_server.spec_telemetry.summary()["acceptance_rate"],
        "latency": latency_block(spec_obs),
    }

    # observability overhead: the same workload on two identical burst=8
    # servers, metrics-only observer on vs off, interleaved best-of (load
    # drift hits both equally). The observed server also supplies the
    # record's SLO latency block — percentiles, not just tok/s.
    plain = BatchedServer(model, ctx, params, slots=args.slots,
                          max_len=max_len, burst=8)
    watched = BatchedServer(model, ctx, params, slots=args.slots,
                            max_len=max_len, burst=8)
    obs = attach_observer(watched)
    work = lambda: _workload(cfg, args.requests, max_new=args.max_new)
    t_plain, out_plain = timed(lambda: plain.run(work()))
    t_obs, out_obs = timed(lambda: watched.run(work()))
    for _ in range(2):
        t_plain = min(t_plain, timed(lambda: plain.run(work()), warmup=0)[0])
        t_obs = min(t_obs, timed(lambda: watched.run(work()), warmup=0)[0])
    tok_plain = _gen_tokens(out_plain) / max(t_plain, 1e-9)
    tok_obs = _gen_tokens(out_obs) / max(t_obs, 1e-9)
    record["configs"]["observability"] = {
        "arch": "olmo-1b", "burst": 8,
        "tok_s_plain": round(tok_plain, 1),
        "tok_s_observed": round(tok_obs, 1),
        "obs_ratio": round(tok_obs / max(tok_plain, 1e-9), 3),
        "bit_identical": out_obs == out_plain,
        "latency": latency_block(obs),
    }

    emit_record(record, args.out)

    # CI gate: bursts must never lose tokens/sec or bit-identity, and
    # observability must stay (near-)free
    failures = []
    obs_rec = record["configs"]["observability"]
    if not obs_rec["bit_identical"]:
        failures.append("observability: token stream changed with an "
                        "observer attached")
    if obs_rec["obs_ratio"] < args.min_obs_ratio:
        failures.append(
            f"observability: observed server at {obs_rec['obs_ratio']}x of "
            f"plain tok/s (< {args.min_obs_ratio}x)"
        )
    for name, rec in record["configs"].items():
        if name == "observability":
            continue
        if "sweep" not in rec:
            if not rec["bit_identical"]:
                failures.append(f"{name}: speculative output drifted")
            continue
        by_burst = {row["burst"]: row for row in rec["sweep"]}
        for row in rec["sweep"]:
            if not row["bit_identical"]:
                failures.append(f"{name}: burst={row['burst']} output drifted")
        if 1 in by_burst and 8 in by_burst:
            speedup = by_burst[8]["tok_s"] / max(by_burst[1]["tok_s"], 1e-9)
            if speedup < args.min_speedup:
                failures.append(
                    f"{name}: burst=8 speedup {speedup:.2f}x < {args.min_speedup}x"
                )
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    return record


if __name__ == "__main__":
    main()
