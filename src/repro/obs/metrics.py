"""Lightweight serving metrics: counters, gauges, streaming histograms.

The registry is the host-side half of serving observability: every value it
holds is recorded at an existing host synchronization point (burst boundary,
prefill return, speculative-round commit), so attaching it to a server never
adds a device round-trip and never changes a jitted program.

Histograms are streaming: observations land in geometric buckets
(``growth``-spaced), so memory stays bounded at O(log(range)) while count,
sum, min, and max remain exact. Quantiles (p50/p90/p99) are read from the
bucket boundaries — the error is bounded by one bucket width (< ``growth``
relative), which is far below scheduling noise for latency telemetry.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

__all__ = ["Counter", "Gauge", "StreamingHistogram", "MetricsRegistry"]


class Counter:
    """A monotonically-increasing count (requests, tokens, transfers...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A last-write-wins scalar (run tok/s, acceptance rate...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class StreamingHistogram:
    """Geometric-bucket histogram with exact count/sum/min/max.

    Bucket ``i`` covers ``(floor * growth**(i-1), floor * growth**i]``;
    values at or below ``floor`` share bucket 0. One dict entry per occupied
    bucket — O(1) per observation, bounded memory, mergeable.
    """

    __slots__ = ("growth", "floor", "count", "total", "lo", "hi", "_buckets",
                 "_log_growth")

    def __init__(self, growth: float = 1.25, floor: float = 1e-7) -> None:
        if growth <= 1.0:
            raise ValueError("growth must be > 1")
        self.growth = growth
        self.floor = floor
        self._log_growth = math.log(growth)
        self.count = 0
        self.total = 0.0
        self.lo = math.inf
        self.hi = -math.inf
        self._buckets: Dict[int, int] = {}

    def _index(self, v: float) -> int:
        if v <= self.floor:
            return 0
        return max(0, math.ceil(math.log(v / self.floor) / self._log_growth))

    def observe(self, v: float, n: int = 1) -> None:
        """Record ``n`` observations of value ``v`` (``n > 1`` spreads one
        measured aggregate, e.g. a burst's per-token latency)."""
        v = float(v)
        self.count += n
        self.total += v * n
        self.lo = min(self.lo, v)
        self.hi = max(self.hi, v)
        i = self._index(v)
        self._buckets[i] = self._buckets.get(i, 0) + n

    def quantile(self, q: float) -> Optional[float]:
        """The q-quantile (0..1) from bucket boundaries, clamped to the exact
        observed [min, max]. None when empty."""
        if self.count == 0:
            return None
        rank = q * self.count
        seen = 0
        for i in sorted(self._buckets):
            seen += self._buckets[i]
            if seen >= rank:
                # geometric bucket midpoint; exact bounds clamp the tails
                mid = self.floor * self.growth ** max(i - 0.5, 0.0)
                return min(max(mid, self.lo), self.hi)
        return self.hi

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def summary(self) -> Dict:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.lo,
            "max": self.hi,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named counters / gauges / histograms with a JSON-able snapshot."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, StreamingHistogram] = {}

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> StreamingHistogram:
        return self.histograms.setdefault(name, StreamingHistogram())

    # conveniences for hook-site brevity
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float, n: int = 1) -> None:
        self.histogram(name).observe(v, n)

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()

    def snapshot(self) -> Dict:
        """One JSON-able dict: {"counters": ..., "gauges": ..., "histograms":
        {name: {count, mean, min, max, p50, p90, p99}}}."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self.histograms.items())
            },
        }
