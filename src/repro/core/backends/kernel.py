"""kernel backend: the Pallas CORDIC kernels (same math as carmen).

Prepared path: weights are signed-digit-rounded once (the PE weight memory
bank) and the execution point's dot parameters — CORDIC depth, activation and
weight quantization formats — ride in a small *traced* int32 ``point`` vector
on the :class:`PreparedWeight` (``make_point``).  The fused dot+AF kernel
(``kernels/cordic_fused``) consumes that vector as a scalar-prefetch operand,
so one compiled program serves every :class:`~repro.runtime.bank.ExecutionPoint`
and a ModeController switch swaps arrays, never programs.  When the Pallas
kernel is unavailable (mesh-sharded params, CPU under ``fused="auto"``,
oversized contraction dim) the bitwise-identical pure-XLA chain
(``cordic_fused.ref``) runs instead — the parity tests gate on exact equality.

The per-call path (raw float weights, static formats from the policy) still
runs the standalone ``cordic_mac`` kernel, as does the legacy prepared layout
that carried static formats in ``meta``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import cordic
from ..fxp import FxPFormat
from .base import Backend, PreparedWeight, unit_fmt

__all__ = ["KernelBackend"]


def _use_fused(ctx, k: int) -> bool:
    """Pallas kernel vs XLA fallback for the fused chain (values identical)."""
    from repro.kernels.cordic_fused.ops import _interpret_default, fuse_supported
    from repro.sharding.partition import current_mesh_axes

    fused = getattr(ctx, "fused", "auto")
    if fused == "off" or not fuse_supported(k) or current_mesh_axes():
        return False
    if fused == "on":
        return True
    return not _interpret_default()  # auto: native TPU only


class KernelBackend(Backend):
    name = "kernel"

    def prepare(self, w, lp, *, stacked_axes: int = 0, in_axes=None):
        from repro.kernels.cordic_fused import POINT_LEN, make_point

        fmt = unit_fmt(lp.fmt)
        data = cordic.signed_digit_round(w, int(lp.depth), fmt)
        point = make_point(int(lp.depth), lp.fmt, fmt)
        if stacked_axes:
            # stacked layer banks are consumed as lax.scan xs: give each
            # layer slice its own copy of the params vector
            point = jnp.broadcast_to(
                point, w.shape[:stacked_axes] + (POINT_LEN,)
            )
        # meta stays empty so every execution point shares one treedef
        return PreparedWeight(data, None, self.name, (), point)

    def _fused(self, ctx, x, w, af_mode: str, name: str):
        from repro.kernels.cordic_fused import fused_dot_af, fused_dot_af_ref

        lp_af = ctx.layer_precision("af")
        fn = fused_dot_af if _use_fused(ctx, x.shape[-1]) else fused_dot_af_ref
        out = fn(
            x, w.data, w.point,
            af_mode=af_mode,
            af_depth=int(lp_af.depth),
            af_fmt=lp_af.fmt,
            compute_round=ctx.compute_dtype != jnp.float32,
        )
        return out.astype(ctx.compute_dtype)

    def dot(self, ctx, x, w, *, name: str = ""):
        if isinstance(w, PreparedWeight) and w.point is not None:
            return self._fused(ctx, x, w, "identity", name)

        from repro.kernels.cordic_mac import ops as mac_ops

        x2 = x.reshape(-1, x.shape[-1])
        if isinstance(w, PreparedWeight):
            # legacy prepared leaf: static formats in meta
            bits, frac = w.get("fmt")
            x_fmt = w.get("x_fmt")
            x_fmt = (
                FxPFormat(*x_fmt) if x_fmt else ctx.layer_precision(name).fmt
            )
            out = mac_ops.cordic_mac(
                x2, w.data, depth=w.get("depth"), x_fmt=x_fmt,
                w_fmt=FxPFormat(bits, frac), w_prequantized=True,
            )
        else:
            lp = ctx.layer_precision(name)
            out = mac_ops.cordic_mac(
                x2, w, depth=int(lp.depth), x_fmt=lp.fmt, w_fmt=unit_fmt(lp.fmt)
            )
        return out.reshape(x.shape[:-1] + (w.shape[-1],)).astype(ctx.compute_dtype)

    def dot_af(self, ctx, x, w, *, af: str, name: str = ""):
        """Fused dot + activation epilogue; NotImplemented -> caller unfuses."""
        from repro.kernels.cordic_fused import FUSED_AFS

        if not (
            isinstance(w, PreparedWeight)
            and w.point is not None
            and af in FUSED_AFS
        ):
            return NotImplemented
        return self._fused(ctx, x, w, af, name)
