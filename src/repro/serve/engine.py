"""Serving engine: decode bursts, bucketed prefill, sampling, batched scheduler.

The scheduler implements continuous batching over a fixed slot count —
admit/evict at burst boundaries, per-slot positions — with four serving fast
paths on top:

* **prepared weight banks**: on construction the server runs
  ``prepare_params`` (quantize once), so carmen/int8/kernel decode performs
  zero weight-side rounding or scale computation per step;
* **device-resident decode bursts**: the decode hot loop is ONE jitted
  ``lax.scan`` over up to ``burst`` single-token steps. All per-slot state
  (pending token, generated count, remaining budget, PRNG key, temperature)
  lives on device in the burst carry; token ids and top-2 logit margins
  accumulate into ``(slots, burst)`` on-device buffers, so exactly one host
  round-trip happens per burst instead of per token. The KV cache and slot
  state are donated (``donate_argnums``), so XLA updates them in place
  rather than copying per call. ``burst=1`` is the classic per-token loop;
  larger bursts are bit-identical for greedy requests and stream-identical
  for sampled ones (per-request PRNG keys are folded by generated-token
  index, never by schedule);
* **bucketed prefill**: an admitted prompt is padded to a power-of-two
  length bucket and run through the model in one jitted call that also
  scatters the resulting KV rows into the slot cache and rewinds the write
  index to the true prompt length (the padded tail's rows are invisible
  behind the per-query-causal mask and reclaimed by decode) — prefill
  compiles O(log max_len) programs instead of one per distinct prompt
  length, and cache insertion is not an eager ``jax.tree.map`` anymore.
  Recurrent-state families (ssm/hybrid/audio) prefill through a jitted
  ``lax.scan`` over the padded prompt with masked state updates — same
  bucketing, no per-token host round-trip;
* **runtime-adaptive precision** (``repro.runtime``): pass a
  :class:`~repro.runtime.controller.ModeController` and each decode burst
  executes at the controller's current execution point — a different
  prepared tree from the multi-point weight bank, selected from per-burst
  aggregated telemetry (min top-2 margin over the burst, queue pressure,
  cycle budget) with zero weight-side work per switch and zero extra device
  syncs (the margins ride the burst's one transfer). ``self.telemetry``
  accumulates burst-aware mode occupancy, estimated MAC cycles, and switch
  counts;
* **self-speculative decoding** (``repro.spec``): pass
  ``speculate=SpecConfig(...)`` (plus a bank, or a controller that carries
  one) and the decode loop becomes draft-k-then-verify rounds: a jitted scan
  rolls the approximate execution point ``k`` tokens forward into the cache
  region past each slot's committed index, then ONE accurate multi-token
  forward verifies all ``k+1`` positions, accepts a draft prefix
  (greedy exact-match / rejection sampling), and rolls the cache back to the
  accepted length per slot. The round keeps the burst discipline: the cache
  is donated through draft and verify, and the emit buffers come back in a
  single host transfer. Greedy output is bit-identical to accurate-only
  serving; ``self.spec_telemetry`` records acceptance and weight-pass cycle
  savings.

* **observability** (``BatchedServer(observer=ServingObserver())``): per-
  request SLO latency metrics (time-to-first-token, inter-token latency,
  queue wait, prefill/decode wall time — streaming p50/p90/p99 histograms)
  and a structured event trace (admission, bursts with their execution
  point, controller switches, speculative draft/verify/rollback, compile
  events) with Chrome-trace and replayable JSONL exports. Every hook runs
  host-side at a sync point the loop already pays for, so the jitted
  programs are untouched and token streams are bit-identical with the
  observer on or off; ``snapshot()`` is the symmetric export of everything
  ``run()`` resets on entry.

* **sharded serving** (``BatchedServer(mesh=...)``): the same hot paths run
  tensor-parallel on a device mesh with no code fork. Every prepared weight
  leaf (including whole multi-point banks, alias-preserving) is placed with
  the logical-axis rules from ``sharding/partition.py``, the KV cache shards
  slots across the ``data`` axis and heads/latent across ``model`` (the S
  row axis is never split — decode's write index stays shard-local), the
  per-slot decode state shards slots across ``data``, and the burst/prefill
  jits carry explicit in/out shardings so the donated carry round-trips at a
  fixed placement. ``mesh=None`` (the default) skips every placement call —
  that path is byte-identical to single-device serving, and greedy token
  streams are bit-identical across mesh shapes
  (``tests/test_sharded_serving.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, prepare_params
from repro.models import ModelApi
from repro.sharding import partition

from .kvcache import bucket_length, scatter_rows, with_cache_positions

# families whose decode caches are pure attention/MLA KV rows (scatterable,
# index-rewindable); recurrent-state families prefill via the masked scan
_BATCHED_PREFILL_FAMILIES = ("dense", "vlm", "moe")


def make_decode_sample_step(model: ModelApi, ctx: EngineContext, *,
                            temperature: float = 0.0):
    """Decode + on-device sampling: only (B, 1) ids leave the device."""

    def decode_sample(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        return sample(logits, key, temperature=temperature), cache

    return decode_sample


def sample(logits, key, *, temperature: float = 0.0):
    """logits (B, 1, V) -> tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Serving steps: per-slot sampling + margin telemetry
# ---------------------------------------------------------------------------


def _sample_slots(last, base_keys, counts, temps):
    """Per-slot sampling: last (B, V) logits -> (B, 1) int32 tokens.

    ``base_keys`` (B, 2) per-request PRNG keys, ``counts`` (B,) per-request
    generated-token indices (folded in, so a request's stream is independent
    of batch composition, scheduling, AND burst size), ``temps`` (B,)
    temperatures — ``temp <= 0`` means greedy, bit-identical to plain argmax.
    """
    greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
    keys = jax.vmap(jax.random.fold_in)(base_keys, counts)
    scaled = last / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)[:, None]


def top2_margin(logits):
    """Top-2 logit margin along the last axis — the controller's confidence
    signal (shared with the speculative verify step)."""
    top2 = jax.lax.top_k(logits, 2)[0]
    return top2[..., 0] - top2[..., 1]


# ---------------------------------------------------------------------------
# Jitted hot paths: decode burst + bucketed prefill
# ---------------------------------------------------------------------------
#
# Per-slot serving state, device-resident between jitted calls:
#   tok   (slots, 1) int32   pending token (last generated)
#   count (slots,)   int32   generated-token index (PRNG fold position)
#   rem   (slots,)   int32   remaining token budget; 0 = slot inactive
#   key   (slots, 2) uint32  per-request PRNG base key
#   temp  (slots,)   float32 per-request temperature (<= 0: greedy)
#   fault (slots,)   bool    non-finite/saturated logits seen since admission


def _init_slot_state(slots: int):
    return {
        "tok": jnp.zeros((slots, 1), jnp.int32),
        "count": jnp.zeros((slots,), jnp.int32),
        "rem": jnp.zeros((slots,), jnp.int32),
        # distinct placeholder keys per slot; every admission overwrites the
        # slot's key inside the jitted prefill (the seed's identical
        # PRNGKey(0) stack relied on that overwrite happening eagerly)
        "key": jax.vmap(jax.random.PRNGKey)(jnp.arange(slots)),
        "temp": jnp.zeros((slots,), jnp.float32),
        "fault": jnp.zeros((slots,), jnp.bool_),
    }


def _admit_state(state, slot, tok, base_key, temp, max_new):
    """Write one admitted request's serving state into slot ``slot``."""
    return {
        "tok": state["tok"].at[slot].set(tok[0]),
        "count": state["count"].at[slot].set(1),  # prefill emitted token 0
        "rem": state["rem"].at[slot].set(max_new - 1),
        "key": state["key"].at[slot].set(base_key),
        "temp": state["temp"].at[slot].set(temp),
        "fault": state["fault"].at[slot].set(False),
    }


def make_decode_burst(model: ModelApi, ctx: EngineContext, burst: int,
                      sampled: bool = True,
                      logit_limit: Optional[float] = None):
    """The decode hot loop: ``burst`` single-token steps as one lax.scan.

    ``(tree, cache, state) -> (cache, state, tokens (B, burst), margins
    (B, burst), faults (B, burst))``. Tokens/margins accumulate on device;
    the caller performs ONE host transfer per burst and clips each slot's
    emitted run to its remaining budget (``state['rem']`` on entry — slots
    keep computing after their budget drains, their output is discarded and
    their rows are re-scattered at the next admission).

    ``faults`` is the per-slot numeric-fault flag, cumulative across the
    burst: step ``j`` is True iff some step ``<= j`` produced a non-finite
    logit (or, with ``logit_limit``, a logit beyond ``±logit_limit`` — the
    saturated-accumulator probe) in that slot's lane. The flag folds into
    the scan carry and persists in ``state['fault']``, so detection costs
    one ``isfinite``+reduce per step and ZERO extra host round-trips; the
    host finds the first faulted step as the count of leading False entries
    and commits only the clean prefix. Token math is untouched — with
    finite logits the emitted streams are bit-identical to a build without
    the flag.

    ``sampled=False`` compiles the all-greedy variant: no threefry fold /
    categorical per step (a real cost on small models), bit-identical to the
    sampled variant at ``temp <= 0``. The server picks per burst from the
    active requests' temperatures.
    """

    def decode_burst(tree, cache, state):
        keys, temps = state["key"], state["temp"]

        def step(carry, _):
            tok, cache, count, rem, fault = carry
            logits, cache = model.decode_step(tree, tok, cache, ctx)
            last = logits[:, -1, :].astype(jnp.float32)
            bad = ~jnp.all(jnp.isfinite(last), axis=-1)
            if logit_limit is not None:
                bad |= jnp.any(jnp.abs(last) > logit_limit, axis=-1)
            fault = fault | bad
            if sampled:
                nxt = _sample_slots(last, keys, count, temps)
                margin = top2_margin(last)
            else:
                # one top_k yields the greedy token AND the margin (top_k and
                # argmax share first-occurrence tie-breaking)
                top2, idx = jax.lax.top_k(last, 2)
                nxt = idx[:, :1].astype(jnp.int32)
                margin = top2[..., 0] - top2[..., 1]
            active = (rem > 0).astype(jnp.int32)
            return (nxt, cache, count + active, rem - active, fault), (
                nxt[:, 0], margin, fault,
            )

        (tok, cache, count, rem, fault), (toks, margins, faults) = jax.lax.scan(
            step, (state["tok"], cache, state["count"], state["rem"],
                   state["fault"]),
            None, length=burst,
        )
        state = dict(state, tok=tok, count=count, rem=rem, fault=fault)
        return (cache, state, jnp.moveaxis(toks, 0, 1),
                jnp.moveaxis(margins, 0, 1), jnp.moveaxis(faults, 0, 1))

    return decode_burst


def make_prefill_chunk(model: ModelApi, ctx: EngineContext):
    """One chunked-prefill step for attention/MLA families.

    ``(tree, row, last, tokens (1, Cb), start, clen) -> (row, last (1, V))``.
    ``row`` is the request's PRIVATE single-row cache with its write index at
    ``start`` (the prompt rows committed by earlier chunks); ``tokens`` is
    the next ``clen`` prompt rows padded to a pow2 bucket ``Cb``. The chunk
    runs ONE S=Cb decode forward — each query attends the committed rows
    plus its own chunk prefix under the per-query-causal mask, exactly the
    key set the monolithic prefill's single forward gives it — then the
    write index rewinds to ``start + clen`` so the padded tail is invisible
    scratch, reclaimed by the next chunk. ``last`` returns the logits at the
    chunk's final REAL row: once the prompt is exhausted this is the
    sampling input for token 0 (:func:`make_chunk_admit`).

    A prompt that fits one chunk runs the same program shape as monolithic
    prefill; split prompts agree to reduction-order ulps (token streams are
    asserted identical, the repo-wide cross-shape contract). Compiles once
    per chunk bucket: O(log chunk_budget) programs.
    """

    def chunk(tree, row, last, tokens, start, clen):
        logits, row = model.decode_step(tree, tokens, row, ctx)
        new_last = jax.lax.dynamic_slice_in_dim(logits, clen - 1, 1, axis=1)
        new_last = new_last[:, 0, :].astype(jnp.float32)
        row = with_cache_positions(row, (start + clen)[None])
        return row, new_last

    return chunk


def make_scan_chunk(model: ModelApi, ctx: EngineContext):
    """Chunked prefill for recurrent-state families: the masked-scan prefill
    over one chunk, with the (state, last-logits) carry crossing chunks.

    Same signature as :func:`make_prefill_chunk`; ``start`` is unused (mixer
    state carries no positional index) and steps past ``clen`` run but have
    their state update masked out, so chunk bucketing composes with
    recurrent state exactly as whole-prompt bucketing does.
    """

    def chunk(tree, row, last, tokens, start, clen):
        def step(carry, xs):
            row, last = carry
            tok_i, i = xs
            logits, new_row = model.decode_step(tree, tok_i[None, None], row, ctx)
            valid = i < clen
            row = jax.tree.map(lambda n, o: jnp.where(valid, n, o), new_row, row)
            last = jnp.where(valid, logits[:, -1, :].astype(jnp.float32), last)
            return (row, last), None

        (row, last), _ = jax.lax.scan(
            step, (row, last), (tokens[0], jnp.arange(tokens.shape[1]))
        )
        return row, last

    return chunk


def make_chunk_admit():
    """Finalize a chunked prefill: sample token 0 from the accumulated last
    logits, scatter the finished row cache into its slot, admit the slot
    state — the shared :func:`_finish_prefill` tail as its own jitted
    program. ``(cache, state, row, last, slot, base_key, temp, max_new) ->
    (tok (1, 1), margin (1,), cache, state)``."""

    def admit(cache, state, row, last, slot, base_key, temp, max_new):
        return _finish_prefill(cache, state, row, last, slot, base_key, temp,
                               max_new)

    return admit


def make_bucketed_prefill(model: ModelApi, ctx: EngineContext, max_len: int):
    """Whole-prompt prefill for attention/MLA families, scatter included.

    ``(tree, cache, state, tokens (1, Pb), plen, slot, base_key, temp,
    max_new) -> (tok (1, 1), margin (1,), cache, state)``. ``tokens`` is the
    prompt padded to a power-of-two bucket ``Pb`` (suffix padding, so MoE
    dispatch ranks of real tokens are untouched); the first sampled token
    comes from the logits at ``plen - 1`` and the fresh row cache is written
    into slot ``slot`` with its index rewound to ``plen`` — the padded
    tail's KV rows are invisible garbage, overwritten by decode.

    Compiles once per bucket shape: O(log max_len) programs total.
    """

    def prefill(tree, cache, state, tokens, plen, slot, base_key, temp, max_new):
        row = model.make_cache(1, max_len, dtype=jnp.float32)
        logits, row = model.decode_step(tree, tokens, row, ctx)
        last = jax.lax.dynamic_slice_in_dim(logits, plen - 1, 1, axis=1)
        last = last[:, 0, :].astype(jnp.float32)
        row = with_cache_positions(row, plen[None])
        return _finish_prefill(cache, state, row, last, slot, base_key, temp,
                               max_new)

    return prefill


def make_scan_prefill(model: ModelApi, ctx: EngineContext, max_len: int):
    """Prefill for recurrent-state families (ssm/hybrid/audio): one jitted
    ``lax.scan`` over the padded prompt instead of a per-token host loop.

    Steps past ``plen`` run but their state update is masked out
    (``jnp.where`` select on every cache leaf), so buckets compose with
    recurrent state too. Same signature and compile-count bound as
    :func:`make_bucketed_prefill`.
    """

    def prefill(tree, cache, state, tokens, plen, slot, base_key, temp, max_new):
        row0 = model.make_cache(1, max_len, dtype=jnp.float32)
        last0 = jnp.zeros((1, model.cfg.vocab_size), jnp.float32)

        def step(carry, xs):
            row, last = carry
            tok_i, i = xs
            logits, new_row = model.decode_step(tree, tok_i[None, None], row, ctx)
            valid = i < plen
            row = jax.tree.map(lambda n, o: jnp.where(valid, n, o), new_row, row)
            last = jnp.where(valid, logits[:, -1, :].astype(jnp.float32), last)
            return (row, last), None

        (row, last), _ = jax.lax.scan(
            step, (row0, last0), (tokens[0], jnp.arange(tokens.shape[1]))
        )
        return _finish_prefill(cache, state, row, last, slot, base_key, temp,
                               max_new)

    return prefill


def _finish_prefill(cache, state, row, last, slot, base_key, temp, max_new):
    """Shared prefill tail: sample token 0, scatter the row, admit the slot."""
    tok = _sample_slots(last, base_key[None, :], jnp.zeros((1,), jnp.int32),
                        temp[None])
    cache = scatter_rows(cache, row, slot)
    state = _admit_state(state, slot, tok, base_key, temp, max_new)
    return tok, top2_margin(last), cache, state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new: int
    temperature: float = 0.0      # <= 0: greedy
    seed: Optional[int] = None    # PRNG stream seed; defaults to rid
    # deadline in seconds from run entry; checked at the loop's existing host
    # sync points (burst boundaries), so expiry granularity is one burst.
    # None: no deadline (ResilienceConfig.default_deadline_s may fill it in)
    deadline_s: Optional[float] = None
    generated: Optional[List[int]] = None
    margins: Optional[List[float]] = None  # top-2 logit margin per generated token


def _checked_prompt(req: Request) -> np.ndarray:
    prompt = np.asarray(req.prompt, np.int32)
    if prompt.size == 0:
        raise ValueError(
            f"request {req.rid}: empty prompt — prompts must carry at least "
            "one token (seed with BOS)"
        )
    return prompt


@dataclasses.dataclass
class BatchedServer:
    """Continuous batching over ``slots`` concurrent sequences.

    ``burst`` is the decode granularity: one jitted scan of up to ``burst``
    single-token steps per host round-trip, with admission/eviction at burst
    boundaries. ``burst=1`` reproduces the per-token loop exactly; larger
    bursts produce identical per-request streams (greedy is bit-identical,
    sampled streams fold the PRNG by token index) while cutting Python
    dispatch and host transfers by the burst factor. ``host_transfers``
    counts device->host round-trips for the run.

    ``prepare_weights=True`` (default) formats the weight bank once through
    the engine's backend registry; pass False to benchmark the per-call path.

    ``controller`` switches the server to runtime-adaptive precision: each
    burst executes at the controller's current execution point (a tree from
    its multi-point weight bank), the controller observes the burst's
    aggregated margins / queue pressure, and ``self.telemetry`` accumulates
    occupancy, switch counts, and estimated MAC-cycle savings. ``params``
    may stay the raw float tree in that case — the bank carries all serving
    weights.

    ``speculate`` (a :class:`repro.spec.SpecConfig`) switches the decode loop
    to self-speculative rounds served from a multi-point ``bank`` (defaulting
    to ``controller.bank``): draft ``draft_len`` tokens at the draft point,
    verify all of them plus a bonus position in one accurate multi-token
    forward, commit the accepted prefix, roll the KV cache back. Requires a
    scatterable (attention/MLA) cache family — recurrent state cannot roll
    back. With a controller attached, the controller picks the draft point
    per round; ``self.telemetry``'s cycle fields then describe draft-point
    occupancy only, and ``self.spec_telemetry`` is the cycle-accounting
    authority.

    ``resilience`` (a :class:`repro.resilience.ResilienceConfig`) switches
    the server from fail-stop to shed/quarantine/degrade: oversized or empty
    prompts and queue overflow are *shed* with structured reasons instead of
    raising, per-request deadlines are enforced at burst boundaries, and
    slots whose logits go non-finite are quarantined and evicted before
    their state can corrupt a neighbor (the detection flag rides the burst
    carry — zero extra host round-trips). Every request then ends in exactly
    one ``self.outcomes[rid]`` :class:`~repro.resilience.RequestOutcome`;
    ``run()`` still returns rid -> tokens (partial for expired/faulted, shed
    requests excluded). ``resilience=None`` (default) keeps the legacy
    contract byte-identical. ``injector`` (a
    :class:`~repro.resilience.FaultInjector`) fires deterministic faults at
    chosen decode rounds — test/benchmark instrumentation, never wired in
    production.

    ``mesh`` serves tensor-parallel on a device mesh (axes from
    ``data``/``model``/``pod``): weights, KV cache, and slot state are placed
    once at construction with the logical-axis sharding rules and the jitted
    hot paths carry explicit in/out shardings. ``mesh=None`` keeps the
    single-device path byte-identical (no placement calls at all);
    ``self.shardings`` holds the :class:`~repro.sharding.partition.\
ServingShardings` bundle (``partition.serving_sharding_report`` summarizes
    it) when a mesh is attached.
    """

    model: ModelApi
    ctx: EngineContext
    params: object
    slots: int = 4
    max_len: int = 256
    burst: int = 8
    prepare_weights: bool = True
    controller: Optional[object] = None  # repro.runtime.ModeController
    speculate: Optional[object] = None   # repro.spec.SpecConfig
    bank: Optional[object] = None        # repro.runtime.MultiPointBank
    mesh: Optional[object] = None        # jax.sharding.Mesh
    observer: Optional[object] = None    # repro.obs.ServingObserver
    resilience: Optional[object] = None  # repro.resilience.ResilienceConfig
    injector: Optional[object] = None    # repro.resilience.FaultInjector

    def __post_init__(self):
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        self._bank = self.bank
        if self._bank is None and self.controller is not None:
            self._bank = self.controller.bank
        if self.controller is not None:
            from repro.runtime import TelemetryRecorder

            self.telemetry = TelemetryRecorder.for_bank(self.controller.bank)
        else:
            self.telemetry = None
            if self.prepare_weights and self.speculate is None:
                self.params = prepare_params(
                    self.params, self.ctx.policy, self.ctx.mode, specs=self.model.specs()
                )
        self.batched_prefill = self.model.cfg.family in _BATCHED_PREFILL_FAMILIES
        self.spec = None
        self.spec_telemetry = None
        if self.speculate is not None:
            if self._bank is None:
                raise ValueError(
                    "speculate= needs a multi-point weight bank: pass bank= "
                    "or a controller that carries one"
                )
            if not self.batched_prefill:
                raise ValueError(
                    f"speculative serving needs a scatterable KV cache; the "
                    f"{self.model.cfg.family!r} family carries recurrent "
                    "state that cannot roll back past rejected drafts"
                )
        self.cache = self.model.make_cache(self.slots, self.max_len, dtype=jnp.float32)
        self.active: Dict[int, Request] = {}
        self._state = _init_slot_state(self.slots)
        self._slot_start = np.zeros((self.slots,), np.int32)  # committed KV rows
        self.host_transfers = 0
        self._run_complete: Optional[bool] = None  # None: never ran
        self._seen_buckets = set()  # prefill shapes already compiled
        # resilience accounting (per run, reset in _begin_run)
        self.outcomes: Dict[int, object] = {}  # rid -> RequestOutcome
        self._round_idx = 0
        self._t0 = 0.0
        self._fault_counts = {"shed": 0, "expired": 0, "faulted": 0,
                              "deadline_misses": 0}
        self._deadlines: Dict[int, Optional[float]] = {}
        self._chunk_fns = None       # (chunk, admit) jits, frontend-only
        self._frontend_meta = None   # set by the streaming frontend
        # mesh serving: derive every placement once from the logical-axis
        # rules and commit weights / cache / slot state to the mesh. With
        # mesh=None nothing below runs — that path stays byte-identical.
        self.shardings = None
        if self.mesh is not None:
            specs = self.model.specs()
            sample_tree = (self._bank.tree(self._bank.names[0])
                           if self._bank is not None else self.params)
            self.shardings = partition.serving_shardings(
                self.mesh, params=sample_tree, cache=self.cache,
                state=self._state, specs=specs, cfg=self.model.cfg,
                max_len=self.max_len,
            )
            if self._bank is not None:
                from repro.runtime.bank import place_bank

                place_bank(self._bank, self.mesh, specs)
            else:
                self.params = jax.device_put(self.params, self.shardings.params)
            self.cache = jax.device_put(self.cache, self.shardings.cache)
            self._state = jax.device_put(self._state, self.shardings.state)
        if self.speculate is not None:
            from repro.spec import SpeculativeDecoder

            self.spec = SpeculativeDecoder(
                self.model, self.ctx, self._bank, self.speculate,
                shardings=self.shardings,
            )
            self.spec_telemetry = self.spec.telemetry
        # the two jitted hot paths: cache + slot state are donated so XLA
        # writes them in place instead of copying the KV buffers per call.
        # Burst variants (sampled / all-greedy) compile lazily on first use.
        self._burst_fns = {}
        prefill_factory = (
            make_bucketed_prefill if self.batched_prefill else make_scan_prefill
        )
        prefill_sharding_kwargs = {}
        if self.shardings is not None:
            sh, r = self.shardings, self.shardings.replicated
            prefill_sharding_kwargs = dict(
                # (tree, cache, state, tokens, plen, slot, key, temp, max_new);
                # the tree inherits its committed placement: carmen/int8 bank
                # points carry distinct pytree aux data (one shardings tree
                # cannot describe them all), and kernel-mode points — which DO
                # share a treedef via the traced params vector — are already
                # placed by place_bank. cache/state are pinned so the donated
                # carry round-trips at a fixed placement
                in_shardings=(None, sh.cache, sh.state, r, r, r, r, r, r),
                out_shardings=(r, r, sh.cache, sh.state),
            )
        self.prefill = jax.jit(
            prefill_factory(self.model, self.ctx, self.max_len),
            donate_argnums=(1, 2),
            **prefill_sharding_kwargs,
        )

    def _serving_tree(self):
        """The tree prefill / non-speculative decode executes at.

        Speculative serving prefills at the VERIFY point so the committed
        prompt KV is accurate — the bit-exactness guarantee starts there.
        """
        if self.spec is not None:
            return self._bank.tree(self.spec.verify_point)
        return self.controller.tree() if self.controller is not None else self.params

    def _prefill_slot(self, slot: int, req: Request):
        """Run the prompt into this slot's cache; sets ``req.generated``.

        One jitted call: the prompt (padded to its length bucket) prefills a
        FRESH single-row cache, the row is scattered into the slot, and the
        slot's serving state is admitted — prefilling never touches other
        active slots' state, and only the first token + margin cross back to
        the host.
        """
        prompt = _checked_prompt(req)
        tree = self._serving_tree()
        seed = req.seed if req.seed is not None else req.rid
        bucket = bucket_length(len(prompt), self.max_len)
        obs, point_name = self.observer, self._serving_point()
        if obs is not None:
            if bucket not in self._seen_buckets:
                obs.compile_event("prefill", bucket=bucket)
            obs.prefill_begin(req.rid, bucket, point_name)
        self._seen_buckets.add(bucket)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, : len(prompt)] = prompt
        with self._scope():
            tok, margin, self.cache, self._state = self.prefill(
                tree, self.cache, self._state, jnp.asarray(padded),
                jnp.int32(len(prompt)), jnp.int32(slot),
                jax.random.PRNGKey(seed), jnp.float32(req.temperature),
                jnp.int32(req.max_new),
            )
        tok, margin = jax.device_get((tok, margin))
        self.host_transfers += 1
        self._slot_start[slot] = len(prompt)
        req.generated = [int(tok[0, 0])]
        req.margins = [float(margin[0])]
        if obs is not None:
            obs.prefill_end(req.rid, len(prompt), point_name)
        if self.telemetry is not None:
            self.telemetry.record_prefill(point_name, len(prompt))

    def _serving_point(self) -> Optional[str]:
        """Name of the execution point prefill / static decode runs at
        (None when serving a plain prepared tree, no bank)."""
        if self.spec is not None:
            return self.spec.verify_point
        return self.controller.point if self.controller is not None else None

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> generated tokens.

        Per-token top-2 margins land on each request's ``.margins``; with a
        controller attached, ``self.telemetry`` holds the adaptive-run record.
        ``run`` is reusable: telemetry, controller state, speculative
        counters, observer state, the transfer count, AND any slots stranded
        by an aborted prior run all start fresh on every invocation
        (``_begin_run``); ``snapshot()`` exports exactly the state one run
        accumulated, whether it completed or died mid-flight.

        With ``resilience`` attached the fail-stop contract becomes
        shed/quarantine/degrade: invalid or overflowing requests are shed
        with structured reasons instead of raising, deadlines evict at burst
        boundaries, faulted slots are quarantined, and every request ends in
        exactly one ``self.outcomes[rid]``. The returned dict then carries
        partial streams for expired/faulted requests and omits shed ones.
        """
        res = self.resilience
        shed_pre: List[Tuple[Request, str]] = []
        admitted: List[Request] = []
        # deadlines resolve into RUN-LOCAL state, never onto the caller's
        # Request objects: a list reused across servers (or runs) must not
        # carry one run's resolved default_deadline_s into the next
        deadlines = {req.rid: self._resolve_deadline(req) for req in requests}
        for req in requests:  # reject/shed before any state mutates
            reason = self._admission_error(req)
            if reason is not None:
                shed_pre.append((req, reason))
                continue
            admitted.append(req)
        if res is not None and res.queue_limit is not None:
            from repro.resilience.outcome import shed_overflow

            admitted, dropped = shed_overflow(
                admitted, res.queue_limit, res.shed_policy,
                deadline_of=lambda r: deadlines[r.rid],
            )
            shed_pre.extend((r, "queue_full") for r in dropped)
        self._begin_run(requests)
        self._deadlines = deadlines
        obs = self.observer
        for req, reason in shed_pre:
            self._shed(req, reason)
        aborted = True
        try:
            queue = list(admitted)
            results: Dict[int, List[int]] = {}
            slot_of: Dict[int, int] = {}
            free = list(range(self.slots))
            shed_since = len(shed_pre)  # sheds since the last controller observe
            while queue or self.active:
                if res is not None:  # shed queued work that can no longer win
                    queue, n_shed = self._expire_queue(queue)
                    shed_since += n_shed
                while queue and free:
                    req = queue.pop(0)
                    slot = free.pop(0)
                    if obs is not None:
                        obs.request_admitted(req.rid, slot)
                    self._prefill_slot(slot, req)
                    self._after_prefill(slot, req, results, slot_of, free)
                if not self.active:
                    continue
                queue_depth, free_slots = len(queue), len(free)
                if self.spec is not None:
                    summary = self._spec_round(slot_of)
                else:
                    summary = self._burst_round(slot_of)
                misses = self._settle_round(summary, results, slot_of, free)
                if self.controller is not None:
                    self._observe(summary["point"], summary["emitted"],
                                  summary["steps"], queue_depth, free_slots,
                                  summary["min_margin"],
                                  deadline_misses=misses, shed=shed_since)
                    shed_since = 0
            aborted = False
        finally:
            self._end_run(aborted)
        return results

    # -- per-round bookkeeping (shared by run() and the streaming frontend) ---

    def _after_prefill(self, slot: int, req: Request, results: Dict,
                       slot_of: Dict[int, int], free: List[int]) -> None:
        """Post-prefill triage: quarantine a non-finite prefill, retire a
        request whose budget the prefill token already satisfied, otherwise
        activate the slot."""
        res = self.resilience
        if (res is not None and res.fault_isolation
                and not math.isfinite(req.margins[0])):
            # non-finite prefill logits: the sampled token is garbage —
            # quarantine before anything is committed (the slot's rows are
            # reclaimed by the next scatter)
            req.generated, req.margins = [], []
            results[req.rid] = req.generated
            self._finish(req, "faulted", reason="prefill_nonfinite")
            free.append(slot)
            return
        if len(req.generated) >= req.max_new:  # prefill already done
            results[req.rid] = req.generated
            self._finish(req, "ok")
            free.append(slot)
            return
        self.active[req.rid] = req
        slot_of[req.rid] = slot

    def _settle_round(self, summary: Dict, results: Dict,
                      slot_of: Dict[int, int], free: List[int]) -> int:
        """After one burst/spec round: quarantine faulted lanes, evict
        deadline misses, retire finished requests. Returns the number of
        deadline misses (the controller signal)."""
        res = self.resilience
        for rid in summary["faulted"]:  # quarantine at the boundary
            req = self.active.pop(rid)
            results[rid] = req.generated
            self._finish(req, "faulted", reason=summary["fault_reason"])
            free.append(slot_of.pop(rid))
        misses = 0
        if res is not None:
            now = time.perf_counter() - self._t0
            for rid, req in list(self.active.items()):
                d = self._deadline(req)
                if d is not None and now >= d:
                    self.active.pop(rid)
                    results[rid] = req.generated
                    self._finish(req, "expired", reason="deadline")
                    free.append(slot_of.pop(rid))
                    misses += 1
        done = [r for r, q in self.active.items()
                if len(q.generated) >= q.max_new]
        for rid in done:
            req = self.active.pop(rid)
            results[rid] = req.generated
            self._finish(req, "ok")
            free.append(slot_of.pop(rid))
        return misses

    # -- admission: validation + run-local deadline resolution ----------------

    def _admission_error(self, req: Request) -> Optional[str]:
        """Validate one request at admission. Resilient servers get a
        structured shed reason (or None when admissible); the legacy
        ``resilience=None`` contract raises instead (byte-identical to the
        original fail-stop path). Shared by ``run()`` and the streaming
        frontend's ``submit``."""
        scratch = self.spec.draft_len if self.spec is not None else 0
        prompt = np.asarray(req.prompt, np.int32)
        too_long = len(prompt) + req.max_new + scratch > self.max_len
        if self.resilience is None:  # legacy fail-stop contract
            _checked_prompt(req)
            if too_long:
                extra = (f" + draft_len ({scratch})"
                         if self.spec is not None else "")
                why = (" — the verify forward needs draft_len rows of "
                       "scratch headroom" if self.spec is not None else
                       " — the KV cache would overflow mid-decode")
                raise ValueError(
                    f"request {req.rid}: prompt ({len(prompt)}) + max_new "
                    f"({req.max_new}){extra} exceeds max_len "
                    f"({self.max_len}){why}"
                )
            return None
        if prompt.size == 0:
            return "empty_prompt"
        if too_long:
            return "too_long"
        return None

    def _resolve_deadline(self, req: Request) -> Optional[float]:
        """The deadline this run enforces for ``req`` — its own, else the
        resilience default. Pure: the Request is never written."""
        if req.deadline_s is not None:
            return req.deadline_s
        res = self.resilience
        return res.default_deadline_s if res is not None else None

    def _deadline(self, req: Request) -> Optional[float]:
        """Run-local resolved deadline (run-relative seconds); falls back to
        the request's own field for rids this run never registered."""
        return self._deadlines.get(req.rid, req.deadline_s)

    # -- resilience: outcome bookkeeping --------------------------------------

    def _finish(self, req: Request, status: str,
                reason: Optional[str] = None) -> None:
        """Record the terminal outcome of an admitted request."""
        from repro.resilience.outcome import RequestOutcome

        tokens = len(req.generated or [])
        self.outcomes[req.rid] = RequestOutcome(
            rid=req.rid, status=status, reason=reason, tokens=tokens,
            deadline_s=self._deadline(req),
            wall_s=time.perf_counter() - self._t0,
        )
        obs = self.observer
        if status == "ok":
            if obs is not None:
                obs.request_completed(req.rid)
        elif status == "expired":
            self._fault_counts["expired"] += 1
            self._fault_counts["deadline_misses"] += 1
            if obs is not None:
                obs.request_expired(req.rid, tokens)
        elif status == "aborted":
            # streaming-frontend cancellation (client disconnect); the batch
            # run() path never produces this status itself
            self._fault_counts["aborted"] = (
                self._fault_counts.get("aborted", 0) + 1)
            if obs is not None:
                obs.request_cancelled(req.rid, tokens)
        else:
            self._fault_counts["faulted"] += 1
            if obs is not None:
                obs.request_faulted(req.rid, tokens, reason)

    def _shed(self, req: Request, reason: str) -> None:
        """Record a rejected-at-admission request (never held a slot)."""
        from repro.resilience.outcome import RequestOutcome

        self.outcomes[req.rid] = RequestOutcome(
            rid=req.rid, status="shed", reason=reason, tokens=0,
            deadline_s=self._deadline(req),
            wall_s=time.perf_counter() - self._t0,
        )
        self._fault_counts["shed"] += 1
        if self.observer is not None:
            self.observer.request_shed(req.rid, reason)

    def _expire_queue(self, queue: List[Request]):
        """Shed queued requests whose deadline already passed — admitting
        them would burn prefill on work that cannot finish in time."""
        now = time.perf_counter() - self._t0
        kept, n_shed = [], 0
        for req in queue:
            d = self._deadline(req)
            if d is not None and now >= d:
                self._shed(req, "deadline_expired")
                n_shed += 1
            else:
                kept.append(req)
        return kept, n_shed

    # -- run lifecycle: symmetric reset / export ------------------------------

    def _begin_run(self, requests: List[Request]) -> None:
        """Reset every per-run accumulator ``snapshot()`` exports.

        Slots stranded by an aborted prior run are dropped here (their device
        rows are reclaimed by the next admission's scatter), so a failed run
        can never leak tokens, telemetry, or transfer counts into the next
        run's results or exported snapshots.
        """
        self.active.clear()
        self.outcomes = {}
        self._round_idx = 0
        self._t0 = time.perf_counter()
        self._fault_counts = {"shed": 0, "expired": 0, "faulted": 0,
                              "deadline_misses": 0}
        self._deadlines = {}  # rid -> resolved run-relative deadline
        self._run_requests = list(requests)
        if self.telemetry is not None:
            self.telemetry.reset()
        if self.controller is not None:
            self.controller.reset()
            self.controller.on_switch = (
                self.observer.controller_switch
                if self.observer is not None else None
            )
        if self.spec is not None:
            self.spec.reset()
            self.spec.observer = self.observer
        self.host_transfers = 0
        self._run_complete = False
        if self.observer is not None:
            self.observer.run_begin(self._run_meta(), requests)

    def _end_run(self, aborted: bool) -> None:
        self._run_complete = not aborted
        if aborted:
            # every request the run touched but never resolved gets an
            # ``aborted`` outcome (with its partial token count), so a run
            # that died mid-flight is still fully attributable from
            # ``snapshot()``
            from repro.resilience.outcome import RequestOutcome

            wall = time.perf_counter() - self._t0
            for req in getattr(self, "_run_requests", []):
                if req.rid not in self.outcomes:
                    self.outcomes[req.rid] = RequestOutcome(
                        rid=req.rid, status="aborted",
                        tokens=len(req.generated or []),
                        deadline_s=self._deadline(req), wall_s=wall,
                    )
        if self.observer is not None:
            self.observer.run_end(aborted, self.host_transfers,
                                  self._telemetry_records())

    def _run_meta(self) -> Dict:
        """The trace-header metadata for one run (sharding report included
        under a mesh)."""
        meta = {
            "family": self.model.cfg.family,
            "mode": self.ctx.mode,
            "slots": self.slots,
            "burst": self.burst,
            "max_len": self.max_len,
            "adaptive": self.controller is not None,
            "speculative": self.spec is not None,
        }
        if self.spec is not None:
            meta["draft_len"] = self.spec.draft_len
            meta["verify_point"] = self.spec.verify_point
        if self.resilience is not None:
            meta["resilience"] = {
                "queue_limit": self.resilience.queue_limit,
                "shed_policy": self.resilience.shed_policy,
                "fault_isolation": self.resilience.fault_isolation,
                "default_deadline_s": self.resilience.default_deadline_s,
            }
        if self._frontend_meta is not None:
            meta["frontend"] = dict(self._frontend_meta)
        if self.shardings is not None:
            meta["sharding"] = partition.serving_sharding_report(self.shardings)
        engine = self._engine_cost_meta()
        if engine is not None:
            meta["engine"] = engine
        return meta

    def _engine_cost_meta(self) -> Optional[Dict]:
        """The trace header's ``engine`` block: per-point cycle estimates plus
        the per-weight (shape, depth, bits) table — everything the PE-array
        simulator needs to replay this trace without reconstructing the
        model. ``None`` for exact-mode serving (no precision knob, nothing to
        attribute cycles to). Computed once per server (the bank and policy
        are fixed at construction)."""
        if not hasattr(self, "_engine_meta_cache"):
            from repro.runtime.telemetry import (estimate_point_cycles,
                                                 layer_cost_table)

            specs = self.model.specs()
            if self._bank is not None:
                bank = self._bank
                policies = {p.name: p.policy for p in bank.points}
                self._engine_meta_cache = {
                    "points": {n: bank.cycles_per_token[n] for n in bank.names},
                    "reference": bank.reference,
                    "cycle_model": getattr(bank, "cycle_model", "analytic"),
                    "layers": layer_cost_table(bank.tree(bank.reference),
                                               policies, specs=specs),
                }
            elif self.ctx.mode != "exact" and self.ctx.policy is not None:
                # static prepared serving: a single-point "bank"
                self._engine_meta_cache = {
                    "points": {"static": estimate_point_cycles(
                        self.params, self.ctx.policy, specs=specs)},
                    "reference": "static",
                    "cycle_model": "analytic",
                    "layers": layer_cost_table(
                        self.params, {"static": self.ctx.policy}, specs=specs),
                }
            else:
                self._engine_meta_cache = None
        return self._engine_meta_cache

    def _telemetry_records(self) -> List[Dict]:
        """The unified telemetry records (``to_dict`` shape) this run holds."""
        recs = []
        if self.telemetry is not None:
            recs.append(self.telemetry.to_dict())
        if self.spec_telemetry is not None:
            recs.append(self.spec_telemetry.to_dict())
        return recs

    def snapshot(self) -> Dict:
        """Everything one ``run()`` accumulated, as one JSON-able record.

        Symmetric with the reset in ``_begin_run``: the export covers exactly
        the state since the last run started — ``completed`` is False for a
        run that died mid-flight (and None if the server never ran), and no
        field can carry residue from an earlier run.
        """
        return {
            "completed": self._run_complete,
            "host_transfers": self.host_transfers,
            "telemetry": self._telemetry_records(),
            "observability": (self.observer.snapshot()
                              if self.observer is not None else None),
            "resilience": {
                "outcomes": {rid: o.to_dict()
                             for rid, o in self.outcomes.items()},
                "counters": dict(self._fault_counts),
            },
        }

    def collective_snapshot(self) -> Optional[Dict]:
        """Collective-traffic summary of the compiled greedy decode burst —
        the mesh-serving cost block a trace header carries. ``None`` without
        a mesh; compiles the burst program if it has not run yet."""
        if self.mesh is None:
            return None
        from repro.launch import hlo_analysis

        with self._scope():
            hlo = (
                self.decode_burst(False)
                .lower(self._serving_tree(), self.cache, self._state)
                .compile()
                .as_text()
            )
        costs = hlo_analysis.analyze(hlo)
        return {
            "collective_bytes": costs.collective_bytes,
            "collective_by_kind": costs.collective_by_kind,
        }

    def _observe(self, point, tokens, steps, queue_depth, free_slots,
                 min_margin, deadline_misses=0, shed=0):
        from repro.runtime import StepSignals

        self.telemetry.record_burst(point, tokens=tokens, steps=steps,
                                    min_margin=min_margin)
        self.controller.observe(StepSignals(
            active=len(self.active),
            queue_depth=queue_depth,
            free_slots=free_slots,
            min_margin=min_margin,
            steps=steps,
            deadline_misses=deadline_misses,
            shed=shed,
        ))

    def _scope(self):
        """Ambient context for the jitted hot-path calls. A no-op without a
        mesh; with one it (a) installs the mesh so the model's activation
        constraints (``partition.constrain``) bind to it at trace time and
        (b) switches to partitionable threefry — the sharding-invariant PRNG
        mode, so SAMPLED streams are identical across mesh shapes (the legacy
        PRNG generates different bits when the vocab axis is sharded; greedy
        decoding never samples and is bit-identical to ``mesh=None`` either
        way)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        stack = contextlib.ExitStack()
        stack.enter_context(jax.threefry_partitionable(True))
        stack.enter_context(self.mesh)
        return stack

    def chunk_fns(self):
        """The jitted chunked-prefill programs ``(chunk, admit)`` — the
        streaming frontend's prefill hot path. Built lazily so batch-only
        servers never trace them; ``run()`` itself never calls these.
        ``chunk`` advances a request's private row cache by one padded chunk
        (row + last-logits donated); ``admit`` is the shared
        :func:`_finish_prefill` tail (cache/state/row donated)."""
        if self._chunk_fns is None:
            factory = (make_prefill_chunk if self.batched_prefill
                       else make_scan_chunk)
            if self.mesh is not None:
                raise ValueError(
                    "chunked prefill is single-device for now: the streaming "
                    "frontend rejects mesh= (ROADMAP: sharded streaming)"
                )
            self._chunk_fns = (
                jax.jit(factory(self.model, self.ctx), donate_argnums=(1, 2)),
                # the row is an input-only buffer here (scattered into the
                # slot cache, never returned) — donating it would just warn
                jax.jit(make_chunk_admit(), donate_argnums=(0, 1)),
            )
        return self._chunk_fns

    def fresh_row(self):
        """A fresh single-request prefill carry: a private ``(1, max_len)``
        row cache (write index 0) and a zeroed last-logits buffer."""
        row = self.model.make_cache(1, self.max_len, dtype=jnp.float32)
        last = jnp.zeros((1, self.model.cfg.vocab_size), jnp.float32)
        return row, last

    def decode_burst(self, sampled: bool = True):
        """The jitted burst step (``sampled=False``: the all-greedy variant)."""
        if sampled not in self._burst_fns:
            sharding_kwargs = {}
            if self.shardings is not None:
                sh = self.shardings
                buf = sh.slots((self.slots, self.burst))  # emit buffers
                sharding_kwargs = dict(
                    in_shardings=(None, sh.cache, sh.state),
                    out_shardings=(sh.cache, sh.state, buf, buf, buf),
                )
            limit = (self.resilience.logit_limit
                     if self.resilience is not None else None)
            self._burst_fns[sampled] = jax.jit(
                make_decode_burst(self.model, self.ctx, self.burst,
                                  sampled=sampled, logit_limit=limit),
                donate_argnums=(1, 2),
                **sharding_kwargs,
            )
        return self._burst_fns[sampled]

    def _burst_round(self, slot_of) -> Dict:
        """One decode burst over the active slots: ``burst`` scan steps on
        device, one host transfer, per-slot budget clipping on the host.

        Returns the round summary the scheduler acts on: tokens emitted,
        the executed point, the min margin over *clean* committed tokens,
        and the rids whose lanes faulted (their commit is clipped to the
        steps before the first bad logit; the scheduler quarantines them).
        """
        obs = self.observer
        if self.injector is not None:
            self.injector.before_round(self, self._round_idx, slot_of)
        self._round_idx += 1
        point = self.controller.point if self.controller is not None else None
        sampled = any(r.temperature > 0.0 for r in self.active.values())
        if obs is not None:
            if sampled not in self._burst_fns:
                obs.compile_event("burst", sampled=sampled)
            obs.burst_begin(point)
        with self._scope():
            self.cache, self._state, toks, margins, faults = (
                self.decode_burst(sampled)(
                    self._serving_tree(), self.cache, self._state,
                ))
        toks, margins, faults = jax.device_get((toks, margins, faults))
        self.host_transfers += 1
        isolate = (self.resilience is not None
                   and self.resilience.fault_isolation)
        emitted = 0
        burst_margins = []
        by_rid: Dict[int, List[int]] = {}
        faulted: List[int] = []
        for rid, req in self.active.items():
            s = slot_of[rid]
            n = min(self.burst, req.max_new - len(req.generated))
            if isolate and faults[s].any():
                # the flag is cumulative: clean steps are the leading False
                # run; everything from the first bad logit on is discarded
                n = min(n, int((~faults[s]).sum()))
                faulted.append(rid)
            by_rid[rid] = [int(t) for t in toks[s, :n]]
            req.generated.extend(by_rid[rid])
            req.margins.extend(float(m) for m in margins[s, :n])
            self._slot_start[s] += n
            emitted += n
            if rid not in faulted:
                burst_margins.append(float(margins[s, :n].min()))
        if obs is not None:
            obs.burst_end(point, self.burst, by_rid)
        return {
            "point": point,
            "emitted": emitted,
            "steps": self.burst,
            "min_margin": min(burst_margins) if burst_margins else None,
            "faulted": faulted,
            "fault_reason": "decode_nonfinite",
        }

    def _spec_round(self, slot_of) -> Dict:
        """One draft-k-then-verify round over the active slots.

        Each active request gains between 1 (first draft rejected) and
        ``draft_len + 1`` (all accepted + bonus) tokens, clipped to its
        ``max_new``; the KV cache comes back rolled back to the committed
        length per slot, and the device slot state (pending token, count) is
        re-synced in one fused update.

        Fault handling (the spec abort path, flags from the verify step's
        single host transfer): a *draft*-faulted lane already degraded to
        plain accurate decode inside the verify step (zero accepts, accurate
        correction token, accurate KV rewritten over the drafted scratch) —
        it commits normally and stays admitted. A *verify*-faulted lane is
        numerically unrecoverable: it commits nothing and the scheduler
        quarantines it.
        """
        st = self._state
        obs = self.observer
        if self.injector is not None:
            self.injector.before_round(self, self._round_idx, slot_of)
        self._round_idx += 1
        draft_point = self.controller.point if self.controller is not None else None
        if obs is not None:
            obs.burst_begin(draft_point or self.spec.default_draft_point,
                            kind="spec")
        with self._scope():
            (emitted, accepted, margins, draft_fault, verify_fault,
             self.cache, point) = self.spec.round(
                st["tok"], self.cache, st["key"], st["count"], st["temp"],
                self._slot_start, draft_point=draft_point,
            )
        self.host_transfers += 1
        isolate = (self.resilience is not None
                   and self.resilience.fault_isolation)
        accs, emits, round_margins = [], [], []
        by_rid: Dict[int, List[int]] = {}
        faulted: List[int] = []
        draft_faults: List[int] = []
        sync_slots, sync_toks, sync_counts = [], [], []
        for rid, req in self.active.items():
            s = slot_of[rid]
            if isolate and bool(verify_fault[s]):
                by_rid[rid] = []
                faulted.append(rid)
                continue
            if isolate and bool(draft_fault[s]):
                draft_faults.append(rid)
            n = min(int(accepted[s]) + 1, req.max_new - len(req.generated))
            by_rid[rid] = [int(t) for t in emitted[s, :n]]
            req.generated.extend(by_rid[rid])
            req.margins.extend(float(m) for m in margins[s, :n])
            self._slot_start[s] += int(accepted[s]) + 1
            accs.append(int(accepted[s]))
            emits.append(n)
            round_margins.append(float(margins[s, :n].min()))
            sync_slots.append(s)
            sync_toks.append(int(emitted[s, n - 1]))
            sync_counts.append(len(req.generated))
        if obs is not None:
            extra = {"draft_faults": draft_faults} if draft_faults else {}
            obs.burst_end(point, self.spec.draft_len + 1, by_rid, kind="spec",
                          accepted=accs, **extra)
        if sync_slots:
            sl = jnp.asarray(sync_slots, jnp.int32)
            self._state = dict(
                st,
                tok=st["tok"].at[sl].set(
                    jnp.asarray(sync_toks, jnp.int32)[:, None]),
                count=st["count"].at[sl].set(
                    jnp.asarray(sync_counts, jnp.int32)),
            )
        self.spec.telemetry.record_round(point, self.spec.verify_point, accs,
                                         emits)
        # a round executes draft_len single-token steps + one multi-token
        # verify forward: that is what the budget EMA / decode_steps cover
        return {
            "point": point,
            "emitted": sum(emits),
            "steps": self.spec.draft_len + 1,
            "min_margin": min(round_margins) if round_margins else None,
            "faulted": faulted,
            "fault_reason": "verify_nonfinite",
        }
