"""Pallas TPU kernel: CARMEN's time-multiplexed multi-AF block.

One kernel body serves six elementwise activation functions, selected by a
**runtime mode scalar** (SMEM) — the software image of the paper's
time-multiplexed shared CORDIC datapath: the hyperbolic-rotation exp core,
the linear-vectoring divider and the linear-rotation multiplier are emitted
once and every AF branch of the ``lax.switch`` composes them. ReLU is the
bypass branch. Softmax (the seventh AF) needs a row reduction, so it gets a
row-blocked sibling kernel sharing the same sub-units.

The fixed-point arithmetic inside the kernel is *literally* the core library
(`repro.core.activations` / `repro.core.cordic`) traced into the Pallas body —
kernel and bit-faithful simulation cannot drift apart.

Tiling: elementwise AFs use (bm, bn) = (256, 256) f32 blocks (in + out + ~3
int32 temporaries ~= 1.25 MiB VMEM). Softmax blocks whole rows (bm, N).

CORDIC depth is a compile-time parameter of the kernel (one specialization per
depth — the runtime-adaptive *traced-depth* path lives in the production int8
engine, see core/engine.py). Mode is runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import activations as afs
from repro.core.fxp import FxPFormat, dequantize, quantize, requantize

DEFAULT_BM = 256
DEFAULT_BN = 256

# Elementwise AFs become switch branches in this fixed order (softmax separate).
ELEMENTWISE_AFS = ("relu", "gelu", "tanh", "sigmoid", "swish", "selu")


def _af_elementwise_kernel(mode_ref, x_ref, out_ref, *, depth: int, fmt: FxPFormat):
    x = x_ref[...]
    ifmt = afs.internal_fmt(fmt)
    d = max(depth + (ifmt.frac - fmt.frac), 2)
    xq = requantize(quantize(x, fmt), fmt, ifmt)  # I/O grid -> guard-bit datapath

    branches = [
        functools.partial(afs.multi_af, mode=name, depth=d, fmt=ifmt)
        for name in ELEMENTWISE_AFS
    ]
    out_raw = jax.lax.switch(mode_ref[0], branches, xq)
    out_ref[...] = dequantize(requantize(out_raw, ifmt, fmt), fmt)


def _af_softmax_kernel(x_ref, out_ref, *, depth: int, fmt: FxPFormat):
    x = x_ref[...]
    ifmt = afs.internal_fmt(fmt)
    d = max(depth + (ifmt.frac - fmt.frac), 2)
    xq = requantize(quantize(x, fmt), fmt, ifmt)
    out_raw = afs.cordic_softmax(xq, d, ifmt, axis=-1)
    out_ref[...] = dequantize(requantize(out_raw, ifmt, fmt), fmt)


def _smem_spec():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pl.BlockSpec(memory_space=pltpu.SMEM)
    except ImportError:  # pragma: no cover
        return pl.BlockSpec(memory_space=pl.ANY)


@functools.partial(jax.jit, static_argnames=("depth", "fmt", "bm", "bn", "interpret"))
def af_elementwise(
    x,
    mode,
    *,
    depth: int,
    fmt: FxPFormat,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """(M, N) f32 -> (M, N) f32, AF selected by runtime ``mode`` (int32 index)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    mode = jnp.asarray(mode, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_af_elementwise_kernel, depth=depth, fmt=fmt),
        grid=(m // bm, n // bn),
        in_specs=[
            _smem_spec(),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(mode, x)


@functools.partial(jax.jit, static_argnames=("depth", "fmt", "bm", "interpret"))
def af_softmax(
    x,
    *,
    depth: int,
    fmt: FxPFormat,
    bm: int = 8,
    interpret: bool = False,
):
    """Row-blocked fixed-point softmax over the last axis."""
    m, n = x.shape
    assert m % bm == 0, (x.shape, bm)
    return pl.pallas_call(
        functools.partial(_af_softmax_kernel, depth=depth, fmt=fmt),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x)
