"""Decode-burst serving semantics.

Bursts are a pure scheduling change — one jitted scan over up to ``burst``
single-token steps with device-resident slot state — so every observable
contract of per-token serving must survive them bit-for-bit:

* greedy output is bit-identical to ``burst=1`` across every scatterable
  family (dense / vlm / moe / mla) AND the recurrent scan-prefill families;
* sampled streams depend only on (seed, token index) — never on burst size
  or batch composition (the PRNG folds by generated-token count);
* ``max_new`` is exact even when a request finishes mid-burst (emitted
  tokens past the budget are clipped on the host);
* bucketed prefill compiles O(log max_len) programs, not one per distinct
  prompt length;
* the whole point: host round-trips shrink by the burst factor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request
from repro.serve.kvcache import bucket_length

EXACT = EngineContext(mode="exact", compute_dtype=jnp.float32)


def _setup(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, *, max_new=6, temperature=0.0, seed_base=None):
    rng = np.random.default_rng(0)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 3 + i).astype(np.int32),
                max_new, temperature=temperature,
                seed=None if seed_base is None else seed_base + i)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def olmo():
    return _setup("olmo-1b")


# ---------------------------------------------------------------------------
# greedy bit-identity across burst sizes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmo-1b", "internvl2-2b",
                                  "llama4-maverick-400b-a17b",
                                  "deepseek-v3-671b"])
def test_burst_greedy_bit_identical_to_per_token(arch):
    """dense / vlm / moe / mla: burst=4 output == burst=1 output, token for
    token, including margins (same compiled step math, fewer round-trips)."""
    cfg, model, params = _setup(arch)
    reqs1 = _requests(cfg, 3)
    ref = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                        burst=1).run(reqs1)
    reqs4 = _requests(cfg, 3)
    out = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                        burst=4).run(reqs4)
    assert out == ref
    for a, b in zip(reqs1, reqs4):
        np.testing.assert_allclose(a.margins, b.margins, atol=1e-5)


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-7b"])
def test_recurrent_scan_prefill_burst_identical(arch):
    """ssm / hybrid: the masked-scan prefill + burst decode match burst=1."""
    cfg, model, params = _setup(arch)
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=1)
    assert not server.batched_prefill  # these take the scan-prefill path
    ref = server.run(_requests(cfg, 3))
    out = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                        burst=4).run(_requests(cfg, 3))
    assert out == ref


def test_burst_matches_dedicated_sequential_decode(olmo):
    """Burst serving with padded bucketed prefill reproduces a hand-rolled
    single-sequence decode loop exactly (the seed's ground truth)."""
    cfg, model, params = olmo
    prompt = np.array([5, 17, 3], np.int32)
    out = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                        burst=8).run([Request(0, prompt, 5)])
    cache = model.make_cache(1, 32, dtype=jnp.float32)
    tok = None
    for t in prompt:
        lg, cache = model.decode_step(params, jnp.array([[t]]), cache, EXACT)
        tok = int(np.asarray(lg[0, 0]).argmax())
    gen = [tok]
    for _ in range(4):
        lg, cache = model.decode_step(params, jnp.array([[gen[-1]]]), cache, EXACT)
        gen.append(int(np.asarray(lg[0, 0]).argmax()))
    assert out[0] == gen


def test_pinned_adaptive_burst_identical_to_static(olmo):
    """The adaptive machinery at a fixed execution point composes with
    bursts: burst=8 through the bank == static burst=1 serving."""
    from repro.runtime import ControllerConfig, ModeController, build_bank, default_points

    cfg, model, params = olmo
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    want = BatchedServer(model, ctx, bank.tree("accurate"), slots=2, max_len=32,
                         burst=1, prepare_weights=False).run(_requests(cfg, 4))
    ctrl = ModeController(bank, ControllerConfig(pin="accurate"))
    srv = BatchedServer(model, ctx, params, slots=2, max_len=32, burst=8,
                        controller=ctrl)
    assert srv.run(_requests(cfg, 4)) == want
    tele = srv.telemetry.summary()
    assert tele["decode_steps"] == tele["steps"] * 8  # one observation/burst


# ---------------------------------------------------------------------------
# sampled streams: burst- and schedule-independent
# ---------------------------------------------------------------------------


def test_sampled_streams_independent_of_burst_size(olmo):
    cfg, model, params = olmo
    serve = lambda burst: BatchedServer(
        model, EXACT, params, slots=2, max_len=32, burst=burst,
    ).run(_requests(cfg, 3, max_new=8, temperature=1.3, seed_base=40))
    a, b = serve(1), serve(8)
    assert a == b
    # sanity: high temperature actually diverges from greedy
    greedy = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                           burst=8).run(_requests(cfg, 3, max_new=8))
    assert a != greedy


def test_sampled_streams_independent_of_batch_composition(olmo):
    """Request 0's stream is the same served alone or alongside others, at
    any burst size — keys fold by token index, not by schedule."""
    cfg, model, params = olmo
    reqs = _requests(cfg, 3, max_new=8, temperature=1.3, seed_base=7)
    together = BatchedServer(model, EXACT, params, slots=2, max_len=32,
                             burst=8).run(reqs)
    alone = BatchedServer(model, EXACT, params, slots=1, max_len=32,
                          burst=4).run(_requests(cfg, 1, max_new=8,
                                                 temperature=1.3, seed_base=7))
    assert together[0] == alone[0]


# ---------------------------------------------------------------------------
# budget clipping + transfer accounting
# ---------------------------------------------------------------------------


def test_mid_burst_max_new_clipping(olmo):
    """max_new that is not a multiple of burst is exact: tokens computed past
    the budget inside the final burst are discarded on the host."""
    cfg, model, params = olmo
    for max_new in (1, 3, 9, 12):
        out = BatchedServer(model, EXACT, params, slots=2, max_len=40,
                            burst=8).run(_requests(cfg, 2, max_new=max_new))
        assert all(len(v) == max_new for v in out.values())
        ref = BatchedServer(model, EXACT, params, slots=2, max_len=40,
                            burst=1).run(_requests(cfg, 2, max_new=max_new))
        assert out == ref


def test_rejects_requests_exceeding_cache_rows(olmo):
    """prompt + max_new beyond max_len is rejected up front — the KV write
    index would clamp onto the last row mid-decode and corrupt output.

    This is the legacy (resilience=None) fail-stop contract; with a
    ResilienceConfig the same request is shed with reason ``too_long``
    instead (tests/test_resilience.py)."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=16, burst=8)
    with pytest.raises(ValueError, match="exceeds max_len"):
        server.run([Request(0, np.arange(12, dtype=np.int32) % cfg.vocab_size, 8)])
    with pytest.raises(ValueError, match="exceeds max_len"):  # prompt alone too long
        server.run([Request(0, np.arange(20, dtype=np.int32) % cfg.vocab_size, 1)])


def test_oversized_request_shed_when_resilient(olmo):
    """Same oversized request, resilient server: shed with a structured
    reason, batch unharmed, nothing raises."""
    from repro.resilience import ResilienceConfig

    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=16, burst=8,
                           resilience=ResilienceConfig())
    ok = Request(1, np.arange(3, dtype=np.int32) % cfg.vocab_size, 4)
    out = server.run(
        [Request(0, np.arange(12, dtype=np.int32) % cfg.vocab_size, 8), ok])
    assert server.outcomes[0].status == "shed"
    assert server.outcomes[0].reason == "too_long"
    assert 0 not in out and len(out[1]) == 4


def test_host_transfers_shrink_with_burst(olmo):
    cfg, model, params = olmo
    counts = {}
    for burst in (1, 8):
        srv = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=burst)
        srv.run(_requests(cfg, 2, max_new=8))
        counts[burst] = srv.host_transfers
    # 2 prefills either way; decode rounds collapse by the burst factor
    assert counts[8] < counts[1]
    assert counts[1] - 2 >= 4 * (counts[8] - 2)


# ---------------------------------------------------------------------------
# bucketed prefill: compile count
# ---------------------------------------------------------------------------


def test_bucket_length_is_pow2_clamped():
    assert [bucket_length(p, 64) for p in (1, 2, 3, 5, 9, 33, 64)] == \
        [1, 2, 4, 8, 16, 64, 64]
    assert bucket_length(50, 40) == 40  # clamped to the cache row budget


def test_bucketed_prefill_compile_count(olmo):
    """20 distinct prompt lengths must compile <= log2(max_len)+1 prefill
    programs (one per power-of-two bucket), not one per length."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=64, burst=8)
    rng = np.random.default_rng(3)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, i + 1).astype(np.int32), 1)
        for i in range(20)  # prompt lengths 1..20, max_new=1: prefill only
    ]
    out = server.run(reqs)
    assert all(len(v) == 1 for v in out.values())
    assert server.prefill._cache_size() <= int(np.log2(64)) + 1


def test_scan_prefill_compile_count():
    """The recurrent-family scan prefill buckets too."""
    cfg, model, params = _setup("mamba2-780m")
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32, burst=4)
    rng = np.random.default_rng(3)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, i + 1).astype(np.int32), 1)
        for i in range(10)  # lengths 1..10 -> buckets {1, 2, 4, 8, 16}
    ]
    server.run(reqs)
    assert server.prefill._cache_size() <= int(np.log2(32)) + 1
