"""Runtime-adaptive serving benchmark: cycles saved vs accuracy across load.

For each load level (request count against a fixed slot count) the same
workload is served twice — once all-accurate (static prepared bank), once
through the runtime-adaptive subsystem (multi-point bank + mode controller)
— and the record captures the trade the paper's §III makes measurable
end-to-end: estimated MAC-cycle savings, mode occupancy, switch counts,
throughput, and greedy token agreement (teacher-forced overall + on
high-confidence tokens, split at the median accurate-run top-2 margin).

    PYTHONPATH=src python -m benchmarks.bench_adaptive --arch olmo-1b \
        --loads 4,12 --max-new 16

``--smoke`` shrinks the workload for CI and writes the same JSON shape to
``artifacts/bench/BENCH_adaptive.json``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, FXP8, FXP16, PrecisionPolicy
from repro.runtime import (
    ControllerConfig,
    ModeController,
    build_bank,
    default_points,
    teacher_forced_agreement,
)
from repro.serve.engine import BatchedServer

from ._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    make_requests,
    timed,
)


def bench_load(model, cfg, params, bank, n_requests, *, slots, prompt_len,
               max_new, cycle_budget, fmt):
    ctx = EngineContext(mode=bank.mode, policy=PrecisionPolicy.accurate(fmt),
                        compute_dtype=jnp.float32)
    max_len = prompt_len + max_new + 2
    workload = lambda: make_requests(cfg, n_requests, prompt_len=prompt_len,
                                     max_new=max_new)

    ref_reqs = workload()
    # the bank already holds the all-accurate tree — no second prepare pass
    ref_server = BatchedServer(model, ctx, bank.tree(bank.reference), slots=slots,
                               max_len=max_len, prepare_weights=False)
    ref_dt, ref_out = timed(lambda: ref_server.run(ref_reqs))

    controller = ModeController(bank, ControllerConfig(cycle_budget=cycle_budget))
    adp_server = BatchedServer(model, ctx, params, slots=slots, max_len=max_len,
                               controller=controller)
    obs = attach_observer(adp_server)
    adp_dt, adp_out = timed(lambda: adp_server.run(workload()))
    tele = adp_server.telemetry.summary()

    seq_agree = float(np.mean([
        np.mean(np.array(adp_out[r]) == np.array(ref_out[r])) for r in ref_out
    ]))
    overall, high_conf, thr, _ = teacher_forced_agreement(
        model, ctx, bank.tree(bank.names[0]), ref_reqs, ref_out,
        {r.rid: r.margins for r in ref_reqs},
    )
    gen_toks = sum(len(v) for v in ref_out.values())  # decode tokens only
    return {
        "requests": n_requests,
        "queue_pressure": round(n_requests / slots, 2),
        "accurate_tok_s": round(gen_toks / max(ref_dt, 1e-9), 1),
        "adaptive_tok_s": round(gen_toks / max(adp_dt, 1e-9), 1),
        "est_cycle_savings_frac": tele["est_cycle_savings_frac"],
        "mode_occupancy": tele["mode_occupancy"],
        "switches": tele["switches"],
        "sequence_agreement": round(seq_agree, 4),
        "greedy_agreement_overall": round(overall, 4),
        "greedy_agreement_high_conf": round(high_conf, 4),
        "margin_threshold": round(thr, 4),
        "latency": latency_block(obs),
    }


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_adaptive.json")
    ap.add_argument("--mode", choices=["carmen", "int8", "kernel"], default="carmen")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--loads", default="4,12",
                    help="comma-separated request counts (load levels)")
    ap.add_argument("--cycle-budget", type=float, default=0.75)
    ap.add_argument("--fxp8", action="store_true",
                    help="FxP8 operand ladder (default FxP16)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.loads = "2,6"
        args.max_new = 8
        args.slots = 2

    cfg, model, params = load_model(args.arch, full_size=args.full_size)
    fmt = FXP8 if args.fxp8 else FXP16
    bank = build_bank(params, args.mode, default_points(fmt, hifi_fmt=None),
                      specs=model.specs())

    record = base_record(
        args,
        mode=args.mode,
        fmt=f"FXP{fmt.bits}",
        slots=args.slots,
        max_new=args.max_new,
        cycle_budget=args.cycle_budget,
        bank={
            "points": list(bank.names),
            "rel_cycles": {n: round(bank.rel_cycles(n), 4) for n in bank.names},
            "shared_leaves": bank.shared_leaves,
            "unique_leaves": bank.unique_leaves,
        },
        loads=[],
    )
    for n in (int(x) for x in args.loads.split(",")):
        rec = bench_load(model, cfg, params, bank, n, slots=args.slots,
                         prompt_len=args.prompt_len, max_new=args.max_new,
                         cycle_budget=args.cycle_budget, fmt=fmt)
        record["loads"].append(rec)
    return emit_record(record, args.out)


if __name__ == "__main__":
    main()
