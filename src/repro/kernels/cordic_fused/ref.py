"""Pure-XLA reference for the fused dot+AF chain.

Runs the *identical* integer-dot computation as the Pallas kernel — same
quantization, same int32 ``dot_general``, same descale association, same
activation epilogue — so it is bitwise equal to the kernel in interpret mode
and on TPU.  It doubles as the dispatch fallback whenever the fused kernel is
unavailable (mesh-sharded params, oversized K) and as the oracle in the
parity tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import P_WFRAC, P_XFRAC, P_XQMAX, P_XQMIN, af_epilogue


def fused_dot_af_ref(x, w, point, *, af_mode, af_depth, af_fmt, compute_round):
    """``x: (..., K) float``, ``w: (K, N) float`` signed-digit grid values,
    ``point: int32[5]`` from :func:`make_point`.  Returns f32."""
    x_frac = point[P_XFRAC]
    qmin = point[P_XQMIN].astype(jnp.float32)
    qmax = point[P_XQMAX].astype(jnp.float32)
    w_frac = point[P_WFRAC]

    xq = jnp.clip(
        jnp.round(x.astype(jnp.float32) * jnp.exp2(x_frac.astype(jnp.float32))),
        qmin, qmax,
    ).astype(jnp.int32)
    wq = jnp.round(
        w.astype(jnp.float32) * jnp.exp2(w_frac.astype(jnp.float32))
    ).astype(jnp.int32)

    acc = jax.lax.dot_general(
        xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    h = (acc.astype(jnp.float32) * jnp.exp2(-x_frac.astype(jnp.float32))
         ) * jnp.exp2(-w_frac.astype(jnp.float32))
    return af_epilogue(h, af_mode, af_depth, af_fmt, compute_round)
