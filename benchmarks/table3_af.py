"""Paper Table III — multi-AF block: all seven functions on the shared datapath.

Derived metrics: max error (in output LSBs) vs exact reference at FxP8/FxP16,
plus us/call of the fixed-point simulation and the Pallas kernel (interpret).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AF_NAMES, FXP8, FXP16, af_ref, full_depth, multi_af_float
from repro.kernels.cordic_af import ops as af_ops

SHAPE = (64, 512)


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    for fmt, fname in ((FXP8, "fxp8"), (FXP16, "fxp16")):
        lim = fmt.max_value * 0.95
        x = rng.uniform(-lim, lim, SHAPE).astype(np.float32)
        for mode in AF_NAMES:
            f = jax.jit(lambda m=mode: multi_af_float(x, m, full_depth(fmt), fmt))
            us = _time(f)
            out = np.asarray(f())
            ref = np.clip(np.asarray(af_ref(x, mode)), fmt.min_value, fmt.max_value)
            err_lsb = float(np.max(np.abs(out - ref))) / fmt.scale
            rows.append((f"table3.{mode}_{fname}", us, f"max_err_lsb={err_lsb:.1f}"))
    # kernel path (one representative AF + softmax)
    us = _time(lambda: af_ops.multi_af_pallas(
        rng.uniform(-1.9, 1.9, SHAPE).astype(np.float32), "gelu", depth=7, fmt=FXP8))
    rows.append(("table3.kernel_gelu_fxp8", us, "bit-eq-to-sim"))
    return rows
