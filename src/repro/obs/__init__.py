"""Serving observability: SLO latency metrics + replayable structured traces.

The measurement substrate under the serving engine's performance claims.
Three pieces, all host-side (never inside a jitted program — token streams
are bit-identical with observability on or off, asserted in
``tests/test_obs.py``):

* :mod:`repro.obs.metrics` — counters, gauges, and streaming histograms
  (p50/p90/p99) for the SLO quantities: time-to-first-token, inter-token
  latency, queue wait, prefill/decode wall time, per-request and run tok/s,
  acceptance rate, host transfers.
* :mod:`repro.obs.trace` — a structured event timeline (admission, prefill,
  bursts with their execution point, controller switches with their
  ``StepSignals``, speculative draft/verify/rollback, compile events) with
  two exports: Chrome-trace JSON (render a serving run in Perfetto) and a
  versioned JSONL format — the replay input for the ROADMAP's cycle-accurate
  PE-array simulator (``read_trace`` is the schema-checked reader).
* :mod:`repro.obs.observer` — :class:`ServingObserver`, the hook bundle
  ``BatchedServer(observer=...)`` drives at its existing host sync points.

Overhead is gated in CI: ``bench_serving --smoke`` fails if serving with an
observer attached falls below 95% of uninstrumented tok/s.
"""
from .metrics import Counter, Gauge, MetricsRegistry, StreamingHistogram
from .observer import ServingObserver
from .trace import (TRACE_SCHEMA, TRACE_VERSION, TraceReader, TraceRecorder,
                    iter_trace, read_trace)

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "ServingObserver",
    "StreamingHistogram",
    "TraceReader",
    "TraceRecorder",
    "TRACE_SCHEMA",
    "TRACE_VERSION",
    "iter_trace",
    "read_trace",
]
