"""KV-cache rollback: truncate drafted rows past the accepted prefix.

Truncation is a pure index rewrite: the per-query-causal mask makes rows at
positions ``>= index`` invisible, so rejected draft rows stay resident as
garbage and are overwritten by the next draft/verify round. The index
helpers live in :mod:`repro.serve.kvcache` (bucketed prefill shares the same
scratch discipline); this module keeps the speculative-decoding vocabulary.
"""
from __future__ import annotations

from repro.serve.kvcache import cache_positions, with_cache_positions

__all__ = ["cache_positions", "rollback", "with_cache_positions"]


def rollback(cache, committed):
    """Truncate each slot's cache to its ``committed`` row count.

    Rows at positions ``>= committed[b]`` (rejected drafts, the speculative
    scratch region) become invisible to all subsequent queries and are
    reclaimed by the next round's writes.
    """
    return with_cache_positions(cache, committed)
