"""Serving engine: prefill/decode step builders, sampling, batched scheduler.

The decode step is the unit the decode-shape cells lower (one new token against
a seq_len-deep KV cache). The scheduler below implements simple continuous
batching over a fixed slot count — admit/evict per step, per-slot positions —
with two serving fast paths on top:

* **prepared weight banks**: on construction the server runs
  ``prepare_params`` (quantize once), so carmen/int8/kernel decode performs
  zero weight-side rounding or scale computation per step;
* **batched prefill**: an admitted prompt runs through the model in ONE
  multi-token forward (``decode_step`` with S = prompt length), and the
  resulting KV rows are scattered into the slot cache — replacing the seed's
  token-by-token Python loop. Greedy sampling happens on device inside the
  jitted step, so only (B, 1) token ids cross the host boundary per step.

SSM/hybrid/audio families keep the sequential prefill path (their recurrent
state is carried step-by-step); the distributed story (cache shardings) lives
in sharding/partition.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, prepare_params
from repro.models import ModelApi

# families whose decode caches are pure attention/MLA KV rows (scatterable);
# recurrent-state families prefill sequentially
_BATCHED_PREFILL_FAMILIES = ("dense", "vlm", "moe")


def make_decode_sample_step(model: ModelApi, ctx: EngineContext, *,
                            temperature: float = 0.0):
    """Decode + on-device sampling: only (B, 1) ids leave the device."""

    def decode_sample(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        return sample(logits, key, temperature=temperature), cache

    return decode_sample


def make_cached_prefill_step(model: ModelApi, ctx: EngineContext):
    """Whole-prompt prefill: tokens (B, P) -> (first sampled token (B, 1), cache)."""

    def prefill_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), cache

    return prefill_step


def sample(logits, key, *, temperature: float = 0.0):
    """logits (B, 1, V) -> tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new: int
    generated: Optional[List[int]] = None


def _checked_prompt(req: Request) -> np.ndarray:
    prompt = np.asarray(req.prompt, np.int32)
    if prompt.size == 0:
        raise ValueError(
            f"request {req.rid}: empty prompt — prompts must carry at least "
            "one token (seed with BOS)"
        )
    return prompt


@dataclasses.dataclass
class BatchedServer:
    """Continuous batching over ``slots`` concurrent sequences (greedy).

    ``prepare_weights=True`` (default) formats the weight bank once through
    the engine's backend registry; pass False to benchmark the per-call path.
    """

    model: ModelApi
    ctx: EngineContext
    params: object
    slots: int = 4
    max_len: int = 256
    prepare_weights: bool = True

    def __post_init__(self):
        if self.prepare_weights:
            self.params = prepare_params(
                self.params, self.ctx.policy, self.ctx.mode, specs=self.model.specs()
            )
        self.decode = jax.jit(make_decode_sample_step(self.model, self.ctx))
        self.prefill = jax.jit(make_cached_prefill_step(self.model, self.ctx))
        self.cache = self.model.make_cache(self.slots, self.max_len, dtype=jnp.float32)
        self.active: Dict[int, Request] = {}
        self.batched_prefill = self.model.cfg.family in _BATCHED_PREFILL_FAMILIES

    def _scatter_slot(self, slot: int, row_cache):
        """Write a freshly prefilled single-row cache into this slot's rows."""

        def put(dst, src):
            src = src.astype(dst.dtype)
            if dst.shape == src.shape:  # slots == 1: whole-cache replacement
                return src
            diff = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape)) if a != b]
            assert len(diff) == 1, (dst.shape, src.shape)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, diff[0])

        self.cache = jax.tree.map(put, self.cache, row_cache)

    def _prefill_slot(self, slot: int, req: Request):
        """Run the prompt into this slot's cache; sets ``req.generated``.

        Both paths prefill a FRESH single-row cache and scatter it into the
        slot, so prefilling never touches other active slots' state: one
        multi-token pass for attention families (compiles once per distinct
        prompt length), a sequential token loop for recurrent state.
        """
        prompt = _checked_prompt(req)
        row = self.model.make_cache(1, self.max_len, dtype=jnp.float32)
        if self.batched_prefill:
            tok, row = self.prefill(self.params, jnp.asarray(prompt[None, :]), row)
            tok = int(np.asarray(tok)[0, 0])
        else:
            for t in prompt:
                sampled, row = self.decode(
                    self.params, jnp.asarray([[t]], jnp.int32), row
                )
            tok = int(np.asarray(sampled)[0, 0])
        self._scatter_slot(slot, row)
        req.generated = [tok]

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> generated tokens."""
        for req in requests:  # reject before any state mutates
            _checked_prompt(req)
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        slot_of: Dict[int, int] = {}
        free = list(range(self.slots))
        while queue or self.active:
            while queue and free:
                req = queue.pop(0)
                slot = free.pop(0)
                self._prefill_slot(slot, req)
                if len(req.generated) >= req.max_new:  # prefill already done
                    results[req.rid] = req.generated
                    free.append(slot)
                    continue
                self.active[req.rid] = req
                slot_of[req.rid] = slot
            if not self.active:
                continue
            toks = np.zeros((self.slots, 1), np.int32)
            for rid, req in self.active.items():
                toks[slot_of[rid], 0] = req.generated[-1]
            sampled, self.cache = self.decode(self.params, jnp.asarray(toks), self.cache)
            sampled = np.asarray(sampled)
            done = []
            for rid, req in self.active.items():
                req.generated.append(int(sampled[slot_of[rid], 0]))
                if len(req.generated) >= req.max_new:
                    done.append(rid)
            for rid in done:
                req = self.active.pop(rid)
                results[rid] = req.generated
                free.append(slot_of.pop(rid))
        return results
