"""Continuous-batching frontend: identity, interleaving, cancellation.

The frontend is a scheduling layer over the unchanged device-resident
engine, so its core contract is the one every scheduling change in this
repo carries: **greedy token streams are bit-identical to batch
``run()``** — per model family (attention chunking and the recurrent scan
carry are different programs), under sampling, under adaptive and
speculative serving, and regardless of when requests arrive relative to
each other. On top of that ride the open-world behaviours ``run()`` cannot
express: chunked prefill's interleaving bound (a long prompt admitted
mid-run stalls decoding slots by at most one chunk budget), client
cancellation mid-prefill / mid-decode (slot freed at the next tick, outcome
``aborted`` with partial tokens, no telemetry leak onto the slot's next
tenant), submit-relative deadlines, and per-tick shed sweeps.
"""
import asyncio
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.obs import ServingObserver
from repro.resilience import ResilienceConfig
from repro.runtime import (
    ControllerConfig,
    ModeController,
    build_bank,
    default_points,
)
from repro.serve.engine import BatchedServer, Request
from repro.serve.frontend import (
    AsyncFrontend,
    ContinuousScheduler,
    FrontendConfig,
)
from repro.spec import SpecConfig

EXACT = EngineContext(mode="exact", compute_dtype=jnp.float32)
CARMEN = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                       compute_dtype=jnp.float32)


def _setup(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, *, max_new=6, temperature=0.0, seed_base=None,
              prompt_len=None):
    rng = np.random.default_rng(0)
    return [
        Request(i, rng.integers(
                    0, cfg.vocab_size,
                    prompt_len if prompt_len else 3 + i).astype(np.int32),
                max_new, temperature=temperature,
                seed=None if seed_base is None else seed_base + i)
        for i in range(n)
    ]


def _frontend_serve(server, reqs, *, chunk_tokens=2, monolithic=False):
    sched = ContinuousScheduler(
        server, FrontendConfig(chunk_tokens=chunk_tokens,
                               monolithic_prefill=monolithic))
    with sched:
        for r in reqs:
            sched.submit(r)
        out = sched.drain()
    return out, sched


@pytest.fixture(scope="module")
def olmo():
    return _setup("olmo-1b")


# ---------------------------------------------------------------------------
# identity: chunked frontend == run(), every family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmo-1b", "internvl2-2b",
                                  "llama4-maverick-400b-a17b",
                                  "deepseek-v3-671b",
                                  "mamba2-780m", "zamba2-7b"])
def test_frontend_greedy_bit_identical_to_run(arch):
    """dense / vlm / moe / mla / ssm / hybrid: chunk_tokens=2 forces every
    prompt through multiple chunks; the streams must still match run()
    token for token — chunked prefill is scheduling, never numerics."""
    cfg, model, params = _setup(arch)
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=4)
    ref = server.run(_requests(cfg, 3))
    out, sched = _frontend_serve(server, _requests(cfg, 3))
    assert out == ref
    assert sched.stats["prefill_rows"] == sum(3 + i for i in range(3))


def test_frontend_monolithic_prefill_matches_run(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=4)
    ref = server.run(_requests(cfg, 3))
    out, _ = _frontend_serve(server, _requests(cfg, 3), monolithic=True)
    assert out == ref


def test_frontend_sampled_streams_match_run(olmo):
    """Sampling depends only on (seed, token index): the frontend's chunked
    admission must reproduce run()'s sampled streams exactly."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=4)
    ref = server.run(_requests(cfg, 3, temperature=0.8, seed_base=11))
    out, _ = _frontend_serve(
        server, _requests(cfg, 3, temperature=0.8, seed_base=11))
    assert out == ref


def test_frontend_adaptive_matches_run(olmo):
    cfg, model, params = olmo
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    def build():
        return BatchedServer(
            model, CARMEN, params, slots=2, max_len=32, burst=4, bank=bank,
            controller=ModeController(bank,
                                      ControllerConfig(pin=bank.reference)))
    ref = build().run(_requests(cfg, 3))
    out, _ = _frontend_serve(build(), _requests(cfg, 3))
    assert out == ref


def test_frontend_speculative_matches_run(olmo):
    cfg, model, params = olmo
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    def build():
        return BatchedServer(model, CARMEN, params, slots=2, max_len=40,
                             bank=bank, speculate=SpecConfig(draft_len=3))
    ref = build().run(_requests(cfg, 3))
    out, _ = _frontend_serve(build(), _requests(cfg, 3))
    assert out == ref


def test_frontend_late_arrival_stream_identical(olmo):
    """A request admitted mid-run (other slots already decoding) gets the
    same stream as when it was in the opening batch: per-slot state is
    independent of batch composition."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=2)
    reqs = _requests(cfg, 3, max_new=8)
    ref = server.run(_requests(cfg, 3, max_new=8))
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=2))
    with sched:
        sched.submit(reqs[0])
        sched.submit(reqs[1])
        for _ in range(4):
            sched.step()
        sched.submit(reqs[2])  # mid-run arrival
        out = sched.drain()
    assert out == ref


# ---------------------------------------------------------------------------
# interleaving: the chunk budget bounds prefill stall
# ---------------------------------------------------------------------------


def _interleave_workload(cfg):
    """Two shorts with different budgets (one outlives the other, so the
    long prompt's prefill really interleaves with live decoding) plus one
    24-token prompt submitted mid-run."""
    rng = np.random.default_rng(5)
    short = [
        Request(0, rng.integers(0, cfg.vocab_size, 3).astype(np.int32), 20),
        Request(1, rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 6),
    ]
    long_req = Request(
        9, rng.integers(0, cfg.vocab_size, 24).astype(np.int32), 4)
    return short, long_req


def test_interleaving_bound_holds_for_long_prompt(olmo):
    """A 24-token prompt admitted while a slot is still decoding advances
    at most chunk_tokens rows between bursts — decoding keeps emitting."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=48, burst=2)
    short, long_req = _interleave_workload(cfg)
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=4))
    with sched:
        for r in short:
            sched.submit(r)
        sched.step()
        sched.submit(long_req)
        out = sched.drain()
    # non-vacuous: prefill rows really ran while a slot was decoding...
    assert sched.stats["max_prefill_rows_between_bursts"] > 0
    # ...and never more than one chunk budget of them between two bursts
    assert sched.stats["max_prefill_rows_between_bursts"] <= 4
    assert len(out[9]) == 4
    # and the long prompt's stream is still exactly what run() gives it
    ref = server.run([Request(9, long_req.prompt.copy(), 4)])
    assert out[9] == ref[9]


def test_monolithic_contrast_takes_the_stall(olmo):
    """With monolithic_prefill the same workload charges the whole long
    prompt between two bursts — the stall chunking exists to amortize."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=48, burst=2)
    short, long_req = _interleave_workload(cfg)
    sched = ContinuousScheduler(
        server, FrontendConfig(chunk_tokens=4, monolithic_prefill=True))
    with sched:
        for r in short:
            sched.submit(r)
        sched.step()
        sched.submit(long_req)
        sched.drain()
    assert sched.stats["max_prefill_rows_between_bursts"] >= 24


# ---------------------------------------------------------------------------
# cancellation: mid-prefill, mid-decode, queued
# ---------------------------------------------------------------------------


def test_cancel_mid_prefill_frees_slot_no_leak(olmo):
    """Cancelling during a chunked prefill drops the private row cache,
    frees the slot at the next tick, settles the handle as aborted with 0
    tokens — and the slot's next tenant streams exactly as if the
    cancelled request never existed."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32, burst=4,
                           resilience=ResilienceConfig())
    server.observer = ServingObserver()
    ref = server.run(_requests(cfg, 1, max_new=6))

    rng = np.random.default_rng(5)
    victim = Request(
        50, rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 6)
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=2))
    with sched:
        handle = sched.submit(victim)
        sched.step()  # 2 of 12 prompt rows done: mid-prefill
        assert sched.job is not None and sched.job.done == 2
        handle.cancel()
        sched.step()
        assert sched.job is None and sched.free == [0]
        assert handle.done and handle.status == "aborted"
        assert handle.outcome.reason == "cancelled"
        assert handle.tokens == []
        # slot reuse: the next request on slot 0 is untouched by the corpse
        out = {}
        for r in _requests(cfg, 1, max_new=6):
            sched.submit(r)
        out = sched.drain()
    assert out[0] == ref[0]
    assert 50 not in out
    # telemetry: cancelled counted, but no first-token/ttft ever recorded
    snap = server.observer.snapshot()
    assert snap["metrics"]["counters"]["cancelled"] == 1
    assert snap["requests"][50]["tokens"] == 0
    assert snap["requests"][50]["ttft_s"] is None  # no first token ever
    prefilled = [e for e in server.observer.trace.events
                 if e["name"] == "request_prefilled"
                 and e["args"]["rid"] == 50]
    assert prefilled == []


def test_cancel_mid_decode_keeps_partial_tokens(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=64, burst=2,
                           resilience=ResilienceConfig())
    ref = server.run(_requests(cfg, 1, max_new=40))
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=4))
    with sched:
        handle = sched.submit(_requests(cfg, 1, max_new=40)[0])
        while len(handle.tokens) < 5:
            sched.step()
        handle.cancel()
        out = sched.drain()
    assert handle.status == "aborted"
    assert handle.outcome.reason == "cancelled"
    assert 0 < len(handle.tokens) < 40
    # the partial stream is a clean prefix of the uncancelled one
    assert out[0] == ref[0][:len(out[0])]


def test_cancel_queued_request_never_prefills(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32, burst=4,
                           resilience=ResilienceConfig())
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=8))
    with sched:
        first = sched.submit(_requests(cfg, 1, max_new=12)[0])
        queued = sched.submit(Request(
            7, np.arange(1, 5, dtype=np.int32), 6))
        sched.step()  # first occupies the only slot; 7 waits
        queued.cancel()
        out = sched.drain()
    assert queued.status == "aborted" and queued.tokens == []
    assert first.status == "ok" and len(out[0]) == 12
    assert 7 not in out


# ---------------------------------------------------------------------------
# submit-relative deadlines + per-tick shed sweeps
# ---------------------------------------------------------------------------


def test_deadline_counts_from_submit(olmo):
    """Frontend deadlines anchor at submit(): a request whose deadline
    passes while it sits in the inbox/queue is shed at the next tick."""
    cfg, model, params = olmo
    server = BatchedServer(
        model, EXACT, params, slots=1, max_len=32, burst=4,
        resilience=ResilienceConfig(default_deadline_s=30.0))
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=8))
    with sched:
        doomed = sched.submit(Request(0, np.arange(1, 4, dtype=np.int32), 4,
                                      deadline_s=0.03))
        time.sleep(0.15)  # expires before the first tick ever sees it
        fine = sched.submit(Request(1, np.arange(1, 4, dtype=np.int32), 4))
        out = sched.drain()
    assert doomed.status == "shed"
    assert doomed.outcome.reason == "deadline_expired"
    assert fine.status == "ok" and len(out[1]) == 4
    # the caller's Request objects were never mutated by resolution
    assert doomed.request.deadline_s == 0.03
    assert fine.request.deadline_s is None


def test_queue_overflow_sheds_per_tick(olmo):
    """shed_overflow runs on every tick, not once per run: requests
    submitted while the queue is full are shed with queue_full even though
    they never coexisted in one run() call."""
    cfg, model, params = olmo
    server = BatchedServer(
        model, EXACT, params, slots=1, max_len=32, burst=2,
        resilience=ResilienceConfig(queue_limit=1))
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=8))
    with sched:
        running = sched.submit(_requests(cfg, 1, max_new=12)[0])
        sched.step()  # occupies the slot
        waiters = [sched.submit(Request(10 + i,
                                        np.arange(1, 4, dtype=np.int32), 4))
                   for i in range(3)]
        sched.drain()
    assert running.status == "ok"
    statuses = sorted(h.status for h in waiters)
    assert statuses == ["ok", "shed", "shed"]
    shed = [h for h in waiters if h.status == "shed"]
    assert all(h.outcome.reason == "queue_full" for h in shed)


def test_legacy_contract_raises_at_submit(olmo):
    """resilience=None keeps fail-stop: invalid requests raise
    synchronously at submit(), byte-identical to run()'s message."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=8, burst=2)
    sched = ContinuousScheduler(server, FrontendConfig())
    with sched:
        with pytest.raises(ValueError, match="exceeds max_len"):
            sched.submit(Request(0, np.arange(1, 30, dtype=np.int32), 4))
        with pytest.raises(ValueError, match="empty prompt"):
            sched.submit(Request(1, np.zeros(0, dtype=np.int32), 4))


# ---------------------------------------------------------------------------
# API guards
# ---------------------------------------------------------------------------


def test_duplicate_rid_rejected(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32, burst=2)
    sched = ContinuousScheduler(server, FrontendConfig())
    with sched:
        sched.submit(Request(3, np.arange(1, 4, dtype=np.int32), 2))
        with pytest.raises(ValueError, match="duplicate rid"):
            sched.submit(Request(3, np.arange(1, 4, dtype=np.int32), 2))
        sched.drain()


def test_submit_requires_open_and_close_is_final(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32, burst=2)
    sched = ContinuousScheduler(server, FrontendConfig())
    with pytest.raises(RuntimeError, match="not open"):
        sched.submit(Request(0, np.arange(1, 4, dtype=np.int32), 2))
    with sched:
        pass
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(Request(0, np.arange(1, 4, dtype=np.int32), 2))


def test_mesh_server_rejected(olmo):
    cfg, model, params = olmo
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = BatchedServer(model, EXACT, params, slots=1, max_len=32,
                           burst=2, mesh=mesh)
    with pytest.raises(ValueError, match="single-device"):
        ContinuousScheduler(server)


def test_frontend_config_validation():
    with pytest.raises(ValueError):
        FrontendConfig(chunk_tokens=0)


def test_close_settles_in_flight_as_shutdown(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=64, burst=2,
                           resilience=ResilienceConfig())
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=8))
    with sched:
        h = sched.submit(_requests(cfg, 1, max_new=30)[0])
        sched.step()
        sched.step()
    assert h.done and h.status == "aborted"
    assert h.outcome.reason == "shutdown"
    assert 0 < len(h.tokens) < 30  # partial stream kept


# ---------------------------------------------------------------------------
# threads + asyncio facade
# ---------------------------------------------------------------------------


def test_threaded_submitters_one_scheduler(olmo):
    """submit() is thread-safe: N client threads feeding one scheduler get
    exactly the streams run() computes for the same requests."""
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=4)
    ref = server.run(_requests(cfg, 4))
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=2))
    reqs = _requests(cfg, 4)
    with sched:
        threads = [threading.Thread(target=sched.submit, args=(r,))
                   for r in reqs]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = sched.drain()
    assert out == ref


def test_async_frontend_generate_and_stream(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=2, max_len=32, burst=4)
    ref = server.run(_requests(cfg, 2))

    async def go():
        async with AsyncFrontend(server,
                                 FrontendConfig(chunk_tokens=2)) as fe:
            reqs = _requests(cfg, 2)
            task = asyncio.ensure_future(fe.generate(reqs[0]))
            streamed = []
            async for tok in fe.stream(reqs[1]):
                streamed.append(tok)
            return await task, streamed

    generated, streamed = asyncio.run(go())
    assert generated == ref[0]
    assert streamed == ref[1]


def test_async_frontend_cancellation(olmo):
    cfg, model, params = olmo
    server = BatchedServer(model, EXACT, params, slots=1, max_len=64, burst=2,
                           resilience=ResilienceConfig())
    fe = AsyncFrontend(server, FrontendConfig(chunk_tokens=4)).start()
    try:
        handle = fe.submit(_requests(cfg, 1, max_new=40)[0])
        while len(handle.tokens) < 4:
            time.sleep(0.005)
        handle.cancel()
        handle.result(timeout=30.0)
    finally:
        fe.stop()
    assert handle.status == "aborted"
    assert handle.outcome.reason == "cancelled"
    assert 0 < len(handle.tokens) < 40
