"""CARMEN core: CORDIC arithmetic, multi-AF block, MAC engine, precision policy."""
from .fxp import FXP8, FXP8_UNIT, FXP16, FXP16_UNIT, FxPFormat, dequantize, quantize
from .cordic import (
    approx_depth,
    cordic_div,
    cordic_exp,
    cordic_mul,
    full_depth,
    signed_digit_round,
)
from .activations import AF_INDEX, AF_NAMES, af_ref, cordic_softmax, multi_af, multi_af_float
from .mac import carmen_matmul_fast, cordic_dot, cordic_matmul, mac_cycles
from .engine import EngineContext, PreparedWeight, carmen_dot, int8_dot, prepare_params
from .precision_policy import (
    CRITICAL_KEYWORDS,
    LayerPrecision,
    PrecisionPolicy,
    assign_depths,
    pin_critical,
    sensitivity_scan,
)
from .pooling import aad_pool, aad_pool_1d, avg_pool, max_pool
from .normalization import layernorm, l2norm, nonparametric_ln, qk_norm, rmsnorm

__all__ = [
    "FXP8", "FXP8_UNIT", "FXP16", "FXP16_UNIT", "FxPFormat", "dequantize", "quantize",
    "approx_depth", "cordic_div", "cordic_exp", "cordic_mul", "full_depth",
    "signed_digit_round",
    "AF_INDEX", "AF_NAMES", "af_ref", "cordic_softmax", "multi_af", "multi_af_float",
    "carmen_matmul_fast", "cordic_dot", "cordic_matmul", "mac_cycles",
    "EngineContext", "PreparedWeight", "carmen_dot", "int8_dot", "prepare_params",
    "CRITICAL_KEYWORDS", "LayerPrecision", "PrecisionPolicy", "assign_depths",
    "pin_critical", "sensitivity_scan",
    "aad_pool", "aad_pool_1d", "avg_pool", "max_pool",
    "layernorm", "l2norm", "nonparametric_ln", "qk_norm", "rmsnorm",
]
