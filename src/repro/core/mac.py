"""CARMEN's runtime-adaptive iterative CORDIC MAC (paper §II-A).

Two simulation fidelities of the same arithmetic:

* :func:`cordic_dot` / :func:`cordic_matmul` — **bit-faithful**: every product
  is the linear-rotation shift-add recurrence from ``core/cordic.py``, exactly
  what the RTL executes. In hardware the accumulator register chains through
  the K MACs; because linear rotation is additive in ``y``, chaining equals
  summing the per-product outputs, so the vectorized product-then-sum below is
  bit-exact to the serial PE. Cost: O(K * depth) fixed-point steps.

* :func:`carmen_matmul_fast` — **error-model**: CORDIC's dominant error is the
  signed-digit rounding of the multiplier (``signed_digit_round``); applying it
  to the weight matrix once and then running a real matmul reproduces the
  bit-faithful result up to shift-truncation noise (bounded, see
  ``tests/test_cordic_mac.py::test_fast_model_matches_bitexact``). This is the
  form large-network accuracy sweeps (benchmarks/fig3) use, and the form the
  Pallas production kernel implements on the MXU.

Cycle model (for the paper's 33%-cycle-reduction claim): one CORDIC iteration
is one cycle in the iterative PE, so a K-length dot at depth d costs K*d
cycles (+K accumulate). ``mac_cycles`` exposes this for the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .fxp import FxPFormat, dequantize, quantize

__all__ = [
    "cordic_dot",
    "cordic_matmul",
    "carmen_matmul_fast",
    "mac_cycles",
]


def mac_cycles(k: int, depth: int) -> int:
    """Cycle count of a K-length dot product on one iterative CORDIC PE."""
    return k * (depth + 1)


def cordic_dot(x_raw, w_raw, depth: int, w_fmt: FxPFormat):
    """Bit-faithful dot product: sum_k cordic_mul(x[k], w[k]).

    x_raw: (..., K) int32 raw activations (any binary point).
    w_raw: (..., K) int32 raw weights in ``w_fmt`` (Q1.f — |w| < 2).
    Returns int32 raw in x's binary point (int32 accumulator = the PE's wide
    accumulator register).
    """
    prod = cordic.cordic_mul(x_raw, w_raw, depth, w_fmt)
    return jnp.sum(prod, axis=-1)


def cordic_matmul(x_raw, w_raw, depth: int, w_fmt: FxPFormat):
    """Bit-faithful fixed-point matmul: (M, K) @ (K, N) -> (M, N) int32 raw.

    Scanned over K so the intermediate is (M, N), not (M, K, N): each scan step
    is one vector-engine broadcast MAC (all PEs consume activation column k).
    """
    x_raw = jnp.asarray(x_raw, jnp.int32)
    w_raw = jnp.asarray(w_raw, jnp.int32)
    m, k = x_raw.shape
    k2, n = w_raw.shape
    assert k == k2, (x_raw.shape, w_raw.shape)

    def step(acc, xw):
        xk, wk = xw  # (M,), (N,)
        p = cordic.cordic_mul(xk[:, None], wk[None, :], depth, w_fmt)
        return acc + p, None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(step, acc0, (x_raw.T, w_raw))
    return acc


def carmen_matmul_fast(x, w, depth: int, x_fmt: FxPFormat, w_fmt: FxPFormat):
    """CARMEN error-model matmul on float values (production/TPU form).

    Quantizes activations to ``x_fmt``, weights to the depth-d signed-digit
    grid of ``w_fmt``, and runs a single real matmul. Float32 carries the int
    arithmetic exactly (values < 2^24).
    """
    xq = dequantize(quantize(x, x_fmt), x_fmt)
    wq = cordic.signed_digit_round(w, depth, w_fmt)
    return xq @ wq
