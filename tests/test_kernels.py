"""Pallas kernel validation (interpret mode): sweeps vs pure-jnp oracles.

Both kernels must be BIT-IDENTICAL to their refs — the MAC kernel reproduces
carmen_matmul_fast (same quantize/sd-round/int-dot arithmetic), and the AF
kernel traces the same core fixed-point functions the oracle evaluates.
"""
import numpy as np
import pytest

from repro.core import (
    FXP8,
    FXP8_UNIT,
    FXP16,
    FXP16_UNIT,
    approx_depth,
    carmen_matmul_fast,
    full_depth,
)
from repro.core.activations import AF_NAMES
from repro.kernels.cordic_af import ops as af_ops
from repro.kernels.cordic_af import ref as af_ref_mod
from repro.kernels.cordic_mac import ops as mac_ops
from repro.kernels.cordic_mac import ref as mac_ref_mod


# ---------------------------------------------------------------------------
# cordic_mac
# ---------------------------------------------------------------------------

MAC_SHAPES = [(8, 16, 8), (48, 200, 72), (128, 256, 128), (33, 127, 65), (1, 512, 1)]


@pytest.mark.parametrize("m,k,n", MAC_SHAPES)
@pytest.mark.parametrize(
    "x_fmt,w_fmt", [(FXP8, FXP8_UNIT), (FXP16, FXP16_UNIT)], ids=["fxp8", "fxp16"]
)
def test_mac_kernel_matches_fast_model(m, k, n, x_fmt, w_fmt, rng):
    depth = full_depth(w_fmt)
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
    out = np.asarray(mac_ops.cordic_mac(x, w, depth=depth, x_fmt=x_fmt, w_fmt=w_fmt))
    ref = np.asarray(carmen_matmul_fast(x, w, depth, x_fmt, w_fmt))
    if x_fmt.frac + w_fmt.frac <= 18:
        # FxP8: every product/sum sits on a grid f32 carries exactly -> bit-equal.
        np.testing.assert_array_equal(out, ref)
    else:
        # FxP16 products live on a 2^-26 grid; the *oracle's* f32 matmul rounds
        # while the kernel's integer accumulator is exact. Tolerance = f32 ulp
        # accumulation over K.
        np.testing.assert_allclose(out, ref, rtol=0, atol=k * 2.0**-22)


@pytest.mark.parametrize("depth_kind", ["full", "approx", "minimal"])
def test_mac_kernel_depth_sweep(depth_kind, rng):
    depth = {"full": full_depth(FXP8_UNIT), "approx": approx_depth(FXP8_UNIT), "minimal": 2}[
        depth_kind
    ]
    x = rng.uniform(-1, 1, (32, 64)).astype(np.float32)
    w = rng.uniform(-1, 1, (64, 32)).astype(np.float32)
    out = np.asarray(mac_ops.cordic_mac(x, w, depth=depth))
    ref = np.asarray(carmen_matmul_fast(x, w, depth, FXP8, FXP8_UNIT))
    np.testing.assert_array_equal(out, ref)


def test_mac_kernel_oracle_path(rng):
    """Kernel against the explicit int-arithmetic oracle (ref.py)."""
    x = rng.uniform(-1, 1, (16, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    x_q, xs = mac_ops.quantize_activations(x, FXP8)
    w_q, ws = mac_ops.quantize_weights(w, 5, FXP8_UNIT)
    ref = np.asarray(
        mac_ref_mod.mac_matmul_ref(
            x_q, w_q, np.full((16, 1), xs, np.float32), np.full((1, 16), ws, np.float32)
        )
    )
    out = np.asarray(mac_ops.cordic_mac(x, w, depth=5))
    np.testing.assert_array_equal(out, ref)


def test_mac_kernel_fused_relu(rng):
    x = rng.uniform(-1, 1, (16, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    out = np.asarray(mac_ops.cordic_mac(x, w, depth=7, fuse_relu=True))
    base = np.asarray(mac_ops.cordic_mac(x, w, depth=7))
    np.testing.assert_array_equal(out, np.maximum(base, 0.0))


def test_mac_weight_bank_fits_storage(rng):
    """Signed-digit weight ints must fit the declared storage dtype."""
    w = rng.uniform(-1.99, 1.99, (64, 64)).astype(np.float32)
    w_q, _ = mac_ops.quantize_weights(w, full_depth(FXP8_UNIT), FXP8_UNIT)
    assert w_q.dtype == np.int8
    w_q16, _ = mac_ops.quantize_weights(w, full_depth(FXP16_UNIT), FXP16_UNIT)
    assert w_q16.dtype == np.int16


# ---------------------------------------------------------------------------
# cordic_af
# ---------------------------------------------------------------------------

AF_SHAPES = [(4, 16), (100, 300), (256, 256), (3, 1000)]


@pytest.mark.parametrize("mode", AF_NAMES)
@pytest.mark.parametrize("fmt", [FXP8, FXP16], ids=["fxp8", "fxp16"])
def test_af_kernel_matches_ref(mode, fmt, rng):
    x = rng.uniform(-1.9, 1.9, (64, 128)).astype(np.float32)
    out = np.asarray(af_ops.multi_af_pallas(x, mode, depth=full_depth(fmt), fmt=fmt))
    ref = np.asarray(af_ref_mod.af_ref(x, mode, depth=full_depth(fmt), fmt=fmt))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("shape", AF_SHAPES)
def test_af_kernel_shape_sweep(shape, rng):
    x = rng.uniform(-1.9, 1.9, shape).astype(np.float32)
    out = np.asarray(af_ops.multi_af_pallas(x, "gelu", depth=7, fmt=FXP8))
    ref = np.asarray(af_ref_mod.af_ref(x, "gelu", depth=7, fmt=FXP8))
    np.testing.assert_array_equal(out, ref)


def test_af_kernel_runtime_mode_switch(rng):
    """One compiled kernel, mode switched at runtime (time-multiplexing)."""
    import jax

    x = rng.uniform(-1.5, 1.5, (8, 128)).astype(np.float32)
    f = jax.jit(
        lambda m: af_ops.multi_af_pallas(x, int(0), depth=7, fmt=FXP8)
        if False
        else None
    )
    # call through the traced-mode path: pass int indices
    outs = {}
    for mode in af_ops.AF_INDEX:
        if mode == "softmax":
            continue
        idx = af_ops.af_index(mode)
        outs[mode] = np.asarray(af_ops.multi_af_pallas(x, idx, depth=7, fmt=FXP8))
        ref = np.asarray(af_ref_mod.af_ref(x, mode, depth=7, fmt=FXP8))
        np.testing.assert_array_equal(outs[mode], ref)
    # different modes actually produce different outputs
    assert not np.array_equal(outs["relu"], outs["tanh"])


def test_af_kernel_3d_input(rng):
    x = rng.uniform(-1, 1, (2, 10, 64)).astype(np.float32)
    out = np.asarray(af_ops.multi_af_pallas(x, "swish", depth=7, fmt=FXP8))
    assert out.shape == (2, 10, 64)
    ref = np.asarray(af_ref_mod.af_ref(x, "swish", depth=7, fmt=FXP8))
    np.testing.assert_array_equal(out, ref)
