"""Logical-axis sharding rules -> NamedSharding, divisibility-aware.

The paper's N-PE vector engine scales by adding lanes; on the TPU cluster the
lane axis is the ``model`` mesh axis (TP/EP) and throughput scaling comes from
``(pod, data)`` (DP/FSDP). Rules map logical parameter axes to mesh axes; a
rule only applies when the dimension divides the mesh-axis extent — otherwise
the dimension falls back to replication (recorded by ``sharding_report`` so
the roofline pass can see what was dropped; e.g. 40-head attention on a
16-way model axis replicates heads and relies on FSDP for weight memory).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered candidate mesh-axis groups (first that divides wins)
PARAM_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "vocab": (("model",),),
    "embed": (("pod", "data"), ("data",), ("pod",)),  # FSDP shard of the d_model dim
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "mlp": (("model",),),
    # model-axis EP. (2D EP over data x model — fully-local expert weights —
    # was tried and REFUTED: GSPMD replicates the token batch to feed the
    # expert shards, 14x more collective bytes; see EXPERIMENTS.md §Perf B.)
    "experts": (("model",),),
    "layers": (),
    "q_lora": (),
    "kv_lora": (),
    "ssm_inner": (("model",),),
    "ssm_heads": (("model",),),
    "ssm_state": (),
    "conv": (),
    "groups": (),
    "frames": (),
    None: (),
}

# activation/batch rules used by input and cache shardings
BATCH_AXES = ("pod", "data")


def _resolve(axis_name: Optional[str], dim: int, mesh: Mesh, report: list) -> Optional[Tuple[str, ...]]:
    for group in PARAM_RULES.get(axis_name, ()):  # ordered preference
        group = tuple(a for a in group if a in mesh.axis_names)
        if not group:
            continue
        extent = int(np.prod([mesh.shape[a] for a in group]))
        if dim % extent == 0:
            return group
        report.append((axis_name, dim, group, extent))
    return None


def param_pspec(spec, mesh: Mesh, report: Optional[list] = None) -> P:
    """PartitionSpec for one ParamSpec. Only touches ``mesh.axis_names`` /
    ``mesh.shape``, so duck-typed stand-in meshes work (property tests)."""
    report = report if report is not None else []
    entries, used = [], set()
    for dim, ax in zip(spec.shape, spec.axes):
        group = _resolve(ax, dim, mesh, report)
        if group and not (set(group) & used):
            entries.append(group if len(group) > 1 else group[0])
            used.update(group)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(specs, mesh: Mesh):
    """Pytree of NamedShardings matching a model's param specs."""
    from repro.models import params as P_  # local: avoids circular import

    report: list = []
    out = P_.tree_map_specs(lambda s: NamedSharding(mesh, param_pspec(s, mesh, report)), specs)
    return out, report


def sharding_report(specs, mesh: Mesh):
    """(logical_axis, dim, group, extent) tuples for every replication fallback."""
    _, report = param_shardings(specs, mesh)
    return report


def prepared_shardings(params, specs, mesh: Mesh, report: Optional[list] = None):
    """Shardings for a serving param tree (raw or ``prepare_params`` output).

    The tree's structure matches ``specs`` except that engine-routed matmul
    leaves may be :class:`PreparedWeight` containers (payload inherits the raw
    leaf's rule-derived sharding; the per-channel scale inherits the entries of
    the axes it shares with the payload — see ``PreparedWeight.placement``)
    and tied-embedding trees carry a synthesized transposed ``lm_head`` (its
    pspec comes from the embedding spec with shape/axes reversed). The result
    is usable both for ``jax.device_put`` placement and as jit in/out
    shardings.
    """
    from repro.core.backends import PreparedWeight  # local: avoids cycle
    from repro.models.params import ParamSpec

    report = report if report is not None else []
    param_sh, rep = param_shardings(specs, mesh)
    report.extend(rep)
    if (
        isinstance(params, dict)
        and "lm_head" in params
        and isinstance(param_sh, dict)
        and "lm_head" not in param_sh
    ):
        embed = specs["embed"]
        head_spec = ParamSpec(embed.shape[::-1], embed.axes[::-1])
        param_sh = dict(
            param_sh,
            lm_head=NamedSharding(mesh, param_pspec(head_spec, mesh, report)),
        )

    def one(sh, leaf):
        if isinstance(leaf, PreparedWeight):
            return leaf.placement(sh)
        return sh

    return jax.tree.map(one, param_sh, params)


def slot_pspec(shape, mesh: Mesh) -> P:
    """Per-slot serving-state leaves (and KV slot axes): dim 0 over the batch
    axes when the slot count divides their extent; replicated otherwise."""
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    extent = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if shape and axes and extent > 1 and shape[0] % extent == 0:
        return P(axes)
    return P()


def slot_shardings(state_tree, mesh: Mesh):
    """NamedShardings for the server's device-resident per-slot state."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, slot_pspec(l.shape, mesh)), state_tree
    )


@dataclasses.dataclass
class ServingShardings:
    """Every placement the serving hot path needs, derived from one mesh.

    ``params`` matches the (possibly prepared) serving tree, ``cache`` the
    multi-slot KV cache, ``state`` the per-slot decode state; ``report``
    collects every rule the divisibility fallback dropped (params + the
    synthesized lm_head).
    """

    mesh: Mesh
    params: object
    cache: object
    state: object
    report: list = dataclasses.field(default_factory=list)

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def slots(self, shape) -> NamedSharding:
        """Sharding for a ``(slots, ...)`` emit buffer."""
        return NamedSharding(self.mesh, slot_pspec(tuple(shape), self.mesh))

    def snapshot(self) -> Dict:
        """The JSON placement summary a serving-trace header embeds
        (:func:`serving_sharding_report`)."""
        return serving_sharding_report(self)


def serving_shardings(mesh: Mesh, *, params, cache, state, specs, cfg,
                      max_len: Optional[int] = None) -> ServingShardings:
    """Build the full serving placement bundle for ``BatchedServer(mesh=...)``."""
    report: list = []
    params_sh = prepared_shardings(params, specs, mesh, report=report)
    cache_sh = cache_shardings(cache, mesh, cfg, row_axis_len=max_len)
    state_sh = slot_shardings(state, mesh)
    return ServingShardings(mesh, params_sh, cache_sh, state_sh, report)


def serving_sharding_report(sh: ServingShardings) -> Dict:
    """JSON-able placement summary for a serving mesh.

    ``dropped`` records every rule the divisibility fallback rejected (the
    dims that silently replicate); ``params`` counts sharded vs replicated
    weight leaves; ``cache``/``state`` list the pspec of each leaf.
    """

    def _spec_entries(tree):
        out: Dict[str, str] = {}
        flat, _ = jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        for path, leaf in flat:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            out[key] = str(leaf.spec)
        return out

    param_leaves = [
        l
        for l in jax.tree.leaves(
            sh.params, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if isinstance(l, jax.sharding.Sharding)
    ]
    n_sharded = sum(1 for l in param_leaves if tuple(l.spec))
    return {
        "mesh": {a: int(sh.mesh.shape[a]) for a in sh.mesh.axis_names},
        "devices": int(sh.mesh.devices.size),
        "dropped": [
            {"axis": a, "dim": int(d), "mesh_axes": list(g), "extent": int(e)}
            for a, d, g, e in sh.report
        ],
        "params": {
            "sharded": n_sharded,
            "replicated": len(param_leaves) - n_sharded,
        },
        "cache": _spec_entries(sh.cache),
        "state": _spec_entries(sh.state),
    }


def batch_pspec(mesh: Mesh, *, extra: Sequence[Optional[str]] = ()) -> P:
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    return P(axes, *extra)


def batch_sharding(mesh: Mesh, *, extra: Sequence[Optional[str]] = ()) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, extra=extra))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def current_mesh_axes() -> Tuple[str, ...]:
    """Axis names of the ambient mesh (jax.set_mesh or `with mesh:`), or ()."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return tuple(am.axis_names)
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if not m.empty:
            return tuple(m.axis_names)
    except Exception:
        pass
    return ()


def constrain(x, *entries):
    """with_sharding_constraint against the ambient mesh; no-op without one.

    Entries: "batch" (-> all of pod/data present in the mesh), a mesh axis
    name, or None. Dims that don't divide their axis extent are left
    unconstrained. Model code calls this to pin activation layouts (GSPMD
    propagation otherwise drops the batch sharding after the vocab-sharded
    embedding gather — observed: a TP-only program doing 32x redundant work;
    see EXPERIMENTS.md §Dry-run).
    """
    axes = current_mesh_axes()
    if not axes:
        return x
    from jax._src import mesh as mesh_lib

    try:
        phys = mesh_lib.thread_resources.env.physical_mesh
        sizes = dict(zip(phys.axis_names, phys.devices.shape)) if not phys.empty else {}
    except Exception:
        sizes = {}
    spec = []
    used: set = set()
    for i, e in enumerate(entries):
        if e == "batch":
            group = tuple(a for a in BATCH_AXES if a in axes and a not in used)
            extent = int(np.prod([sizes.get(a, 1) for a in group])) if group else 1
            if group and x.shape[i] % extent == 0:
                spec.append(group)
                used.update(group)
            else:
                spec.append(None)
        elif isinstance(e, tuple):
            group = tuple(a for a in e if a in axes and a not in used)
            extent = int(np.prod([sizes.get(a, 1) for a in group])) if group else 1
            if group and x.shape[i] % extent == 0:
                spec.append(group)
                used.update(group)
            else:
                spec.append(None)
        elif e in axes and e not in used and x.shape[i] % sizes.get(e, 1) == 0:
            spec.append(e)
            used.add(e)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def mesh_axis_sizes() -> Dict[str, int]:
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if not m.empty:
            return dict(zip(m.axis_names, m.devices.shape))
    except Exception:
        pass
    return {}


def use_2d_ep(num_experts: int) -> bool:
    """True when experts divide the full (data x model) extent — weights are
    then fully local (matches the 'experts' param rule preference)."""
    sizes = mesh_axis_sizes()
    extent = sizes.get("data", 1) * sizes.get("model", 1)
    return extent > 1 and num_experts % extent == 0


def cache_shardings(cache_tree, mesh: Mesh, cfg, *, row_axis_len: Optional[int] = None):
    """KV caches: batch over (pod, data); kv_heads/model-dim over model when divisible.

    Cache layouts (see models/*): attn (L, B, S, KV, hd) | mla latent
    (L, B, S, R) | ssm conv (L, B, W, C) / state (L, B, H, N, P).

    ``row_axis_len`` (the serving path passes ``max_len``) marks the sequence
    row axis: trailing dims of that extent are excluded from model-sharding
    and the EARLIEST remaining divisible dim wins — that is the heads/latent
    axis, the one the weight rules already shard, so decode never reshards
    rows. Without it (dry-run compatibility) the largest trailing dim wins.
    """
    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    batch_extent = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    model_extent = mesh.shape.get("model", 1)

    def one(leaf):
        shape = leaf.shape
        if len(shape) <= 1:  # stacked index scalars
            return NamedSharding(mesh, P())
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.integer):
            # (L, B) write-index vectors: these are the decode scatter's
            # indices — GSPMD wants scatter indices replicated (sharding
            # them trips the partitioner's index-broadcast lowering inside
            # the burst scan), and at L*B int32 they are free to replicate
            return NamedSharding(mesh, P())
        entries: list = [None] * len(shape)
        if shape[1] % max(batch_extent, 1) == 0:
            entries[1] = batch_axes  # B dim (dim 0 is layers)
        best = None
        for i in range(2, len(shape)):
            if row_axis_len is not None and i == 2 and shape[i] == row_axis_len:
                # the S row axis — dim 2 of the (L, B, S, ...) row-cache
                # layouts: decode writes here, never shard it. (Position AND
                # extent are checked so a trailing dim that happens to equal
                # max_len is not silently excluded from model-sharding.)
                continue
            if shape[i] % model_extent == 0 and shape[i] >= model_extent:
                if best is None:
                    best = i
                elif row_axis_len is None and shape[i] > shape[best]:
                    best = i
        if best is not None:
            entries[best] = "model"
        while entries and entries[-1] is None:
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, cache_tree)
