"""Backend registry + the one-time ``prepare_params`` pass (quantize once, serve fast).

The engine's four execution modes (``exact`` / ``carmen`` / ``int8`` /
``kernel``) are registered :class:`~repro.core.backends.base.Backend` objects.
``EngineContext.dot`` resolves the backend per call — from the weight leaf
itself when it is a :class:`PreparedWeight` (the prepared bank pins its own
execution path), from the context mode otherwise.

``prepare_params`` is the weight-bank lifecycle step: walk a model's param
tree once, materialize each ctx-routed matmul weight in its backend's serving
format, and return a tree the unchanged model code consumes through
``ctx.linear``. Training (QAT) keeps raw float trees — the traced per-call
path; inference prepares once and then performs zero weight-side rounding or
scale computation per forward.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

import jax

from ..precision_policy import PrecisionPolicy
from .base import Backend, PreparedWeight, unit_fmt
from .carmen import CarmenBackend, carmen_dot, sd_round_traced
from .exact import ExactBackend
from .int8 import Int8Backend, effective_bits, int8_dot, quantize_weight
from .kernel import KernelBackend

__all__ = [
    "Backend", "PreparedWeight", "get_backend", "register", "resolve",
    "prepare_params", "iter_dot_weights", "carmen_dot", "int8_dot",
    "sd_round_traced", "effective_bits", "quantize_weight", "unit_fmt",
]

_REGISTRY: Dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown engine mode {name!r}") from None


def resolve(w, mode: str) -> Backend:
    """Backend for one dot: the prepared leaf's own backend wins, else the mode."""
    if isinstance(w, PreparedWeight) and w.backend != "exact":
        return get_backend(w.backend)
    return get_backend(mode)


for _b in (ExactBackend(), CarmenBackend(), Int8Backend(), KernelBackend()):
    register(_b)


# ---------------------------------------------------------------------------
# prepare_params: walk a model param tree, materialize per-layer weight banks
# ---------------------------------------------------------------------------

# basenames of weight leaves that reach EngineContext.dot (everything else —
# norms, biases, conv filters, routers, MoE expert stacks, embeddings — stays
# float: criticality-pinned or consumed outside the engine)
_DOT_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "up", "gate", "down",
    "in_proj", "out_proj", "wq_a", "wq_b", "wkv_a", "lm_head",
})

# param-tree key -> dot-time layer-name component (policy lookup only)
_KEY_RENAMES = {
    "wq": "q", "wk": "k", "wv": "v", "wo": "o",
    "wq_a": "q_a", "wq_b": "q_b", "wkv_a": "kv_a",
    "self_attn": "self", "cross_attn": "cross",
    "enc_layers": "enc", "dec_layers": "dec",
}

_SEG_RE = re.compile(r"^seg\d+_(\w+)$")


def _path_keys(path):
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def _eligible(keys) -> bool:
    if not keys or keys[-1] not in _DOT_WEIGHT_NAMES:
        return False
    if len(keys) >= 2 and keys[-2] == "moe":
        return False  # expert stacks + router run as einsums, not engine dots
    return True


def _policy_name(keys) -> str:
    out = []
    for k in keys:
        if _SEG_RE.match(k):
            out.append("layer")
        else:
            out.append(_KEY_RENAMES.get(k, k))
    return ".".join(out)


def _stacked_axes(keys, spec) -> int:
    if spec is not None:
        n = 0
        for ax in spec.axes:
            if ax == "layers":
                n += 1
            else:
                break
        return n
    m = _SEG_RE.match(keys[0]) if keys else None
    if m:
        return 2 if m.group(1) == "hybrid" else 1
    if keys and keys[0] in ("enc_layers", "dec_layers"):
        return 1
    return 0


def _spec_index(specs):
    """path-keys tuple -> ParamSpec for stacked-axis identification."""
    if specs is None:
        return {}
    from repro.models.params import is_spec

    flat_specs, _ = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_spec)
    return {tuple(_path_keys(p)): s for p, s in flat_specs}


def _classify(keys, leaf, spec):
    """(policy_name, stacked_axes, in_axes) of an engine-routed matmul weight,
    or None when the leaf never reaches ``EngineContext.dot``."""
    if not _eligible(keys) or not hasattr(leaf, "ndim"):
        return None
    stacked = _stacked_axes(keys, spec)
    if leaf.ndim - stacked < 2:
        return None
    # contraction axes of the dot-time 2D view: weights are (in..., out...)
    # with a single input axis everywhere except wo, whose leading
    # (heads, head_dim) axes fold into the contraction
    in_axes = leaf.ndim - stacked - 1 if keys[-1] == "wo" else 1
    return _policy_name(keys), stacked, in_axes


def iter_dot_weights(params, *, specs=None):
    """Yield ``(keys, policy_name, leaf, stacked_axes, in_axes)`` for every
    weight leaf in ``params`` that reaches ``EngineContext.dot``.

    The single source of truth for "which leaves does the engine multiply":
    ``prepare_params`` formats exactly these leaves, the runtime cycle model
    (``repro.runtime.telemetry``) costs exactly these leaves, and the serving
    calibration scan (``repro.runtime.calibrate``) perturbs exactly these
    layer names. The tied-embedding lm_head is synthesized by callers (it has
    no leaf of its own in a tied tree — except in prepared trees, where the
    materialized head leaf IS yielded).

    Works on raw and prepared trees alike: :class:`PreparedWeight` nodes are
    treated as leaves (not descended into data/scale children).
    """
    spec_of = _spec_index(specs)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, PreparedWeight)
    )
    for path, leaf in flat:
        keys = _path_keys(path)
        info = _classify(keys, leaf, spec_of.get(tuple(keys)))
        if info is not None:
            name, stacked, in_axes = info
            yield keys, name, leaf, stacked, in_axes


def prepare_params(params, policy: Optional[PrecisionPolicy], mode: str, *,
                   specs=None, memo: Optional[Dict] = None):
    """Materialize per-layer prepared weight banks for serving.

    Walks ``params`` and replaces every engine-routed matmul weight with the
    ``mode`` backend's prepared form at the policy's per-layer (fmt, depth):
    signed-digit grids for ``carmen``/``kernel``, int8 qvalues + per-channel
    scales for ``int8``, pass-through for ``exact``. Leaves shared across
    calls are prepared once per (tensor, execution point).

    ``specs`` (the model's ``ParamSpec`` tree, ``model.specs()``) identifies
    stacked layer banks so int8 scales keep their per-layer leading axis and
    slice alongside the qvalues inside ``lax.scan``; without it a naming
    heuristic over the segment keys is used.

    Tied-embedding models get an explicit prepared ``lm_head`` entry (the
    transposed embedding), so decoding never re-quantizes the output head;
    the embedding itself stays float for the table lookup.

    ``memo`` is an optional cross-call cache keyed by (tensor identity, mode,
    execution point, stacked axes). Passing the same dict across several calls
    makes the prepared trees SHARE leaves wherever the execution point agrees
    — how the multi-point weight bank (``repro.runtime.bank``) keeps pinned
    layers single-copy across its modes.
    """
    backend = get_backend(mode)
    if mode == "exact":
        return params
    policy = policy or PrecisionPolicy.accurate()

    spec_of = _spec_index(specs)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if memo is None:
        memo = {}
    out = []
    for path, leaf in flat:
        keys = _path_keys(path)
        info = _classify(keys, leaf, spec_of.get(tuple(keys)))
        if isinstance(leaf, PreparedWeight) or info is None:
            out.append(leaf)
            continue
        name, stacked, in_axes = info
        lp = policy.for_layer(name)
        key = (id(leaf), mode, lp, stacked)
        if key not in memo:
            memo[key] = backend.prepare(leaf, lp, stacked_axes=stacked, in_axes=in_axes)
        out.append(memo[key])
    prepared = jax.tree_util.tree_unflatten(treedef, out)

    if isinstance(prepared, dict) and "lm_head" not in prepared and "embed" in prepared:
        embed = params["embed"]
        if hasattr(embed, "ndim") and embed.ndim == 2 and not isinstance(embed, PreparedWeight):
            lp = policy.for_layer("lm_head")
            key = (id(embed), "lm_head.T", mode, lp)
            if key not in memo:
                memo[key] = backend.prepare(embed.T, lp, stacked_axes=0)
            prepared = dict(prepared)
            prepared["lm_head"] = memo[key]
    return prepared
