"""Parameter specs with logical sharding axes.

Every model declares its parameters as a pytree of :class:`ParamSpec` —
shape + logical axis names + init scale. The same tree serves three uses:

* ``init(specs, key)``       — materialize real (small) params for smoke tests
  and examples;
* ``abstract(specs)``        — ShapeDtypeStructs for the dry-run (no memory);
* ``partition_specs(specs)`` — PartitionSpecs via the logical-axis rules in
  ``repro/sharding/partition.py``.

Logical axis vocabulary (see sharding rules): "vocab", "embed", "heads",
"kv_heads", "head_dim", "mlp", "experts", "layers", "q_lora", "kv_lora",
"ssm_inner", "ssm_state", "ssm_heads", "conv", "groups", None (replicated).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def abstract(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — the dry-run's zero-memory stand-in."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def axes_tree(specs):
    return tree_map_specs(lambda s: s.axes, specs)


def init(specs, key, dtype=jnp.float32):
    """Materialize parameters (smoke tests / examples / real training)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            scale = s.scale
            if s.init == "small_normal":
                scale = s.scale / math.sqrt(max(s.shape[0], 1))
            out.append(scale * jax.random.normal(k, s.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_layers(spec_fn, n: int):
    """Stack one layer's specs along a leading 'layers' axis (scan form)."""
    layer = spec_fn()
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale), layer
    )
