"""Batched serving driver (continuous batching over decode steps).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 6 --max-new 16 --mode carmen

Precision policy (paper §III): ``--policy-file`` loads a JSON policy
(``PrecisionPolicy.save`` / ``assign_depths`` output), ``--calibrate`` runs
the sensitivity scan on a synthetic calibration batch at startup, otherwise
the policy is uniform accurate. ``--adaptive`` serves through the
runtime-adaptive subsystem (``repro.runtime``): a multi-point weight bank +
mode controller that switches execution points per decode step from live
telemetry, optionally steered by ``--cycle-budget``. ``--speculative``
serves self-speculatively (``repro.spec``): draft ``--draft-len`` tokens on
the shallow execution point (``--draft-point``, default the bank's cheapest;
with ``--adaptive`` the controller picks it per round), verify them in one
accurate multi-token forward, roll the KV cache back past rejections —
greedy output stays bit-identical to accurate-only serving. ``--burst``
sets the decode burst length (jitted scan steps per host round-trip;
``--burst 1`` is the per-token loop, for A/B benchmarking). ``--mesh
DATA,MODEL`` (or ``--mesh auto``) serves tensor-parallel on a device mesh —
greedy token streams are bit-identical to single-device serving across mesh
shapes. ``--metrics``/``--metrics-out`` report per-request SLO latency
(TTFT, inter-token, queue-wait percentiles); ``--trace-out`` /
``--chrome-trace`` export the structured serve trace (JSONL replay format /
Perfetto); ``--profile DIR`` additionally captures a ``jax.profiler`` trace.

Fault tolerance (``repro.resilience``, see ``docs/robustness.md``) — any of
the flags below switches the server from fail-stop to shed/quarantine/
degrade, with a per-request outcome summary printed at the end::

    # per-request deadlines: requests that cannot finish inside 500 ms are
    # shed from the queue or evicted mid-decode with partial output
    ... --deadline-ms 500

    # bounded admission queue: at most 8 requests held; overload is shed
    # fast with attributable reasons instead of waiting unboundedly
    ... --queue-limit 8 --shed-policy deadline_aware

    # graceful degradation: under deadline misses / queue pressure the whole
    # batch demotes down the CORDIC depth ladder before anything is shed
    ... --adaptive --deadline-ms 500 --degrade

Streaming frontend (``repro.serve.frontend``, see ``docs/serving.md``) —
``--frontend`` serves the synthetic workload through the continuous-batching
scheduler instead of ``run()``: requests arrive over time (``--arrival-rate``
req/s, seeded Poisson; 0 = all at once), admission/eviction/shed sweeps run
every tick, and prefill is chunked to ``--chunk-tokens`` rows per tick so a
long prompt never stalls decoding slots for more than one chunk budget
(``--monolithic-prefill`` disables chunking, the A/B contrast). Deadlines
become submit-relative. Two live drivers ride the same scheduler::

    # JSONL requests on stdin -> streamed {"rid", "token"} JSONL on stdout
    echo '{"rid": 0, "prompt": [5, 17, 3], "max_new": 8}' | \
        ... --stdin-requests

    # minimal HTTP service: POST /generate {"prompt": [...], "max_new": N}
    ... --http-port 8080
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.core import FXP8, FXP16, EngineContext, PrecisionPolicy, assign_depths
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request


def resolve_policy(args, model, params, fmt) -> PrecisionPolicy:
    """--policy-file > --calibrate (startup sensitivity scan) > accurate."""
    if args.policy_file:
        policy = PrecisionPolicy.load(args.policy_file)
    elif args.calibrate:
        from repro.runtime import calibration_scan

        rng = np.random.default_rng(0)
        tokens = rng.integers(0, model.cfg.vocab_size, (2, max(args.prompt_len, 8)))
        sens = calibration_scan(model, params, tokens, fmt=fmt, mode=args.mode)
        policy = assign_depths(
            sens, fmt=fmt, cycle_reduction_target=args.cycle_reduction
        )
        print("calibration scan:", {k: round(v, 4) for k, v in sorted(sens.items())})
    else:
        policy = PrecisionPolicy.accurate(fmt)
    if args.save_policy:
        policy.save(args.save_policy)
        print(f"policy saved to {args.save_policy}")
    return policy


def _frontend_config(args):
    from repro.serve.frontend import FrontendConfig

    return FrontendConfig(chunk_tokens=args.chunk_tokens,
                          monolithic_prefill=args.monolithic_prefill)


def _serve_synthetic(args, server, reqs):
    """The synthetic workload through the scheduler, ticked on this thread:
    a seeded arrival process decides *when* each request is submitted, and
    between arrivals the scheduler keeps admitting/prefilling/decoding."""
    from repro.serve.frontend import ContinuousScheduler

    rng = np.random.default_rng(args.arrival_seed)
    if args.arrival_rate > 0:
        arrive = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                           size=len(reqs)))
    else:
        arrive = np.zeros(len(reqs))
    pending = list(zip(arrive.tolist(), reqs))
    sched = ContinuousScheduler(server, _frontend_config(args))
    with sched:
        t0 = time.perf_counter()
        while pending or not sched.idle:
            now = time.perf_counter() - t0
            while pending and pending[0][0] <= now:
                sched.submit(pending.pop(0)[1])
            if not sched.step() and pending:
                # idle but arrivals remain: sleep until the next one is due
                time.sleep(min(0.01, max(0.0, pending[0][0] - now)))
        results = dict(sched.results)
    print(f"frontend: ticks={sched.stats['ticks']} "
          f"bursts={sched.stats['bursts']} "
          f"prefill_rows={sched.stats['prefill_rows']} "
          f"max_prefill_rows_between_bursts="
          f"{sched.stats['max_prefill_rows_between_bursts']} "
          f"(chunk budget {args.chunk_tokens})")
    return results


def _serve_stdin(args, server):
    """JSONL requests on stdin, streamed JSONL tokens on stdout. Each line
    in is one request; each token lands as its own line out, then a final
    ``done`` line with the outcome status."""
    import sys
    import threading

    from repro.serve.frontend import AsyncFrontend

    fe = AsyncFrontend(server, _frontend_config(args)).start()
    results = {}
    out_lock = threading.Lock()

    def pump(handle):
        for tok in handle:
            with out_lock:
                print(json.dumps({"rid": handle.rid, "token": int(tok)}),
                      flush=True)
        with out_lock:
            print(json.dumps({"rid": handle.rid, "done": True,
                              "status": handle.status or "ok",
                              "tokens": len(handle.tokens)}), flush=True)
            results[handle.rid] = list(handle.tokens)

    pumps = []
    auto_rid = 0
    try:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            rid = int(d.get("rid", auto_rid))
            auto_rid = max(auto_rid, rid) + 1
            req = Request(
                rid, np.asarray(d["prompt"], np.int32),
                int(d.get("max_new", args.max_new)),
                temperature=float(d.get("temperature", args.temperature)),
                seed=d.get("seed", args.seed),
                deadline_s=d.get("deadline_s"),
            )
            try:
                handle = fe.submit(req)
            except ValueError as e:
                with out_lock:
                    print(json.dumps({"rid": rid, "done": True,
                                      "status": "rejected",
                                      "error": str(e)}), flush=True)
                continue
            t = threading.Thread(target=pump, args=(handle,), daemon=True)
            t.start()
            pumps.append(t)
        for t in pumps:
            t.join()
    finally:
        fe.stop()
    return results


def _serve_http(args, server):
    """Minimal stdlib HTTP service over the async frontend. One endpoint:
    POST /generate with ``{"prompt": [...], "max_new": N, ...}`` blocks
    until the request settles and returns the full token stream (a broken
    connection mid-wait cancels the request — client disconnect maps to
    eviction at the next tick). GET /healthz for liveness."""
    import itertools
    import select
    import socket
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    from repro.serve.frontend import AsyncFrontend

    fe = AsyncFrontend(server, _frontend_config(args)).start()
    results = {}
    counter = itertools.count()
    lock = threading.Lock()

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):  # keep stdout for the serving summary
            pass

        def do_GET(self):
            if self.path != "/healthz":
                self.send_error(404)
                return
            self._reply(200, {"ok": True})

        def do_POST(self):
            if self.path != "/generate":
                self.send_error(404)
                return
            n = int(self.headers.get("Content-Length", 0))
            try:
                d = json.loads(self.rfile.read(n) or b"{}")
                with lock:
                    rid = int(d.get("rid", next(counter) + 100000))
                req = Request(
                    rid, np.asarray(d["prompt"], np.int32),
                    int(d.get("max_new", args.max_new)),
                    temperature=float(d.get("temperature", args.temperature)),
                    seed=d.get("seed", args.seed),
                    deadline_s=d.get("deadline_s"),
                )
                handle = fe.submit(req)
            except (KeyError, ValueError, TypeError,
                    json.JSONDecodeError) as e:
                self._reply(400, {"error": str(e)})
                return
            # block until settled, but watch the socket: a client that
            # disconnects mid-generation cancels the request (eviction at
            # the next tick, partial tokens kept, outcome ``aborted``)
            while not handle._done.wait(0.25):
                readable, _, _ = select.select([self.connection], [], [], 0)
                if readable and not self.connection.recv(1, socket.MSG_PEEK):
                    handle.cancel()
                    handle._done.wait(5.0)
                    return
            toks = list(handle.tokens)
            with lock:
                results[rid] = toks
            self._reply(200, {"rid": rid, "tokens": toks,
                              "status": handle.status or "ok"})

        def _reply(self, code, payload):
            body = json.dumps(payload).encode()
            try:
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            except (BrokenPipeError, ConnectionResetError):
                pass  # client went away; the request already settled

    srv = ThreadingHTTPServer(("127.0.0.1", args.http_port), Handler)
    print(f"serving on http://127.0.0.1:{args.http_port} "
          "(POST /generate, GET /healthz); Ctrl-C to stop", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        print("\nshutting down")
    finally:
        srv.server_close()
        fe.stop()
    return results


def _serve_frontend(args, server, reqs):
    if args.http_port:
        return _serve_http(args, server)
    if args.stdin_requests:
        return _serve_stdin(args, server)
    return _serve_synthetic(args, server, reqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--mode", choices=["exact", "carmen", "int8", "kernel"], default="exact")
    ap.add_argument("--per-call", action="store_true",
                    help="skip prepare_params: re-quantize weights every step "
                         "(the seed behaviour; for A/B benchmarking)")
    ap.add_argument("--fxp16", action="store_true",
                    help="FxP16 operand format (default FxP8)")
    ap.add_argument("--policy-file", default=None,
                    help="JSON precision policy (PrecisionPolicy.save / assign_depths)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the §III sensitivity scan on a calibration batch at startup")
    ap.add_argument("--save-policy", default=None,
                    help="write the resolved policy as JSON (round-trips via --policy-file)")
    ap.add_argument("--cycle-reduction", type=float, default=0.33,
                    help="assign_depths cycle-reduction budget for --calibrate")
    ap.add_argument("--adaptive", action="store_true",
                    help="runtime-adaptive precision: multi-point bank + mode controller")
    ap.add_argument("--cycle-budget", type=float, default=0.75,
                    help="--adaptive: target MAC-cycle fraction vs all-accurate")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="PE-array calibration JSON (repro.sim.calibrate "
                         "export): prices the bank's per-point cycle costs "
                         "with fitted constants instead of the analytic "
                         "model; recorded in telemetry/trace as cycle_model")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative serving: draft on the shallow "
                         "execution point, verify on the accurate point")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="--speculative: tokens drafted per verify round")
    ap.add_argument("--draft-point", default=None,
                    help="--speculative: bank point to draft at (default: the "
                         "cheapest; with --adaptive the controller picks)")
    ap.add_argument("--burst", type=int, default=8,
                    help="decode burst length: jitted scan steps per host "
                         "round-trip (1 = the per-token loop)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--seed", type=int, default=None,
                    help="base sampling seed (request i uses seed + i)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve tensor-parallel on a (data, model) device "
                         "mesh: 'DATA,MODEL' extents (e.g. --mesh 4,2) or "
                         "'auto' to factor the local device count (see "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    res_args = ap.add_argument_group(
        "resilience",
        "fault-tolerant serving (repro.resilience): deadlines, bounded "
        "admission with load shedding, per-slot fault quarantine, graceful "
        "precision degradation — any flag here enables the resilient "
        "contract (structured RequestOutcomes instead of crashes)")
    res_args.add_argument("--deadline-ms", type=float, default=None,
                          help="per-request deadline in ms from run entry: "
                               "expired queued requests are shed, expired "
                               "running requests are evicted with partial "
                               "output at the next burst boundary")
    res_args.add_argument("--queue-limit", type=int, default=None,
                          help="bounded admission queue: overflow is shed "
                               "per --shed-policy with reason queue_full")
    res_args.add_argument("--shed-policy", default="reject_newest",
                          choices=["reject_newest", "reject_largest",
                                   "deadline_aware"],
                          help="queue-overflow victim selection")
    res_args.add_argument("--degrade", action="store_true",
                          help="graceful degradation: cap the whole batch "
                               "down the bank's depth ladder under deadline "
                               "misses / queue pressure, promote back with "
                               "hysteresis (needs a bank: --adaptive or "
                               "--speculative)")
    res_args.add_argument("--degrade-floor", default=None, metavar="POINT",
                          help="--degrade: cheapest bank point the cap may "
                               "reach (default: the cheapest rung)")
    obs_args = ap.add_argument_group(
        "observability",
        "SLO metrics + structured serve trace (repro.obs); hooks run only at "
        "host sync points, so token streams are bit-identical with or "
        "without them")
    obs_args.add_argument("--metrics", action="store_true",
                          help="print the metrics snapshot (TTFT / inter-token "
                               "/ queue-wait percentiles, counters, gauges)")
    obs_args.add_argument("--metrics-out", default=None, metavar="PATH",
                          help="write the metrics + per-request snapshot JSON")
    obs_args.add_argument("--trace-out", default=None, metavar="PATH",
                          help="write the versioned JSONL serve trace "
                               "(replayable: the PE-array simulator input)")
    obs_args.add_argument("--chrome-trace", default=None, metavar="PATH",
                          help="write a Chrome-trace JSON (load in Perfetto "
                               "or chrome://tracing)")
    obs_args.add_argument("--profile", default=None, metavar="DIR",
                          help="wrap the run in a jax.profiler trace "
                               "(XLA-level; complements the serve trace)")
    fe_args = ap.add_argument_group(
        "streaming frontend",
        "continuous-batching scheduler (repro.serve.frontend): requests "
        "arrive over time, admission/eviction sweeps run every tick, prefill "
        "is chunked so long prompts never stall decoding slots")
    fe_args.add_argument("--frontend", action="store_true",
                         help="serve the synthetic workload through the "
                              "continuous-batching scheduler instead of "
                              "run() (deadlines become submit-relative)")
    fe_args.add_argument("--chunk-tokens", type=int, default=32,
                         help="prefill budget: prompt rows advanced per "
                              "admission tick (bounds how long a newly "
                              "admitted prompt can stall decoding slots)")
    fe_args.add_argument("--monolithic-prefill", action="store_true",
                         help="disable chunking: prefill whole prompts in "
                              "one tick (the A/B contrast arm)")
    fe_args.add_argument("--arrival-rate", type=float, default=0.0,
                         help="--frontend: synthetic request arrivals per "
                              "second (seeded Poisson process; 0 = all "
                              "submitted at once)")
    fe_args.add_argument("--arrival-seed", type=int, default=0,
                         help="--frontend: seed for the arrival process")
    fe_args.add_argument("--stdin-requests", action="store_true",
                         help="read JSONL requests from stdin "
                              '({"rid", "prompt", "max_new", ...}) and '
                              'stream {"rid", "token"} JSONL to stdout')
    fe_args.add_argument("--http-port", type=int, default=None,
                         help="serve a minimal HTTP API on 127.0.0.1: "
                              "POST /generate with a JSON request body; "
                              "Ctrl-C to stop")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh

        if args.mesh == "auto":
            mesh = make_host_mesh()
        else:
            data, model_ext = (int(x) for x in args.mesh.split(","))
            mesh = jax.make_mesh((data, model_ext), ("data", "model"))
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"over {mesh.devices.size} devices")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fmt = FXP16 if args.fxp16 else FXP8

    if args.mode == "exact":
        ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
        policy = None
    else:
        policy = resolve_policy(args, model, params, fmt)
        ctx = EngineContext(mode=args.mode, policy=policy, compute_dtype=jnp.float32)

    controller = None
    bank = None
    speculate = None
    if args.adaptive or args.speculative:
        what = "--adaptive/--speculative"
        if args.mode == "exact":
            raise SystemExit(f"{what} needs --mode carmen|int8|kernel")
        if args.per_call:
            raise SystemExit(f"--per-call contradicts {what}: the multi-point "
                             "bank IS the prepared path")
        from repro.runtime import ControllerConfig, ModeController, build_bank, default_points

        calibration = None
        if args.calibration:
            from repro.sim import load_calibration

            calibration = load_calibration(args.calibration)
            print(f"cycle calibration: {calibration['id']} "
                  f"(from {args.calibration})")
        # int8 caps at 8 effective bits: an FXP16 point would cost 1.75x
        # cycles for bit-identical arithmetic, so the ladder drops it
        hifi = None if args.mode == "int8" else FXP16
        bank = build_bank(
            params, args.mode,
            default_points(fmt, base_policy=policy, hifi_fmt=hifi),
            specs=model.specs(), mesh=mesh, calibration=calibration,
        )
        print(f"bank: points={bank.names} shared_leaves={bank.shared_leaves}/"
              f"{bank.unique_leaves} rel_cycles="
              f"{ {n: round(bank.rel_cycles(n), 3) for n in bank.names} }")
        if args.adaptive:
            controller = ModeController(bank, ControllerConfig(
                cycle_budget=args.cycle_budget,
                # speculative rounds draft cheap from the first step; the
                # verify point guards accuracy regardless
                start=bank.names[0] if args.speculative else None,
            ))
    if args.speculative:
        from repro.spec import SpecConfig

        speculate = SpecConfig(draft_len=args.draft_len,
                               draft_point=args.draft_point)

    resilience = None
    if args.deadline_ms is not None or args.queue_limit is not None or args.degrade:
        from repro.resilience import ResilienceConfig

        resilience = ResilienceConfig(
            queue_limit=args.queue_limit,
            shed_policy=args.shed_policy,
            default_deadline_s=(args.deadline_ms / 1000.0
                                if args.deadline_ms is not None else None),
        )
    if args.degrade:
        if bank is None:
            raise SystemExit("--degrade needs a multi-point bank: add "
                             "--adaptive or --speculative")
        from repro.resilience import DegradationConfig, DegradationPolicy
        from repro.runtime import ControllerConfig, ModeController

        # without --adaptive the inner controller pins the reference point —
        # degradation then is the only thing moving the ladder
        inner = controller or ModeController(
            bank, ControllerConfig(pin=bank.reference))
        controller = DegradationPolicy(
            inner, DegradationConfig(floor=args.degrade_floor))

    server = BatchedServer(
        model, ctx, params, slots=args.slots,
        max_len=args.prompt_len + args.max_new
        + (args.draft_len if args.speculative else 0) + 2,
        burst=args.burst,
        prepare_weights=not args.per_call,
        controller=controller,
        speculate=speculate,
        bank=bank,
        mesh=mesh,
        resilience=resilience,
    )
    if server.shardings is not None:
        from repro.sharding.partition import serving_sharding_report

        print("sharding:", json.dumps(serving_sharding_report(server.shardings)))
    observer = None
    want_trace = bool(args.trace_out or args.chrome_trace)
    if args.metrics or args.metrics_out or want_trace:
        from repro.obs import ServingObserver

        # trace_sink: the JSONL trace is flushed there even if the run
        # raises, so crashed-run traces stay replayable
        observer = ServingObserver(trace=want_trace, trace_sink=args.trace_out)
        server.observer = observer
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            i, rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            args.max_new, temperature=args.temperature,
            seed=None if args.seed is None else args.seed + i,
        )
        for i in range(args.requests)
    ]
    use_frontend = args.frontend or args.stdin_requests or args.http_port
    if use_frontend and mesh is not None:
        raise SystemExit("the streaming frontend is single-device for now: "
                         "drop --mesh or drop --frontend/--stdin-requests/"
                         "--http-port")
    if args.profile:
        jax.profiler.start_trace(args.profile)
    t0 = time.time()
    try:
        if use_frontend:
            results = _serve_frontend(args, server, reqs)
        else:
            results = server.run(reqs)
    finally:
        if args.profile:
            jax.profiler.stop_trace()
            print(f"jax profiler trace written to {args.profile}")
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    weights = "adaptive" if args.adaptive else ("per-call" if args.per_call else "prepared")
    serving = "speculative " if args.speculative else ""
    print(f"served {len(results)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s, mode={args.mode}, "
          f"burst={args.burst}, {server.host_transfers} host round-trips, "
          f"{serving}{weights} weights)")
    if resilience is not None:
        statuses: dict = {}
        for o in server.outcomes.values():
            statuses[o.status] = statuses.get(o.status, 0) + 1
        met = sum(1 for o in server.outcomes.values() if o.deadline_met)
        print(f"outcomes: {statuses}; deadline_met {met}/"
              f"{len(server.outcomes)}")
        shed = {rid: o.reason for rid, o in sorted(server.outcomes.items())
                if o.status in ("shed", "faulted", "expired")}
        if shed:
            print(f"shed/evicted reasons: {shed}")
        if args.degrade:
            print(f"degradation: cap={controller.cap} "
                  f"demotions={controller.demotions} "
                  f"promotions={controller.promotions}")
    if server.telemetry is not None:
        print("telemetry:", json.dumps(server.telemetry.summary()))
    if server.spec_telemetry is not None:
        print("speculative:", json.dumps(server.spec_telemetry.summary()))
    if observer is not None:
        if observer.trace is not None and mesh is not None:
            # the mesh cost block rides on the trace header: collective bytes
            # of the compiled decode burst, next to the sharding report
            observer.trace.attach("collectives", server.collective_snapshot())
        for out in (args.metrics_out, args.trace_out, args.chrome_trace):
            if out and os.path.dirname(out):
                os.makedirs(os.path.dirname(out), exist_ok=True)
        if args.metrics or args.metrics_out:
            snap = observer.snapshot()
            if args.metrics:
                print("metrics:", json.dumps(snap["metrics"]))
            if args.metrics_out:
                with open(args.metrics_out, "w") as f:
                    json.dump(snap, f, indent=1)
                print(f"metrics snapshot written to {args.metrics_out}")
        if args.trace_out:
            observer.trace.write_jsonl(args.trace_out)
            print(f"serve trace (JSONL) written to {args.trace_out}")
        if args.chrome_trace:
            observer.trace.write_chrome(args.chrome_trace)
            print(f"chrome trace written to {args.chrome_trace}")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid][:8]}...")
    return results


if __name__ == "__main__":
    main()
