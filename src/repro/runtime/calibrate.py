"""Serving-side §III sensitivity scan: calibrate a policy at server startup.

The JVP-based :func:`repro.core.precision_policy.sensitivity_scan` needs a
per-layer noise-injection hook that the big transformer families do not
expose. For serving we measure the same quantity the direct way: demote one
engine dot *group* (all stacked layers of e.g. ``layer.mlp.up`` share a
policy name) to approximate depth, run the calibration batch, and record the
normalized logit perturbation. One forward per group — a handful of forwards
on a calibration batch — and the resulting sensitivities feed
``assign_depths`` exactly like the JVP scan does.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext
from repro.core.backends import iter_dot_weights
from repro.core.cordic import approx_depth, full_depth
from repro.core.fxp import FXP8, FxPFormat
from repro.core.precision_policy import LayerPrecision, PrecisionPolicy

__all__ = ["calibration_scan"]


def calibration_scan(
    model,
    params,
    tokens,
    *,
    fmt: FxPFormat = FXP8,
    mode: str = "carmen",
) -> Dict[str, float]:
    """name -> normalized logit perturbation when that group runs approximate.

    ``tokens``: (B, S) int32 calibration batch. Uses the per-call engine path
    (no prepare needed — this runs once at startup, before the bank is built).
    """
    names = sorted({name for _, name, _, _, _ in iter_dot_weights(params, specs=model.specs())})
    if isinstance(params, dict) and "lm_head" not in params and "embed" in params:
        names.append("lm_head")
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}

    def logits_at(policy: PrecisionPolicy) -> np.ndarray:
        ctx = EngineContext(mode=mode, policy=policy, compute_dtype=jnp.float32)
        out, _ = model.forward(params, batch, ctx)
        return np.asarray(out, np.float32)

    accurate = LayerPrecision(fmt, full_depth(fmt))
    base = logits_at(PrecisionPolicy(accurate))
    base_norm = float(np.linalg.norm(base)) + 1e-9

    sens: Dict[str, float] = {}
    demoted = LayerPrecision(fmt, approx_depth(fmt))
    for name in names:
        perturbed = logits_at(PrecisionPolicy(accurate, {name: demoted}))
        sens[name] = float(np.linalg.norm(perturbed - base)) / base_norm
    return sens
