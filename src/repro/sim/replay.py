"""Replay a ``carmen-serve-trace`` through the simulated PE array.

The replayer streams a serving trace (:func:`repro.obs.iter_trace` — O(1)
memory) and schedules every recorded span onto an :class:`ArrayConfig`:

* **prefill spans** — one pass of the whole weight bank at the span's
  execution point for the padded bucket's positions (the engine pads
  prompts to pow2 buckets; the array pays for the padding, so does the sim).
  Streaming-frontend traces carry ``prefill_chunk`` spans instead (one pass
  per chunk bucket; only the final chunk syncs the host) — both vocabularies
  replay, and ``admission_tick`` instants are counted.
* **burst spans** — ``steps`` bank passes with ``slots`` activation rows
  each (the burst scan computes every slot row every step, drained or not —
  the sim charges what the engine executes, not what it emits).
* **speculative rounds** — ``draft_len`` single-step passes at the draft
  point plus one multi-position verify pass at the verify point
  (``slots * (draft_len+1)`` rows).
* **controller switches** — ``switch_cycles`` each; **host round-trips** —
  ``host_sync_cycles`` per synced span, kept in their own phase (array
  idle, excluded from savings, included in predicted wall).

Traces are self-contained: the header's ``engine`` block (per-weight shape +
per-point depth/bits table, written by ``BatchedServer``) supplies the cost
model inputs, so replay needs no model reconstruction.

Attribution comes out per phase (prefill / decode / spec_draft / spec_verify
/ switch / host_sync), per execution point (with the measured wall time of
the same spans next to the predicted cycles), per layer, and per request
(span cost split proportionally over the tokens each request landed in it).

Two accountings come out of one replay, on purpose:

* **Totals / phases / layers / requests** charge what the array *executes*:
  padded prefill buckets, drained-but-computed slot rows, host idle. That
  is the honest utilization picture (PE occupancy, stalls).
* **Savings** (``est_cycle_savings_frac``) charges what the serving loop's
  telemetry charges — emitted tokens, at the simulator's per-token bank-pass
  cost for the executed point vs the reference point. Same token weighting
  as ``TelemetryRecorder``/``SpecTelemetry``, so the simulator's savings is
  directly comparable to the reported value and the comparison isolates
  exactly the *cost model* (depths, formats, overheads, stalls): drift
  beyond tolerance means the cycle model disagrees, not that the two sides
  counted different tokens. ``bench_sim`` gates this drift in CI.

CLI::

    python -m repro.sim.replay trace.jsonl --report [--json out.json]
        [--calibration calib.json] [--pes 256]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.obs.trace import iter_trace

from .array import ArrayConfig, CostBreakdown, dot_pass_cost

__all__ = ["ReplayResult", "replay_trace"]


@dataclasses.dataclass
class ReplayResult:
    """Everything one replay produced (JSON-able via ``analyze.report_dict``)."""

    meta: Dict                      # the trace's run metadata
    config: Dict                    # ArrayConfig as a dict
    totals: Dict                    # cycle totals + occupancy
    phases: Dict[str, float]        # phase -> array cycles
    points: Dict[str, Dict]         # point -> predicted + measured aggregates
    layers: Dict[str, float]        # layer -> array cycles
    requests: Dict[str, Dict]       # rid -> tokens + attributed cycles
    counts: Dict[str, int]
    savings: Dict                   # predicted vs reported savings_frac
    measured: Dict                  # wall clock derived from the trace itself


class _BankCost:
    """Per-point bank-pass costs from the trace header's engine block."""

    def __init__(self, engine: Dict, cfg: ArrayConfig):
        self.cfg = cfg
        self.reference = engine["reference"]
        self.point_names = list(engine["points"])
        self.layers = engine["layers"]
        self._cache: Dict[Tuple[str, int], Tuple[CostBreakdown, List]] = {}

    def resolve(self, point: Optional[str]) -> str:
        if point is None:
            return "static" if "static" in self.point_names else self.reference
        return point

    def per_token(self, point: str) -> float:
        """Cycles one token (one activation row) costs through the bank at
        ``point`` — the simulator's refinement of the bank's
        ``cycles_per_token`` analytic estimate."""
        return self.pass_cost(point, 1)[0].total

    def pass_cost(self, point: str, positions: int):
        """(total CostBreakdown, [(layer, cycles)]) of one bank pass."""
        key = (point, positions)
        if key not in self._cache:
            total = CostBreakdown()
            per_layer = []
            for row in self.layers:
                shape = row["shape"]
                if len(shape) == 1:
                    k, n, reps = 1, shape[0], 1
                else:
                    k, n = shape[-2], shape[-1]
                    reps = 1
                    for s in shape[:-2]:
                        reps *= s
                pt = row["points"].get(point)
                if pt is None:  # point unknown to this layer: price at ref
                    pt = row["points"][self.reference]
                c = dot_pass_cost(self.cfg, k, n, pt["depth"],
                                  positions=positions, bits=pt.get("bits", 8),
                                  reps=reps)
                total = total + c
                per_layer.append((row["layer"], c.total))
            self._cache[key] = (total, per_layer)
        return self._cache[key]


class _Replayer:
    def __init__(self, header: Dict, cfg: ArrayConfig):
        meta = header.get("run") or header.get("meta") or {}
        engine = meta.get("engine")
        if engine is None:
            raise ValueError(
                "trace carries no engine cost table — record it with a "
                "precision-mode server (carmen/int8/kernel); exact-mode "
                "traces have no depth knob to attribute cycles to")
        self.header = header
        self.meta = meta
        self.cfg = cfg
        self.bank = _BankCost(engine, cfg)
        self.slots = int(meta.get("slots", 1))
        self.draft_len = int(meta.get("draft_len", 0))
        self.verify_point = meta.get("verify_point")
        # accumulators
        self.phase: Dict[str, float] = {}
        self.points: Dict[str, Dict] = {}
        self.layers: Dict[str, float] = {}
        self.requests: Dict[str, Dict] = {}
        self.counts = {"prefills": 0, "prefill_chunks": 0, "bursts": 0,
                       "spec_rounds": 0, "switches": 0, "tokens": 0,
                       "admission_ticks": 0}
        self.breakdown = CostBreakdown()
        self.host_cycles = 0.0
        self.switch_cycles = 0.0
        # savings accounting (vs reference): the adaptive mirror covers
        # prefill + decode bursts (what TelemetryRecorder charges), the
        # speculative mirror covers draft/verify rounds (SpecTelemetry)
        self.est_cycles = 0.0
        self.baseline_cycles = 0.0
        self.spec_est = 0.0
        self.spec_baseline = 0.0
        self.run_span = [None, None]
        self._open: Dict[Tuple[str, str], Dict] = {}
        self._pending_tokens: Dict[str, int] = {}
        self._prefill_point: Dict[str, str] = {}

    # -- charging -------------------------------------------------------------

    def _point_acc(self, point: str) -> Dict:
        return self.points.setdefault(point, {
            "cycles": 0.0, "steps": 0, "spans": 0, "tokens": 0, "wall_s": 0.0})

    def _req_acc(self, rid) -> Dict:
        return self.requests.setdefault(str(rid), {"tokens": 0, "cycles": 0.0})

    def _charge(self, phase: str, point: str, positions: int, steps: int,
                *, wall_s: float, tokens: int, rid=None) -> None:
        cost, per_layer = self.bank.pass_cost(point, positions)
        cost = cost.scale(steps)
        self.breakdown = self.breakdown + cost
        self.phase[phase] = self.phase.get(phase, 0.0) + cost.total
        for name, cyc in per_layer:
            self.layers[name] = self.layers.get(name, 0.0) + cyc * steps
        acc = self._point_acc(point)
        acc["cycles"] += cost.total
        acc["steps"] += steps
        acc["spans"] += 1
        acc["tokens"] += tokens
        acc["wall_s"] += wall_s
        # request attribution: full span to rid (prefill), else proportional
        # to tokens landed in the span
        if rid is not None:
            self._req_acc(rid)["cycles"] += cost.total
        elif self._pending_tokens:
            landed = sum(self._pending_tokens.values())
            for r, ntok in self._pending_tokens.items():
                req = self._req_acc(r)
                req["tokens"] += ntok
                req["cycles"] += cost.total * ntok / landed

    def _charge_savings(self, point: str, tokens: int) -> None:
        """Token-weighted savings accounting (the TelemetryRecorder mirror:
        tokens at the sim's per-token cost for ``point`` vs reference)."""
        if tokens <= 0:
            return
        self.est_cycles += tokens * self.bank.per_token(point)
        self.baseline_cycles += tokens * self.bank.per_token(self.bank.reference)

    # -- event dispatch -------------------------------------------------------

    def feed(self, ev: Dict) -> None:
        ph, name, track = ev["ph"], ev["name"], ev.get("track", "engine")
        args = ev.get("args", {})
        if ph == "B":
            self._open[(track, name)] = {"ts": ev["ts"], **args}
            if name in ("burst", "spec"):
                self._pending_tokens = {}
            elif name == "run":
                self.run_span[0] = ev["ts"]
            return
        if ph == "I":
            self._instant(name, args)
            return
        span = self._open.pop((track, name), {"ts": ev["ts"]})
        merged = {**span, **args}  # close_open Es carry no args: B's stand in
        wall = ev["ts"] - span["ts"]
        if name == "prefill":
            point = self.bank.resolve(merged.get("point"))
            bucket = int(merged.get("bucket", 1))
            self.counts["prefills"] += 1
            self._charge("prefill", point, bucket, 1, wall_s=wall, tokens=1,
                         rid=merged.get("rid"))
            # savings charge (prompt_len tokens) lands on the
            # request_prefilled instant that follows — it carries the
            # unpadded length the telemetry charged
            self._prefill_point[str(merged.get("rid"))] = point
            self.host_cycles += self.cfg.host_sync_cycles
        elif name == "prefill_chunk":
            # chunked (streaming-frontend) prefill: one bank pass per chunk
            # at the chunk's padded bucket; only the FINAL chunk runs the
            # admit program and syncs the host, so only it counts as a
            # completed prefill / pays host_sync. The request_prefilled
            # instant that follows the final chunk carries the savings
            # charge, same as the monolithic span.
            point = self.bank.resolve(merged.get("point"))
            bucket = int(merged.get("bucket", 1))
            final = bool(merged.get("final"))
            self.counts["prefill_chunks"] += 1
            self._charge("prefill", point, bucket, 1, wall_s=wall,
                         tokens=1 if final else 0, rid=merged.get("rid"))
            if final:
                self.counts["prefills"] += 1
                self._prefill_point[str(merged.get("rid"))] = point
                self.host_cycles += self.cfg.host_sync_cycles
        elif name == "burst":
            point = self.bank.resolve(merged.get("point"))
            steps = int(merged.get("steps", 0))
            tokens = int(merged.get("tokens", 0))
            if steps:
                self.counts["bursts"] += 1
                self._charge("decode", point, self.slots, steps,
                             wall_s=wall, tokens=tokens)
                self._charge_savings(point, tokens)
                self.host_cycles += self.cfg.host_sync_cycles
        elif name == "spec":
            self._spec_round(merged, wall)
            self.host_cycles += self.cfg.host_sync_cycles
        elif name == "run":
            self.run_span[1] = ev["ts"]

    def _spec_round(self, merged: Dict, wall: float) -> None:
        draft = self.bank.resolve(merged.get("point"))
        verify = self.bank.resolve(self.verify_point)
        tokens = int(merged.get("tokens", 0))
        active = len(merged.get("accepted") or []) or self.slots
        k = self.draft_len
        self.counts["spec_rounds"] += 1
        # k draft steps (all slot rows), then one verify pass over
        # slots * (k+1) positions
        self._charge("spec_draft", draft, self.slots, k, wall_s=wall,
                     tokens=0)
        self._charge("spec_verify", verify, self.slots * (k + 1), 1,
                     wall_s=0.0, tokens=tokens)
        # savings: the SpecTelemetry mirror in sim units — per active slot,
        # k draft tokens + one verify token vs the emitted tokens served at
        # the verify point
        self.spec_est += active * (k * self.bank.per_token(draft)
                                   + self.bank.per_token(verify))
        self.spec_baseline += tokens * self.bank.per_token(verify)

    def _instant(self, name: str, args: Dict) -> None:
        if name == "tokens":
            rid = str(args.get("rid"))
            n = int(args.get("n", 0))
            self._pending_tokens[rid] = self._pending_tokens.get(rid, 0) + n
            self.counts["tokens"] += n
        elif name == "request_prefilled":
            req = self._req_acc(args.get("rid"))
            req["tokens"] += 1
            req["prompt_len"] = args.get("prompt_len")
            self.counts["tokens"] += 1
            point = self._prefill_point.pop(str(args.get("rid")), None)
            if point is not None:
                self._charge_savings(point, int(args.get("prompt_len") or 0))
        elif name == "controller_switch":
            self.counts["switches"] += 1
            self.switch_cycles += self.cfg.switch_cycles
            self.phase["switch"] = self.phase.get("switch", 0.0) \
                + self.cfg.switch_cycles
        elif name == "request_submitted":
            self._req_acc(args.get("rid"))["prompt_len"] = args.get("prompt_len")
        elif name == "admission_tick":
            self.counts["admission_ticks"] += 1

    # -- result ---------------------------------------------------------------

    def result(self) -> ReplayResult:
        bd = self.breakdown
        array_cycles = bd.total + self.switch_cycles
        total_cycles = array_cycles + self.host_cycles
        self.phase["host_sync"] = self.host_cycles
        occupancy = (bd.ideal_macs / (self.cfg.n_pes * array_cycles)
                     if array_cycles > 0 else 0.0)
        reported = {rec.get("kind"): rec
                    for rec in self.header.get("telemetry") or []}

        def _savings(est, baseline, kind):
            frac = 1.0 - est / baseline if baseline > 0 else 0.0
            rec = reported.get(kind)
            rel_diff = None
            if rec is not None and rec.get("est_cycle_savings_frac"):
                r = float(rec["est_cycle_savings_frac"])
                rel_diff = abs(frac - r) / max(abs(r), 1e-12)
            return {
                "est_cycles": est,
                "baseline_cycles": baseline,
                "est_cycle_savings_frac": frac,
                "reported": rec,
                "rel_diff_vs_reported": rel_diff,
            }

        adaptive = _savings(self.est_cycles, self.baseline_cycles, "adaptive")
        wall = None
        if self.run_span[0] is not None and self.run_span[1] is not None:
            wall = self.run_span[1] - self.run_span[0]
        sec = self.cfg.sec_per_cycle
        return ReplayResult(
            meta={kk: v for kk, v in self.meta.items() if kk != "engine"},
            config=dataclasses.asdict(self.cfg),
            totals={
                "array_cycles": array_cycles,
                "host_sync_cycles": self.host_cycles,
                "total_cycles": total_cycles,
                "compute_cycles": bd.compute,
                "weight_stall_cycles": bd.weight_stall,
                "af_stall_cycles": bd.af_stall,
                "switch_cycles": self.switch_cycles,
                "ideal_macs": bd.ideal_macs,
                "pe_occupancy": occupancy,
                "predicted_wall_s": (total_cycles * sec
                                     if sec is not None else None),
            },
            phases=dict(self.phase),
            points={p: dict(a) for p, a in self.points.items()},
            layers=dict(self.layers),
            requests=dict(self.requests),
            counts=dict(self.counts),
            savings={
                "reference": self.bank.reference,
                **adaptive,
                "speculative": (_savings(self.spec_est, self.spec_baseline,
                                         "speculative")
                                if self.counts["spec_rounds"] else None),
            },
            measured={
                "wall_s": wall,
                "tokens": self.counts["tokens"],
                "tok_s": (self.counts["tokens"] / wall
                          if wall and wall > 0 else None),
            },
        )


def replay_trace(path: str, *, cfg: Optional[ArrayConfig] = None,
                 calibration: Optional[Dict] = None) -> ReplayResult:
    """Replay the trace at ``path`` onto ``cfg`` (default: 256-PE array built
    from ``calibration``, or the ideal analytic array). Streaming: the event
    list is never materialized."""
    if cfg is None:
        cfg = ArrayConfig.from_calibration(calibration)
    with iter_trace(path) as tr:
        rp = _Replayer(tr.header, cfg)
        for ev in tr:
            rp.feed(ev)
    return rp.result()


def main(argv: Optional[list] = None) -> None:
    from . import analyze
    from .calibrate import load_calibration

    ap = argparse.ArgumentParser(
        description="Replay a carmen-serve-trace through the PE-array "
                    "simulator")
    ap.add_argument("trace", help="carmen-serve-trace JSONL path")
    ap.add_argument("--report", action="store_true",
                    help="print the human-readable attribution report")
    ap.add_argument("--json", default=None,
                    help="write the full structured report to this path")
    ap.add_argument("--calibration", default=None,
                    help="repro.sim.calibrate export to build the array from")
    ap.add_argument("--pes", type=int, default=256)
    args = ap.parse_args(argv)

    calibration = load_calibration(args.calibration) if args.calibration \
        else None
    cfg = ArrayConfig.from_calibration(calibration, n_pes=args.pes)
    result = replay_trace(args.trace, cfg=cfg)
    report = analyze.report_dict(result)
    if args.json:
        import os

        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    if args.report or not args.json:
        print(analyze.render(result))
    else:
        print(json.dumps(report["totals"], indent=2))


if __name__ == "__main__":
    main()
