"""Scaled-integer quantization substrate (quant/)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import (
    QuantizedLinear,
    dequantize_params,
    fake_quant,
    quantize_params_int8,
)


def test_fake_quant_roundtrip_error(rng):
    x = rng.standard_normal((64, 64)).astype(np.float32)
    q = np.asarray(fake_quant(jnp.asarray(x), bits=8))
    # symmetric 8-bit: error <= scale/2 = max|x|/127/2
    assert np.max(np.abs(q - x)) <= np.abs(x).max() / 127.0 / 2 + 1e-6


def test_fake_quant_ste_gradient(rng):
    x = jnp.asarray(rng.standard_normal((16,)).astype(np.float32))
    g = jax.grad(lambda x: jnp.sum(fake_quant(x) ** 2))(x)
    # STE: gradient ~ 2*q(x) but nonzero and finite everywhere
    assert np.isfinite(np.asarray(g)).all() and np.any(np.asarray(g) != 0)


def test_quantize_params_int8_structure(rng):
    params = {
        "w": rng.standard_normal((32, 16)).astype(np.float32),
        "norm": rng.standard_normal((16,)).astype(np.float32),
    }
    q = quantize_params_int8(jax.tree.map(jnp.asarray, params))
    assert q["w"]["qvalue"].dtype == jnp.int8
    assert q["norm"]["qscale"] is None  # 1-D criticality-pinned leaves stay float
    back = dequantize_params(q)
    rel = np.abs(np.asarray(back["w"]) - params["w"]).max() / np.abs(params["w"]).max()
    assert rel < 0.01


def test_quantized_linear_matches_float(rng):
    w = rng.uniform(-1, 1, (64, 32)).astype(np.float32)
    x = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
    ql = QuantizedLinear.from_float(jnp.asarray(w))
    out = np.asarray(ql(jnp.asarray(x)))
    rel = np.abs(out - x @ w).max() / (np.abs(x @ w).max() + 1e-9)
    assert rel < 0.03


def test_quantized_linear_effective_bits_degrade(rng):
    w = rng.uniform(-1, 1, (64, 32)).astype(np.float32)
    x = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
    ql = QuantizedLinear.from_float(jnp.asarray(w))
    errs = [
        np.abs(np.asarray(ql(jnp.asarray(x), effective_bits=b)) - x @ w).mean()
        for b in (8, 5, 3)
    ]
    assert errs[0] < errs[1] < errs[2]
