"""deepseek-v3-671b [arXiv:2412.19437; hf] — MLA + 1 shared + 256 routed top-8 MoE.

First 3 layers are dense (d_ff 18432); the remaining 58 are MoE with 2048-wide
experts. MTP head is out of scope for the serving/training steps measured here
(single-token objective), noted in DESIGN.md.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=1e4,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        first_dense_layers=3,
        moe_every=1,
        d_ff_dense=18432,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)
