"""Unified model API over all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext

from . import blocks, encdec, mamba2, mla, params as P, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def specs(self):
        if self.cfg.family == "audio":
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def init(self, key, dtype=jnp.float32):
        return P.init(self.specs(), key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return P.abstract(self.specs(), dtype)

    def param_axes(self):
        return P.axes_tree(self.specs())

    def count_params(self) -> int:
        return P.count_params(self.specs())

    # -- compute ------------------------------------------------------------
    def forward(self, prms, batch, ctx: EngineContext, *, remat: bool = False):
        if self.cfg.family == "audio":
            return encdec.forward(prms, batch, self.cfg, ctx, remat=remat)
        return transformer.forward(prms, batch, self.cfg, ctx, remat=remat)

    def decode_step(self, prms, tokens, cache, ctx: EngineContext):
        if self.cfg.family == "audio":
            return encdec.decode_step(prms, tokens, cache, self.cfg, ctx)
        return transformer.decode_step(prms, tokens, cache, self.cfg, ctx)

    def make_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16, abstract: bool = False):
        if self.cfg.family == "audio":
            return encdec.make_cache(self.cfg, batch, max_len, dtype, abstract=abstract)
        return transformer.make_cache(self.cfg, batch, max_len, dtype, abstract=abstract)


def get_model(cfg: ModelConfig) -> ModelApi:
    cfg.validate()
    return ModelApi(cfg)


__all__ = ["ModelApi", "get_model", "blocks", "encdec", "mamba2", "mla", "transformer"]
