"""jit'd wrappers around the fused dot+AF kernel.

``fused_dot_af`` is the Pallas path (interpret on CPU, native on TPU);
``fused_dot_af_ref`` is the bitwise-identical pure-XLA chain used as the
mesh/oversize fallback and as the parity oracle in tests.

The per-point parameters arrive as a traced int32 vector (scalar-prefetch
operand on TPU), so swapping execution points never retraces or recompiles —
the zero-cost half of the ModeController switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fxp import FXP8, FxPFormat

from . import kernel as _k
from . import ref as _ref

DEFAULT_BM = 128
DEFAULT_BN = 128
# full-K tiles: keep x(bm,K) + w(K,bn) + out under a few MiB of VMEM
FUSE_MAX_K = 4096


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    # cached: jax.default_backend() walks the backend registry on every call,
    # and this probe sits on the per-layer hot path
    return jax.default_backend() == "cpu"


def fuse_supported(k: int) -> bool:
    """Whether the contraction dim fits the kernel's full-K VMEM tiles."""
    return k <= FUSE_MAX_K


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_to(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _grid_call(kernel_fn, grid, bm, kp, bn, out_shape, interpret):
    """Build the pallas_call, preferring the scalar-prefetch grid spec."""
    in_specs = [
        pl.BlockSpec((bm, kp), lambda i, j, *_: (i, 0)),
        pl.BlockSpec((kp, bn), lambda i, j, *_: (0, j)),
    ]
    out_specs = pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j))
    try:
        from jax.experimental.pallas import tpu as pltpu

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_specs,
        )
        return pl.pallas_call(
            kernel_fn, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )
    except ImportError:  # pragma: no cover - non-TPU pallas builds
        return pl.pallas_call(
            kernel_fn,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "af_mode", "af_depth", "af_fmt", "compute_round", "interpret",
        "bm", "bn",
    ),
)
def fused_dot_af(
    x,
    w,
    point,
    *,
    af_mode: str = "identity",
    af_depth: int = 8,
    af_fmt: FxPFormat = FXP8,
    compute_round: bool = False,
    interpret: bool | None = None,
    bm: int | None = None,
    bn: int | None = None,
):
    """Fused prepared dot + activation: float (..., K) x (K, N) -> f32 (..., N).

    ``w`` carries signed-digit grid values (a prepared weight bank); ``point``
    is the int32[5] vector from :func:`make_point` carrying the execution
    point's dot depth and quantization formats.  ``af_mode`` selects the
    epilogue branch; its index is appended to the params vector so the
    compiled kernel itself is mode-agnostic.
    """
    interpret = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    params = jnp.concatenate(
        [jnp.asarray(point, jnp.int32).reshape(_k.POINT_LEN),
         jnp.asarray([_k.FUSED_AFS.index(af_mode)], jnp.int32)]
    )

    bm = bm or min(DEFAULT_BM, _round_up(m, 8))
    bn = bn or min(DEFAULT_BN, _round_up(n, 128))
    kp = _round_up(k, 128)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)

    x2 = _pad_to(x2.astype(jnp.float32), mp, kp)
    wp = _pad_to(jnp.asarray(w, jnp.float32), kp, np_)

    call = _grid_call(
        functools.partial(
            _k.fused_kernel, af_depth=af_depth, af_fmt=af_fmt,
            compute_round=compute_round,
        ),
        grid=(mp // bm, np_ // bn),
        bm=bm, kp=kp, bn=bn,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )
    out = call(params, x2, wp)
    return out[:m, :n].reshape(lead + (n,))


fused_dot_af_ref = jax.jit(
    _ref.fused_dot_af_ref,
    static_argnames=("af_mode", "af_depth", "af_fmt", "compute_round"),
)
