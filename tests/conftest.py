import os

# Tests run on the single real CPU device. (The 512-device dry-run sets its own
# XLA_FLAGS before any jax import — see src/repro/launch/dryrun.py; it must NOT
# be set here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
