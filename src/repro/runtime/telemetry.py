"""Serving telemetry: mode occupancy, MAC-cycle accounting, switch counts.

Cycle model: one iteration of the iterative CORDIC PE is one cycle, so a
K-length dot at depth d costs K*(d+1) cycles (``repro.core.mac.mac_cycles``).
A weight tensor of N output channels therefore costs numel(W)*(d+1) cycles
per token pushed through it. :func:`estimate_point_cycles` folds that over
every engine-routed weight at a policy's per-layer depths — the quantity the
paper's 33%-cycle-reduction claim is stated in, and the one the mode
controller budgets against.

The analytic constants can be refined by a ``repro.sim.calibrate`` export:
:func:`estimate_point_cycles` accepts the calibration dict and folds its
``mac_overhead`` (extra cycles per MAC beyond the depth+1 pipeline) into the
per-leaf charge, and every telemetry record names which calibration (or
``"analytic"``) produced its ``est_cycles`` so records stay comparable
across runs. The calibrated model is a per-MAC affine refinement of the
analytic one, so relative point costs — the only thing the ModeController's
ladder ordering and hysteresis consume — are perturbed but never reordered
for sane overheads (test-asserted bit-identity for pinned controllers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.backends import iter_dot_weights
from repro.core.precision_policy import PrecisionPolicy

__all__ = ["TelemetryRecorder", "calibration_id", "estimate_point_cycles",
           "layer_cost_table", "teacher_forced_agreement"]


def calibration_id(calibration: Optional[Dict]) -> str:
    """The provenance tag a telemetry record carries for its cycle model."""
    if calibration is None:
        return "analytic"
    return str(calibration.get("id", "calibrated"))


def _mac_overhead(calibration: Optional[Dict]) -> float:
    if calibration is None:
        return 0.0
    return float(calibration.get("constants", {}).get("mac_overhead", 0.0))


def _iter_costed_weights(params, *, specs=None):
    """Yield ``(name, shape)`` for every engine-routed weight the cycle model
    charges: the ``iter_dot_weights`` leaves plus the tied-embedding lm_head
    (raw trees don't materialize it; the engine still pays its dot)."""
    for _, name, leaf, _, _ in iter_dot_weights(params, specs=specs):
        yield name, tuple(int(s) for s in leaf.shape)
    if isinstance(params, dict) and "lm_head" not in params and "embed" in params:
        embed = params["embed"]
        if hasattr(embed, "shape") and getattr(embed, "ndim", 0) == 2:
            v, d = (int(s) for s in embed.shape)
            yield "lm_head", (d, v)


def estimate_point_cycles(params, policy: PrecisionPolicy, *, specs=None,
                          calibration: Optional[Dict] = None) -> float:
    """Estimated engine MAC cycles per decoded token under ``policy``.

    Walks the same leaves ``prepare_params`` formats (plus the tied-embedding
    lm_head) and charges numel * (depth + 1) per leaf — the iterative-PE
    cycle model. Works on raw or prepared trees (both expose ``.shape``).

    ``calibration`` (a ``repro.sim.calibrate`` export) refines the constant:
    the charge becomes numel * (mac_overhead + depth + 1), where
    ``mac_overhead`` is the fitted per-MAC pipeline overhead. With
    ``calibration=None`` the analytic model (overhead 0) is unchanged.
    """
    overhead = _mac_overhead(calibration)
    total = 0.0
    for name, shape in _iter_costed_weights(params, specs=specs):
        depth = policy.for_layer(name).depth
        total += float(np.prod(shape)) * (overhead + depth + 1)
    return total


def layer_cost_table(params, policies: Dict[str, PrecisionPolicy], *,
                     specs=None) -> List[Dict]:
    """Per-weight cost table for the trace header's ``engine`` block.

    One JSON-able row per engine-routed weight leaf: its policy-resolution
    name, shape, and the (depth, format bits) each execution point runs it
    at. This is what makes a serving trace self-contained for the PE-array
    simulator — replay needs no model reconstruction, just this table.
    """
    rows = []
    for name, shape in _iter_costed_weights(params, specs=specs):
        rows.append({
            "layer": name,
            "shape": list(shape),
            "points": {
                pname: {"depth": int(pol.for_layer(name).depth),
                        "bits": int(pol.for_layer(name).fmt.bits)}
                for pname, pol in policies.items()
            },
        })
    return rows


def teacher_forced_agreement(model, ctx, tree, requests, results, margins):
    """Greedy-match rate of ``tree`` against a reference run's outputs.

    Teacher-forced: the execution point under test re-predicts every
    generated token of the reference run given the reference run's own
    prefix, so one flipped token does not cascade into the metric. Returns
    ``(overall, high_confidence, threshold, n_high)`` where tokens are split
    at the median reference top-2 margin — the "matched greedy-decode
    outputs on high-confidence tokens" quantity.

    Edge cases: requests that generated nothing are skipped (they carry no
    scorable token — a run where EVERY request is empty raises, there is no
    agreement to report); a request's margins must align one-to-one with its
    generated tokens; and when no token clears the median threshold (only
    possible with non-finite margins — the median of the scored margins
    themselves always keeps at least one at/above it), the high-confidence
    rate falls back to the overall rate with ``n_high == 0`` rather than
    averaging an empty slice.
    """
    matches, flat = [], []
    for req in requests:
        gen = np.asarray(results[req.rid], np.int32)
        if gen.size == 0:  # nothing generated: nothing to score
            continue
        req_margins = margins[req.rid]
        if len(req_margins) != gen.size:
            raise ValueError(
                f"request {req.rid}: {len(req_margins)} margins for "
                f"{gen.size} generated tokens — margins must align "
                "one-to-one with the reference run's tokens"
            )
        seq = np.concatenate([np.asarray(req.prompt, np.int32), gen])
        logits, _ = model.forward(tree, {"tokens": jnp.asarray(seq[None, :-1])}, ctx)
        pred = np.asarray(logits)[0].argmax(-1)
        start = len(req.prompt) - 1
        matches.extend(pred[start:start + len(gen)] == gen)
        flat.extend(req_margins)
    matches, flat = np.asarray(matches), np.asarray(flat, np.float64)
    if matches.size == 0:
        raise ValueError(
            "teacher_forced_agreement: no generated tokens to score (every "
            "request's generation is empty)"
        )
    thr = float(np.median(flat))
    high = flat >= thr
    overall = float(matches.mean())
    high_conf = float(matches[high].mean()) if high.any() else overall
    return overall, high_conf, thr, int(high.sum())


@dataclasses.dataclass
class TelemetryRecorder:
    """Accumulates per-step serving telemetry for one adaptive run.

    ``record_burst`` is called once per decode burst (the server's host
    round-trip granularity) with the executed point, the tokens emitted over
    the burst, and the number of scan steps it ran; ``record_step`` is the
    ``steps=1`` special case (one observation per classic decode step or
    speculative round). ``record_prefill`` charges prompt tokens without
    counting an observation or a switch. ``steps`` counts observations — one
    per burst/step/round, aligned with ``min_margins`` — and ``decode_steps``
    counts engine steps.
    Savings are relative to running every token at the bank's reference
    (all-accurate) point.
    """

    cycles_per_token: Dict[str, float]
    reference: str
    cycle_model: str = "analytic"  # which calibration produced est_cycles

    def __post_init__(self):
        self.reset()

    @classmethod
    def for_bank(cls, bank) -> "TelemetryRecorder":
        return cls(dict(bank.cycles_per_token), bank.reference,
                   getattr(bank, "cycle_model", "analytic"))

    def reset(self) -> None:
        self.steps = 0  # observations: bursts, classic steps, spec rounds
        self.decode_steps = 0
        self.switches = 0
        self.tokens_by_point: Dict[str, int] = {k: 0 for k in self.cycles_per_token}
        self.steps_by_point: Dict[str, int] = {k: 0 for k in self.cycles_per_token}
        self.est_cycles = 0.0
        self.baseline_cycles = 0.0
        self.min_margins: list = []
        self._prev_point: Optional[str] = None

    def _charge(self, point: str, tokens: int) -> None:
        self.tokens_by_point[point] += tokens
        self.est_cycles += tokens * self.cycles_per_token[point]
        self.baseline_cycles += tokens * self.cycles_per_token[self.reference]

    def record_prefill(self, point: str, tokens: int) -> None:
        self._charge(point, tokens)

    def record_burst(self, point: str, tokens: int, steps: int = 1,
                     min_margin: Optional[float] = None) -> None:
        """One decode burst: ``tokens`` emitted over ``steps`` engine steps,
        all at ``point``; ``min_margin`` aggregates the burst (min over its
        emitted tokens)."""
        self.steps += 1
        self.decode_steps += steps
        self.steps_by_point[point] += 1
        if self._prev_point is not None and point != self._prev_point:
            self.switches += 1
        self._prev_point = point
        self._charge(point, tokens)
        if min_margin is not None:
            self.min_margins.append(float(min_margin))

    def record_step(self, point: str, active: int, min_margin: Optional[float] = None) -> None:
        self.record_burst(point, tokens=active, steps=1, min_margin=min_margin)

    @property
    def tokens(self) -> int:
        return sum(self.tokens_by_point.values())

    def savings_frac(self) -> float:
        """Estimated fraction of MAC cycles saved vs all-accurate serving."""
        if self.baseline_cycles <= 0:
            return 0.0
        return 1.0 - self.est_cycles / self.baseline_cycles

    def to_dict(self) -> Dict:
        """The unified telemetry export: one shape shared with
        :meth:`repro.spec.telemetry.SpecTelemetry.to_dict`, so an
        adaptive+speculative run reports one coherent list of records.

        Common keys: ``kind`` (discriminator), ``reference``, ``tokens``
        (tokens charged), ``est_cycles`` / ``baseline_cycles`` (this record's
        cycle model vs all-reference serving), ``est_cycle_savings_frac``,
        ``cycle_model`` (which calibration — or ``"analytic"`` — produced the
        cycle numbers, so records are comparable across runs); ``detail``
        carries the kind-specific ``summary()``.
        """
        return {
            "kind": "adaptive",
            "cycle_model": self.cycle_model,
            "reference": self.reference,
            "tokens": self.tokens,
            "est_cycles": self.est_cycles,
            "baseline_cycles": self.baseline_cycles,
            # full precision: this is the machine-readable record the
            # simulator's predicted-vs-reported gate compares against
            # (summary() rounds for humans)
            "est_cycle_savings_frac": self.savings_frac(),
            "detail": self.summary(),
        }

    def summary(self) -> Dict:
        tokens = max(self.tokens, 1)
        return {
            "steps": self.steps,
            "decode_steps": self.decode_steps,
            "tokens": self.tokens,
            "switches": self.switches,
            "mode_occupancy": {
                k: round(v / tokens, 4) for k, v in self.tokens_by_point.items()
            },
            "steps_by_point": dict(self.steps_by_point),
            "est_mac_cycles": self.est_cycles,
            "all_accurate_mac_cycles": self.baseline_cycles,
            "est_cycle_savings_frac": round(self.savings_frac(), 4),
            "reference": self.reference,
        }
