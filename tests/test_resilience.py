"""Fault-tolerant serving: deadlines, admission control, fault isolation.

The resilience layer must never change what a healthy server computes:

* with ``resilience=None`` the engine keeps its legacy fail-stop contract
  (oversized prompts raise, faults crash or corrupt loudly) bit-for-bit;
* with a ``ResilienceConfig`` and an injected NaN fault in ONE slot, every
  other slot's greedy stream is bit-identical to a fault-free run — the
  fault flag rides the existing burst carry and the token math is untouched
  (dense and MoE+MLA, adaptive and speculative, mesh=None and 1x1);
* the faulted slot commits exactly its clean prefix (the tokens before the
  first bad logit match the fault-free stream) and is quarantined with a
  structured ``RequestOutcome``;
* admission control sheds work it cannot serve (oversized prompt, full
  queue, expired deadline) instead of crashing, and every shed outcome
  names its reason;
* ``DegradationPolicy`` demotes the batch down the depth ladder under
  pressure before anything is shed, and promotes back with hysteresis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.obs import ServingObserver
from repro.obs.trace import TraceRecorder, read_trace
from repro.resilience import (
    DegradationConfig,
    DegradationPolicy,
    DelayFault,
    FaultInjector,
    NaNCacheFault,
    NaNWeightFault,
    RequestOutcome,
    ResilienceConfig,
    oversized_request,
    shed_overflow,
)
from repro.runtime import (
    ControllerConfig,
    ModeController,
    StepSignals,
    build_bank,
    default_points,
)
from repro.serve.engine import BatchedServer, Request
from repro.spec import SpecConfig

CARMEN = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                       compute_dtype=jnp.float32)


def _setup(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, *, prompt_len=5, max_new=10, deadline_s=None):
    rng = np.random.default_rng(2)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
                max_new, deadline_s=deadline_s)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def olmo():
    return _setup("olmo-1b")


@pytest.fixture(scope="module")
def olmo_bank(olmo):
    _, model, params = olmo
    return build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())


# ---------------------------------------------------------------------------
# fault isolation: the acceptance-criterion matrix
# ---------------------------------------------------------------------------


def _isolation_case(arch, *, spec=False, mesh_shape=None, bank=None,
                    controller_factory=None):
    """Run fault-free vs one-slot-NaN and assert the isolation contract."""
    cfg, model, params = _setup(arch)
    mesh = (jax.make_mesh(mesh_shape, ("data", "model"))
            if mesh_shape is not None else None)
    kw = dict(slots=4, max_len=64, burst=4, mesh=mesh,
              resilience=ResilienceConfig())
    if spec or controller_factory is not None:
        bank = bank or build_bank(params, "carmen",
                                  default_points(FXP16, hifi_fmt=None),
                                  specs=model.specs())
        kw.update(bank=bank)
    if spec:
        kw.update(speculate=SpecConfig(draft_len=3))

    def build(injector=None):
        ctl = (controller_factory(bank)
               if controller_factory is not None else None)
        return BatchedServer(model, CARMEN, params, injector=injector,
                             controller=ctl, **kw)

    ref = build()
    ref_out = ref.run(_requests(cfg, 3))
    assert all(o.status == "ok" for o in ref.outcomes.values())

    srv = build(FaultInjector(NaNCacheFault(rid=1, at_round=1)))
    out = srv.run(_requests(cfg, 3))
    # the injector really fired (otherwise the assertions below are vacuous)
    assert srv.injector.fired and srv.injector.fired[0][0] == 1
    # unaffected slots: bit-identical streams and clean outcomes
    for rid in (0, 2):
        assert out[rid] == ref_out[rid]
        assert srv.outcomes[rid].status == "ok"
    # faulted slot: quarantined, and what WAS committed is the clean prefix
    o1 = srv.outcomes[1]
    assert o1.status == "faulted"
    assert o1.reason in ("decode_nonfinite", "verify_nonfinite")
    assert len(out[1]) < len(ref_out[1])
    assert out[1] == ref_out[1][:len(out[1])]
    assert srv._fault_counts["faulted"] == 1
    return srv


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-v3-671b"])
def test_fault_isolation_burst(arch):
    """Dense and MoE+MLA: a NaN-poisoned KV slot faults alone; the other
    slots' greedy streams never see it."""
    _isolation_case(arch)


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-v3-671b"])
def test_fault_isolation_speculative(arch):
    """Same contract through the draft/verify round: the verify forward
    detects the poisoned lane, quarantines it with zero committed tokens
    from the round, and the other lanes' commits are untouched."""
    _isolation_case(arch, spec=True)


def test_fault_isolation_on_mesh(olmo):
    """The fault flag is one more slot-state leaf: the sharded decode path
    (mesh=1x1) carries it and isolates identically."""
    _isolation_case("olmo-1b", mesh_shape=(1, 1))


def test_fault_isolation_adaptive(olmo_bank):
    """With a ModeController swapping bank trees mid-run, isolation still
    holds (the flag is orthogonal to the executed point)."""
    _isolation_case(
        "olmo-1b",
        controller_factory=lambda bank: ModeController(
            bank, ControllerConfig(pin=bank.reference)),
        bank=olmo_bank,
    )


def test_spec_draft_fault_degrades_to_accurate(olmo, olmo_bank):
    """NaN draft weights: every lane's round aborts to the accurate
    position-0 distribution — one correct token per round, streams
    bit-identical to a healthy run, no quarantine."""
    cfg, model, params = olmo
    kw = dict(slots=4, max_len=64, speculate=SpecConfig(draft_len=3),
              resilience=ResilienceConfig())
    ref = BatchedServer(model, CARMEN, params, bank=olmo_bank, **kw)
    ref_out = ref.run(_requests(cfg, 3))
    # fresh bank: the injector poisons the draft tree in place
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    srv = BatchedServer(
        model, CARMEN, params, bank=bank,
        injector=FaultInjector(NaNWeightFault(at_round=1, point=bank.names[0])),
        **kw)
    out = srv.run(_requests(cfg, 3))
    assert out == ref_out
    assert all(o.status == "ok" for o in srv.outcomes.values())
    # after the fault every round emits exactly 1 token: acceptance collapses
    tele = srv.spec_telemetry.summary()
    assert tele["rounds"] > ref.spec_telemetry.summary()["rounds"]


def test_prefill_fault_quarantines_before_commit(olmo):
    """A non-finite prefill margin means the first sampled token is garbage:
    the request is quarantined with zero tokens and the slot is reused.

    slots=1 sequences it: request 0 prefills clean, the round-0 injector
    poisons the serving weights (decode fault), then request 1's prefill
    runs on the poisoned tree and is caught before any token commits."""
    cfg, model, params = olmo
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    srv = BatchedServer(
        model, CARMEN, params, slots=1, max_len=64, burst=4, bank=bank,
        controller=ModeController(bank, ControllerConfig(pin="accurate")),
        resilience=ResilienceConfig(),
        injector=FaultInjector(NaNWeightFault(at_round=0, point="accurate")))
    out = srv.run(_requests(cfg, 2))
    assert srv.outcomes[0].status == "faulted"
    assert srv.outcomes[0].reason == "decode_nonfinite"
    assert srv.outcomes[1].status == "faulted"
    assert srv.outcomes[1].reason == "prefill_nonfinite"
    assert out[1] == []


# ---------------------------------------------------------------------------
# admission control and shedding
# ---------------------------------------------------------------------------


def test_oversized_prompt_shed_not_crash(olmo):
    """Satellite: prompt + max_new > max_len is shed with reason too_long
    when resilience is on; the rest of the batch serves normally."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=16, burst=4,
                        resilience=ResilienceConfig())
    good = _requests(cfg, 2, max_new=4)
    out = srv.run(good + [oversized_request(9, 16)])
    assert srv.outcomes[9].status == "shed"
    assert srv.outcomes[9].reason == "too_long"
    assert 9 not in out
    assert all(len(out[r.rid]) == 4 for r in good)


def test_legacy_contract_still_raises(olmo):
    """resilience=None keeps the fail-stop ValueError byte-for-byte."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=1, max_len=16, burst=4)
    with pytest.raises(ValueError, match="exceeds max_len"):
        srv.run([oversized_request(0, 16)])


def test_queue_limit_sheds_with_reason(olmo):
    """queue_limit bounds admitted work; every rejected request carries a
    structured shed outcome, and survivors complete."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64, burst=4,
                        resilience=ResilienceConfig(queue_limit=3))
    out = srv.run(_requests(cfg, 6, max_new=4))
    shed = {r: o for r, o in srv.outcomes.items() if o.status == "shed"}
    served = {r: o for r, o in srv.outcomes.items() if o.status == "ok"}
    assert len(shed) == 3 and len(served) == 3
    assert all(o.reason == "queue_full" for o in shed.values())
    assert all(len(out[r]) == 4 for r in served)
    assert srv._fault_counts["shed"] == 3


def test_shed_policies():
    """The three shed policies pick different victims from one queue."""
    reqs = [
        Request(0, np.arange(2, dtype=np.int32), 4, deadline_s=None),
        Request(1, np.arange(9, dtype=np.int32), 4, deadline_s=0.5),
        Request(2, np.arange(5, dtype=np.int32), 4, deadline_s=9.0),
        Request(3, np.arange(3, dtype=np.int32), 4, deadline_s=2.0),
    ]
    kept, shed = shed_overflow(list(reqs), 2, "reject_newest")
    assert [r.rid for r in kept] == [0, 1]
    assert [r.rid for r in shed] == [2, 3]
    kept, shed = shed_overflow(list(reqs), 2, "reject_largest")
    assert [r.rid for r in kept] == [0, 3]  # arrival order preserved
    assert {r.rid for r in shed} == {1, 2}
    kept, shed = shed_overflow(list(reqs), 2, "deadline_aware")
    # least slack shed first: 0.5s then 2.0s; no-deadline ranks last (safe)
    assert {r.rid for r in shed} == {1, 3}
    assert [r.rid for r in kept] == [0, 2]


def test_shed_overflow_noop_under_limit():
    reqs = [Request(0, np.arange(3, dtype=np.int32), 2)]
    kept, shed = shed_overflow(list(reqs), 4, "reject_newest")
    assert kept == reqs and shed == []


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_expiry_keeps_partial_tokens(olmo):
    """A burst-boundary delay past every deadline expires the active slots;
    their partial streams survive in the results."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=4, max_len=64, burst=4,
                        resilience=ResilienceConfig(default_deadline_s=0.5),
                        injector=FaultInjector(DelayFault(at_round=1,
                                                          seconds=1.0)))
    out = srv.run(_requests(cfg, 3, max_new=24))
    assert all(o.status == "expired" for o in srv.outcomes.values())
    assert all(o.reason == "deadline" for o in srv.outcomes.values())
    assert all(0 < len(v) < 24 for v in out.values())
    assert srv._fault_counts["deadline_misses"] == 3
    assert all(not o.deadline_met for o in srv.outcomes.values())


def test_queued_requests_expire_without_prefill(olmo):
    """A request whose deadline passes while queued is shed, never
    prefilled — no wasted forward pass on work that cannot win."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=1, max_len=64, burst=4,
                        resilience=ResilienceConfig(),
                        injector=FaultInjector(DelayFault(at_round=0,
                                                          seconds=0.3)))
    reqs = _requests(cfg, 1, max_new=8)
    reqs.append(Request(7, np.arange(1, 6, dtype=np.int32), 8,
                        deadline_s=0.05))
    srv.run(reqs)
    assert srv.outcomes[7].status == "shed"
    assert srv.outcomes[7].reason == "deadline_expired"
    assert srv.outcomes[0].status == "ok"


def test_per_request_deadline_overrides_default(olmo):
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64, burst=4,
                        resilience=ResilienceConfig(default_deadline_s=0.001))
    reqs = _requests(cfg, 2, max_new=4)
    reqs[0].deadline_s = 60.0  # generous per-request override
    srv.run(reqs)
    assert srv.outcomes[0].status == "ok"
    # rid 1 inherits the impossible default and expires (or finishes within
    # a round if the host is absurdly fast — accept either terminal state)
    assert srv.outcomes[1].status in ("expired", "ok")
    assert srv.outcomes[1].deadline_s == 0.001


def test_run_never_mutates_caller_requests(olmo):
    """Deadline resolution is run-local state, not a write onto the caller's
    Request objects: the SAME request list served by two servers with
    different default deadlines must leave ``req.deadline_s`` untouched and
    give each run its own server's default (the old code stamped the first
    server's default onto the requests, so the second run inherited it)."""
    cfg, model, params = olmo
    reqs = _requests(cfg, 2, max_new=4)  # deadline_s=None on every request
    generous = BatchedServer(
        model, CARMEN, params, slots=2, max_len=64, burst=4,
        resilience=ResilienceConfig(default_deadline_s=120.0))
    generous.run(reqs)
    assert all(r.deadline_s is None for r in reqs)
    assert all(o.deadline_s == 120.0 for o in generous.outcomes.values())

    tight = BatchedServer(
        model, CARMEN, params, slots=2, max_len=64, burst=4,
        resilience=ResilienceConfig(default_deadline_s=0.002))
    tight.run(reqs)
    assert all(r.deadline_s is None for r in reqs)
    # the second run resolved ITS default, not the first server's 120 s
    assert all(o.deadline_s == 0.002 for o in tight.outcomes.values())


# ---------------------------------------------------------------------------
# outcomes and aborted-run attribution
# ---------------------------------------------------------------------------


def test_outcomes_recorded_without_resilience(olmo):
    """RequestOutcome bookkeeping is unconditional — a legacy run still
    reports structured per-request outcomes in the snapshot."""
    cfg, model, params = olmo
    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64, burst=4)
    srv.run(_requests(cfg, 2, max_new=4))
    snap = srv.snapshot()
    oc = snap["resilience"]["outcomes"]
    assert set(oc) == {0, 1}
    assert all(v["status"] == "ok" and v["deadline_met"] for v in oc.values())
    assert snap["resilience"]["counters"]["faulted"] == 0


def test_aborted_run_snapshot_attribution(olmo):
    """Satellite: snapshot() after an aborted run reports every in-flight
    request's outcome (status aborted, tokens so far) plus fault counters."""
    cfg, model, params = olmo

    class Boom(RuntimeError):
        pass

    class _Bomb:
        fired = ()

        def before_round(self, server, round_idx, slot_of):
            if round_idx == 1:
                raise Boom()

    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64, burst=4,
                        resilience=ResilienceConfig(), injector=_Bomb())
    with pytest.raises(Boom):
        srv.run(_requests(cfg, 3, max_new=24))
    snap = srv.snapshot()
    oc = snap["resilience"]["outcomes"]
    assert set(oc) == {0, 1, 2}
    assert all(v["status"] == "aborted" for v in oc.values())
    # the two admitted slots had committed their prefill + first burst
    assert sorted(v["tokens"] for v in oc.values()) == [0, 5, 5]


def test_outcome_to_dict_roundtrip():
    o = RequestOutcome(rid=3, status="expired", reason="deadline", tokens=4,
                       deadline_s=0.5, wall_s=0.7)
    d = o.to_dict()
    assert d["rid"] == 3 and d["deadline_met"] is False
    ok = RequestOutcome(rid=1, status="ok", tokens=8, wall_s=0.1)
    assert ok.deadline_met  # no deadline == met
    with pytest.raises(ValueError):
        RequestOutcome(rid=0, status="nope")


def test_resilience_config_validation():
    with pytest.raises(ValueError):
        ResilienceConfig(shed_policy="coin_flip")
    with pytest.raises(ValueError):
        ResilienceConfig(queue_limit=0)
    with pytest.raises(ValueError):
        ResilienceConfig(default_deadline_s=-1.0)


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------


def _mk_controller(bank, **cfg):
    inner = ModeController(bank, ControllerConfig(pin=bank.reference))
    return DegradationPolicy(inner, DegradationConfig(**cfg))


def test_degradation_demotes_under_pressure(olmo_bank):
    pol = _mk_controller(olmo_bank, promote_hysteresis=3)
    assert pol.point == olmo_bank.reference
    pol.observe(StepSignals(active=2, steps=4, queue_depth=3,
                            free_slots=0, deadline_misses=1))
    assert pol._cap < pol._top_idx  # demoted one rung
    assert pol.demotions == 1 and pol.switches == 1
    before = pol._cap
    # calm rounds: promotion waits for the hysteresis streak
    for _ in range(3):
        assert pol._cap == before
        pol.observe(StepSignals(active=2, steps=4, queue_depth=0,
                                free_slots=2))
    assert pol._cap == before + 1 and pol.promotions == 1


def test_degradation_floor_bounds_demotion(olmo_bank):
    floor = olmo_bank.names[1]
    pol = _mk_controller(olmo_bank, floor=floor, demote_hysteresis=1)
    for _ in range(10):
        pol.observe(StepSignals(active=2, steps=4, queue_depth=5,
                                free_slots=0, shed=1))
    assert pol.point == floor  # never below the configured floor


def test_degradation_effective_point_caps_inner(olmo_bank):
    """The effective point is min(inner, cap): a pinned-accurate inner
    controller still runs cheap under pressure."""
    pol = _mk_controller(olmo_bank, demote_hysteresis=1)
    pol.observe(StepSignals(active=2, steps=4, queue_depth=9,
                            free_slots=0, deadline_misses=2))
    assert olmo_bank.index(pol.point) < olmo_bank.index(pol.inner.point)
    assert pol.cap == pol.point  # pinned inner: the cap IS the effective point


def test_degradation_reset(olmo_bank):
    pol = _mk_controller(olmo_bank, demote_hysteresis=1)
    pol.observe(StepSignals(active=2, steps=4, queue_depth=9,
                            free_slots=0, shed=2))
    assert pol._cap < pol._top_idx
    pol.reset()
    assert pol._cap == pol._top_idx and pol.point == olmo_bank.reference


def test_degradation_improves_deadline_met_fraction(olmo, olmo_bank):
    """The headline property: under deadline pressure the degrading server
    meets at least as many deadlines as the pinned-accurate one (strict
    improvement is asserted by the robustness benchmark, which calibrates
    the deadline; here we assert monotonicity with a fixed one)."""
    cfg, model, params = olmo

    def run(controller):
        srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64,
                            burst=4, bank=olmo_bank, controller=controller,
                            resilience=ResilienceConfig(
                                default_deadline_s=2.0))
        srv.run(_requests(cfg, 6, max_new=12))
        return sum(o.deadline_met for o in srv.outcomes.values())

    pinned = ModeController(olmo_bank, ControllerConfig(pin=olmo_bank.reference))
    met_pinned = run(pinned)
    met_degrade = run(_mk_controller(olmo_bank, demote_hysteresis=1))
    assert met_degrade >= met_pinned


# ---------------------------------------------------------------------------
# trace recorder context manager (satellite)
# ---------------------------------------------------------------------------


def test_trace_recorder_flushes_on_exception(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with pytest.raises(RuntimeError):
        with TraceRecorder(sink=path) as tr:
            tr.begin("burst")
            raise RuntimeError("mid-span crash")
    header, events = read_trace(path)
    assert header["meta"]["aborted"] is True
    # the open span was settled: B and E both present, well-formed
    assert [e["ph"] for e in events] == ["B", "E"]


def test_trace_recorder_clean_exit_flushes(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with TraceRecorder(sink=path) as tr:
        tr.instant("tick")
    header, events = read_trace(path)
    assert "aborted" not in header["meta"]
    assert len(events) == 1


def test_server_trace_survives_aborted_run(olmo, tmp_path):
    """End to end: a crash mid-run still leaves a replayable trace on disk
    when the observer has a sink."""
    cfg, model, params = olmo
    path = str(tmp_path / "aborted.jsonl")

    class _Bomb:
        fired = ()

        def before_round(self, server, round_idx, slot_of):
            if round_idx == 1:
                raise RuntimeError("boom")

    obs = ServingObserver(trace_sink=path)
    srv = BatchedServer(model, CARMEN, params, slots=2, max_len=64, burst=4,
                        observer=obs, resilience=ResilienceConfig(),
                        injector=_Bomb())
    with pytest.raises(RuntimeError):
        srv.run(_requests(cfg, 2, max_new=24))
    header, events = read_trace(path)
    assert header["meta"]["aborted"] is True
    assert any(e["name"] == "burst" for e in events)
