"""Speculative-serving configuration."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """One speculative round drafts ``draft_len`` tokens, then verifies k+1.

    ``draft_point`` names the bank execution point the draft loop runs at;
    ``None`` lets an attached :class:`repro.runtime.ModeController` pick it
    per round (its demote/promote ladder then steers draft cheapness), falling
    back to the bank's cheapest point. ``verify_point`` defaults to the bank
    reference (all-accurate) — greedy outputs are bit-identical to serving
    every token at that point.
    """

    draft_len: int = 4
    draft_point: Optional[str] = None
    verify_point: Optional[str] = None

    def __post_init__(self):
        if self.draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {self.draft_len}")
        if (
            self.draft_point is not None
            and self.draft_point == self.verify_point
        ):
            raise ValueError(
                "draft_point == verify_point drafts at full cost; pick a "
                "cheaper draft point (or leave draft_point=None)"
            )
