"""Speculative-serving telemetry: acceptance + weight-pass cycle accounting.

Cycle model (the ``K*(depth+1)`` iterative-PE model, latency form): decode is
weight-bound — every step streams the weight bank through the PE array once,
at ``numel(W) * (depth+1)`` cycles per engine dot (``runtime.telemetry``'s
per-token quantity). A multi-token verify forward streams the bank ONCE for
all ``k+1`` positions (weight-stationary PEs broadcast each resident weight
across the block), so one speculative round costs

    k * cycles(draft_point) + 1 * cycles(verify_point)

weight-pass cycles per slot and emits ``accepted + 1`` tokens, against
``emitted * cycles(verify_point)`` for accurate-only serving of the same
tokens. Savings are positive once the mean accepted length clears
``k * rel_cycles(draft)`` — the break-even the bench records. (Pure MAC *op*
counts go up under speculation; the win is sequential weight passes, which is
what decode latency follows.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class SpecTelemetry:
    """Accumulates per-round speculative-serving telemetry for one run."""

    cycles_per_token: Dict[str, float]
    reference: str
    draft_len: int
    cycle_model: str = "analytic"  # which calibration produced est_cycles

    def __post_init__(self):
        self.reset()

    @classmethod
    def for_bank(cls, bank, draft_len: int) -> "SpecTelemetry":
        return cls(dict(bank.cycles_per_token), bank.reference, draft_len,
                   getattr(bank, "cycle_model", "analytic"))

    def reset(self) -> None:
        self.rounds = 0
        self.drafted = 0
        self.accepted = 0
        self.emitted = 0
        self.rounds_by_draft_point: Dict[str, int] = {
            k: 0 for k in self.cycles_per_token
        }
        self.est_cycles = 0.0
        self.baseline_cycles = 0.0

    def record_round(self, draft_point: str, verify_point: str,
                     accepted, emitted) -> None:
        """One draft+verify round: per-active-slot accepted/emitted counts."""
        self.rounds += 1
        self.rounds_by_draft_point[draft_point] += 1
        c_draft = self.cycles_per_token[draft_point]
        c_verify = self.cycles_per_token[verify_point]
        for acc, emit in zip(accepted, emitted):
            self.drafted += self.draft_len
            self.accepted += int(acc)
            self.emitted += int(emit)
            self.est_cycles += self.draft_len * c_draft + c_verify
            self.baseline_cycles += int(emit) * c_verify

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def tokens_per_step(self) -> float:
        """Mean tokens committed per verify step (slot-rounds)."""
        slot_rounds = self.drafted / max(self.draft_len, 1)
        return self.emitted / max(slot_rounds, 1)

    def savings_frac(self) -> float:
        """Estimated weight-pass cycles saved vs accurate-only serving."""
        if self.baseline_cycles <= 0:
            return 0.0
        return 1.0 - self.est_cycles / self.baseline_cycles

    def to_dict(self) -> Dict:
        """The unified telemetry export shape shared with
        :meth:`repro.runtime.telemetry.TelemetryRecorder.to_dict` — common
        keys (``kind``/``reference``/``tokens``/``est_cycles``/
        ``baseline_cycles``/``est_cycle_savings_frac``) with the speculative
        ``summary()`` under ``detail``, so adaptive and speculative records
        from one run are consumed uniformly by the metrics registry and the
        trace header."""
        return {
            "kind": "speculative",
            "cycle_model": self.cycle_model,
            "reference": self.reference,
            "tokens": self.emitted,
            "est_cycles": self.est_cycles,
            "baseline_cycles": self.baseline_cycles,
            # full precision, like TelemetryRecorder.to_dict: the replay
            # gate compares against this value (summary() rounds for humans)
            "est_cycle_savings_frac": self.savings_frac(),
            "detail": self.summary(),
        }

    def summary(self) -> Dict:
        return {
            "rounds": self.rounds,
            "draft_len": self.draft_len,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "emitted": self.emitted,
            "acceptance_rate": round(self.acceptance_rate, 4),
            "mean_accepted_per_step": round(
                self.accepted * self.draft_len / max(self.drafted, 1), 4
            ),
            "tokens_per_step": round(self.tokens_per_step, 4),
            "rounds_by_draft_point": dict(self.rounds_by_draft_point),
            "est_weight_pass_cycles": self.est_cycles,
            "accurate_only_cycles": self.baseline_cycles,
            "est_cycle_savings_frac": round(self.savings_frac(), 4),
            "reference": self.reference,
        }
