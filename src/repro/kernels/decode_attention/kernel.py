"""Pallas TPU kernel: per-query-causal decode attention over the slot KV cache.

The serving decode path (burst S in {1..burst}, speculative verify) attends a
short query block against the whole cache with a *per-query* validity mask
(``k_pos <= q_pos``) instead of the training-time triangular mask.  The XLA
chain materializes GQA-repeated keys/values ((B, T, KV, hd) -> (B, T, H, hd))
and an (B, H, S, T) score tensor in HBM; this kernel keeps both inside VMEM:

  grid = (B, H); each program reads its query head's slice, the *shared* kv
  head's cache slice (GQA resolved by the index map — no ``jnp.repeat``
  materialization), computes the (S, T) score tile, masks, softmaxes and
  contracts against V without leaving VMEM.

Numerics deliberately mirror ``models/blocks.attention`` (GQA) and
``models/mla.mla_attention._block`` (MLA) op-for-op — same mask application
order, same dtypes at each step — so the kernel is exchangeable with the XLA
cache path: greedy token streams are identical, and raw outputs agree to
reduction-order tolerance (XLA does not pin f32 reduction order across
differently shaped programs, so the per-(b,h) tiles here vs the whole-batch
einsum can differ by a couple of ulps depending on how the backend threads
the contraction).  Softmax is the plain (not online) form: decode tiles are
small (S <= burst, T = cache length), and the online-softmax rescaling would
drift further from the reference chain.

Sibling kernels: ``flash_attention`` / ``mla_flash`` cover the long-sequence
prefill/training shapes with online softmax; this one covers the cache-decode
shape they cannot express (per-row positions, per-query masks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gqa_decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale: float):
    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (S, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (T, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (S, T)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = t_idx <= pos_ref[0, :][:, None]
    s = jnp.where(valid, s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    v = v_ref[0, :, 0, :]  # (T, hd) cache dtype
    o = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "scale", "interpret"))
def gqa_decode(q, k, v, positions, *, groups: int, scale: float,
               interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) slot caches with H = KV*groups;
    positions: (B, S) int32 absolute query positions.  Returns (B, S, H, hd)
    in the cache dtype (matching the XLA chain's einsum output)."""
    b, s, h, hd = q.shape
    _, t, kv, _ = k.shape
    assert h == kv * groups, (q.shape, k.shape, groups)
    return pl.pallas_call(
        functools.partial(_gqa_decode_kernel, scale=scale),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, s, 1, hd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda bi, hi: (bi, 0, hi // groups, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda bi, hi: (bi, 0, hi // groups, 0)),
            pl.BlockSpec((1, s), lambda bi, hi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, 1, hd), lambda bi, hi: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), v.dtype),
        interpret=interpret,
    )(q, k, v, positions)


def _mla_decode_kernel(ql_ref, qr_ref, ckv_ref, kr_ref, pos_ref, o_ref, *,
                       scale: float):
    ql = ql_ref[0, :, 0, :].astype(jnp.float32)   # (S, R)
    qr = qr_ref[0, :, 0, :].astype(jnp.float32)   # (S, r)
    ckv = ckv_ref[0].astype(jnp.float32)          # (T, R)
    kr = kr_ref[0].astype(jnp.float32)            # (T, r)
    s = jax.lax.dot_general(
        ql, ckv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s + jax.lax.dot_general(
        qr, kr, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale
    t_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t_idx <= pos_ref[0, :][:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_ref[0, :, 0, :] = jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_decode(q_lat, q_rope, c_kv, k_rope, positions, *, scale: float,
               interpret: bool = False):
    """Absorbed-form MLA decode: q_lat (B, S, H, R), q_rope (B, S, H, r),
    c_kv (B, T, R), k_rope (B, T, r), positions (B, S).  Returns the latent
    output (B, S, H, R) f32 — MLA is MQA-shaped in latent space, so every
    head reads the same cache slice."""
    b, s, h, r = q_lat.shape
    _, t, _ = c_kv.shape
    rd = q_rope.shape[-1]
    return pl.pallas_call(
        functools.partial(_mla_decode_kernel, scale=scale),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, s, 1, r), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, rd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, t, r), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, t, rd), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, s), lambda bi, hi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, 1, r), lambda bi, hi: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, r), jnp.float32),
        interpret=interpret,
    )(q_lat, q_rope, c_kv, k_rope, positions)
