"""AAD pooling unit."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _hypothesis_compat import arrays

from repro.core import aad_pool, aad_pool_1d, avg_pool, max_pool


def test_shapes(rng):
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    assert aad_pool(x, 2).shape == (2, 4, 4, 3)
    assert avg_pool(x, 2).shape == (2, 4, 4, 3)
    assert max_pool(x, 2).shape == (2, 4, 4, 3)
    assert aad_pool(x, 2, stride=1).shape == (2, 7, 7, 3)


def test_constant_window_is_identity():
    x = np.full((1, 4, 4, 1), 3.25, np.float32)
    np.testing.assert_allclose(np.asarray(aad_pool(x, 2)), 3.25)


def test_outlier_rejection():
    """AAD's reason to exist: a quantization-noise outlier must not dominate."""
    win = np.array([1.0, 1.1, 0.9, 50.0], np.float32).reshape(1, 2, 2, 1)
    out = np.asarray(aad_pool(win, 2)).item()
    assert abs(out - 1.0) < 0.2  # ~mean of inliers, not (1+1.1+0.9+50)/4 = 13.25
    assert abs(np.asarray(avg_pool(win, 2)).item() - 13.25) < 1e-3


@given(
    x=arrays(
        np.float32,
        (1, 4, 4, 2),
        elements=st.floats(-100, 100, allow_nan=False, width=32),
    )
)
@settings(max_examples=100, deadline=None)
def test_output_within_window_hull(x):
    """Pooled value always lies in [min, max] of its window (robust-mean property)."""
    out = np.asarray(aad_pool(x, 2))
    for i in range(2):
        for j in range(2):
            win = x[0, 2 * i : 2 * i + 2, 2 * j : 2 * j + 2, :]
            lo, hi = win.min(axis=(0, 1)), win.max(axis=(0, 1))
            assert np.all(out[0, i, j] >= lo - 1e-4) and np.all(out[0, i, j] <= hi + 1e-4)


def test_1d_variant(rng):
    x = rng.standard_normal((2, 16, 4)).astype(np.float32)
    out = np.asarray(aad_pool_1d(x, 4))
    assert out.shape == (2, 4, 4)
    np.testing.assert_allclose(
        np.asarray(aad_pool_1d(np.ones((1, 8, 1), np.float32), 2)), 1.0
    )
