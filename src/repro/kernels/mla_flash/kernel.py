"""Pallas TPU kernel: flash attention for Multi-head Latent Attention.

Closes EXPERIMENTS.md §Perf B8: deepseek's remaining memory term is ~6 TB/dev
of materialized f32 MLA score tiles. MLA's structure — every head attends over
the SAME compressed latent (c_kv, k_rope) — means a flash kernel can broadcast
one K/V tile across a block of heads inside VMEM. The pure-JAX twin cannot
express this without materializing the H-repeated K (refuted iteration B6);
this kernel can, because the broadcast is just a BlockSpec index_map that
ignores the head-block grid axis.

Score identity (models/mla.py): s[h, q, t] = q_cat[q, h, :] . k_cat[t, :]
with q_cat = [q_lat, q_rope] (Dk = kv_lora_rank + rope_dim) and
k_cat = [c_kv, k_rope]; the "value" is c_kv alone (Dv = kv_lora_rank).

Grid (B, H/bh, nq, nk), k innermost. VMEM at bh=8, bq=128, bk=512,
Dk=576, Dv=512 (deepseek-v3):
  q tile 128*8*576*4 = 2.4 MB | k tile 512*576*4 = 1.2 MB (shared by 8 heads)
  v tile 512*512*4 = 1 MB | scores 8*128*512*4 = 2 MB | acc 8*128*512*4 = 2 MB
  ~= 8.6 MB << 16 MiB. One k fetch serves bh heads — the H-broadcast the
  XLA twin cannot express.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cordic_mac.kernel import pltpu_vmem

NEG_INF = -1e30


def _mla_flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      n_k: int, bq: int, bk: int, causal: bool, scale: float):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, bh, Dk)
    k = k_ref[0].astype(jnp.float32)  # (bk, Dk)  — shared across the bh heads
    v = v_ref[0].astype(jnp.float32)  # (bk, Dv)

    # scores (bh, bq, bk): one shared-latent K tile serves every head
    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bh, bk)
    s = s * scale
    if causal:
        qi = pl.program_id(2)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1, bk), 0)
        k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, 1, bk), 2)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, bh, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    # acc (bq, bh, Dv) += p (bq, bh, bk) @ v (bk, Dv)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kk == n_k - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "bh", "interpret"))
def mla_flash(q_cat, k_cat, v, *, causal: bool = True, bq: int = 128, bk: int = 512,
              bh: int = 8, interpret: bool = False):
    """q_cat: (B, Sq, H, Dk); k_cat: (B, Sk, Dk); v: (B, Sk, Dv).

    Returns (B, Sq, H, Dv) in q_cat.dtype. Scaling uses 1/sqrt(Dk) — pre-scale
    q_cat if the model uses a different score scale.
    """
    b, sq, h, dk = q_cat.shape
    _, sk, dv = v.shape
    bq = min(bq, sq)
    bk = min(bk, sk)
    bh = min(bh, h)
    assert sq % bq == 0 and sk % bk == 0 and h % bh == 0, (sq, sk, h, bq, bk, bh)
    n_k = sk // bk
    grid = (b, h // bh, sq // bq, n_k)
    scale = 1.0 / math.sqrt(dk)

    return pl.pallas_call(
        functools.partial(
            _mla_flash_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bh, dk), lambda bb, hh, qq, kk: (bb, qq, hh, 0)),
            # the K/V index maps ignore hh: one latent tile broadcast to bh heads
            pl.BlockSpec((1, bk, dk), lambda bb, hh, qq, kk: (bb, kk, 0)),
            pl.BlockSpec((1, bk, dv), lambda bb, hh, qq, kk: (bb, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, bh, dv), lambda bb, hh, qq, kk: (bb, qq, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dv), q_cat.dtype),
        scratch_shapes=[
            pltpu_vmem((bq, bh, dv), jnp.float32),
            pltpu_vmem((bq, bh, 1), jnp.float32),
            pltpu_vmem((bq, bh, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_cat, k_cat, v)
