"""Deterministic fault injection for serving-resilience tests and benchmarks.

A :class:`FaultInjector` holds a set of fault descriptions, each pinned to a
decode-round index (``at_round``); the server calls
``injector.before_round(server, round_idx, slot_of)`` immediately before
dispatching each burst / speculative round, and any fault whose round has
come fires exactly once. Nothing here reads the wall clock or an unseeded
PRNG — a fault plan is pure configuration, so an injected run is exactly as
reproducible as a clean one (which is what lets the robustness gates assert
*bit-identical* streams for unaffected slots).

Fault kinds:

* :class:`NaNCacheFault` — overwrite one request's KV-cache rows (all
  layers, optionally one layer) with NaN: models a slot-local numeric blowup
  (activation overflow, corrupted KV page). Only that slot's lane goes
  non-finite — attention and MoE dispatch are per-batch-row — so this is
  the canonical isolation probe.
* :class:`NaNWeightFault` — overwrite prepared-weight leaves (optionally
  filtered by a path substring) with NaN at one execution point: models a
  corrupted weight shard; every slot faults at once. The poisoned tree
  persists for the rest of the server's life — build a fresh server per
  injected run.
* :class:`DelayFault` — sleep before one round's dispatch: models a stalled
  device / preempted host, for driving deadline expiry deterministically.

``oversized_request`` builds the admission-time shed probe (`too_long`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DelayFault", "FaultInjector", "NaNCacheFault", "NaNWeightFault",
           "oversized_request", "poison_cache_slot", "poison_tree"]


def poison_cache_slot(cache, slot: int, layer: Optional[int] = None):
    """NaN every float leaf of ``cache`` at batch row ``slot``.

    Cache leaves are stacked ``(layers, slots, ...)`` arrays; integer leaves
    (the per-layer write indices) are left intact so the decode program's
    control flow is untouched — only the slot's numerics blow up.
    """
    lsel = slice(None) if layer is None else layer

    def bad(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        return leaf.at[lsel, slot].set(jnp.nan)

    return jax.tree.map(bad, cache)


def poison_tree(tree, match: Optional[str] = None):
    """NaN float leaves of a prepared-weight tree (path-substring filtered)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    hit = 0
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path)
        if (hasattr(leaf, "dtype")
                and jnp.issubdtype(leaf.dtype, jnp.floating)
                and (match is None or match in name)):
            leaf = jnp.full_like(leaf, jnp.nan)
            hit += 1
        out.append(leaf)
    if hit == 0:
        raise ValueError(f"no float weight leaf matched {match!r}")
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class NaNCacheFault:
    """Poison request ``rid``'s KV rows before round ``at_round``."""

    rid: int
    at_round: int
    layer: Optional[int] = None

    def apply(self, server, slot_of: Dict[int, int]) -> None:
        if not server.batched_prefill:
            raise ValueError(
                f"cache fault injection needs a scatterable KV cache; the "
                f"{server.model.cfg.family!r} family carries recurrent state"
            )
        if self.rid not in slot_of:
            raise ValueError(
                f"NaNCacheFault: request {self.rid} is not active at round "
                f"{self.at_round} (active slots: {sorted(slot_of)})"
            )
        server.cache = poison_cache_slot(server.cache, slot_of[self.rid],
                                         self.layer)


@dataclasses.dataclass(frozen=True)
class NaNWeightFault:
    """Poison prepared-weight leaves before round ``at_round``.

    ``point`` picks the bank execution point to corrupt (default: whatever
    the server would serve the next round at); ``layer`` is a substring
    matched against the leaf path (``None``: every float leaf).
    """

    at_round: int
    layer: Optional[str] = None
    point: Optional[str] = None

    def apply(self, server, slot_of: Dict[int, int]) -> None:
        bank = getattr(server, "_bank", None)
        if bank is None:
            server.params = poison_tree(server.params, self.layer)
            return
        name = self.point or server._serving_point() or bank.reference
        bank.trees[name] = poison_tree(bank.tree(name), self.layer)


@dataclasses.dataclass(frozen=True)
class DelayFault:
    """Stall the host for ``seconds`` before round ``at_round`` dispatches."""

    at_round: int
    seconds: float

    def apply(self, server, slot_of: Dict[int, int]) -> None:
        time.sleep(self.seconds)


class FaultInjector:
    """Fires each configured fault once, at its round, before dispatch."""

    def __init__(self, *faults) -> None:
        self.faults: Tuple = tuple(faults)
        self.fired = []  # (round_idx, fault) in firing order

    def before_round(self, server, round_idx: int, slot_of: Dict[int, int]) -> None:
        for fault in self.faults:
            if fault.at_round == round_idx:
                fault.apply(server, slot_of)
                self.fired.append((round_idx, fault))


def oversized_request(rid: int, max_len: int, max_new: int = 8,
                      request_cls=None):
    """A request whose ``prompt + max_new`` overflows ``max_len`` — the
    admission-time ``too_long`` shed probe (legacy servers raise on it)."""
    if request_cls is None:
        from repro.serve.engine import Request as request_cls
    prompt = np.ones((max(max_len - max_new + 1, 1),), np.int32)
    return request_cls(rid=rid, prompt=prompt, max_new=max_new)
