"""End-to-end behaviour: training reduces loss; serving is consistent;
checkpoint-restart resumes identically; CARMEN modes train too (STE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.data.pipeline import TokenPipeline
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request
from repro.train import optimizer as opt
from repro.train.train_loop import TrainConfig, make_train_step

CTX = EngineContext(mode="exact", compute_dtype=jnp.float32)


def _setup(arch="olmo-1b", steps_cfg=None):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = steps_cfg or TrainConfig(
        optimizer=opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30),
        remat=False,
    )
    return cfg, model, params, tcfg


def _run(model, params, tcfg, ctx, steps=25, seq=32, batch=8):
    pipe = TokenPipeline(model.cfg, seq, batch)
    state = opt.init_state(params)
    step_fn = jax.jit(make_train_step(model, ctx, tcfg))
    losses = []
    for s in range(steps):
        params, state, m = step_fn(params, state, pipe.batch(s))
        losses.append(float(m["loss"]))
    return params, state, losses


def test_training_reduces_loss():
    cfg, model, params, tcfg = _setup()
    _, _, losses = _run(model, params, tcfg, CTX)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_training_carmen_mode_reduces_loss():
    """QAT via STE: the paper-faithful quantized engine is trainable."""
    cfg, model, params, tcfg = _setup()
    ctx = EngineContext(
        mode="carmen", policy=PrecisionPolicy.accurate(FXP16), compute_dtype=jnp.float32
    )
    _, _, losses = _run(model, params, tcfg, ctx, steps=20)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.2


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be loss-equivalent to the monolithic step."""
    cfg, model, params, _ = _setup()
    pipe = TokenPipeline(cfg, 32, 8)
    batch = pipe.batch(0)
    t1 = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-3), microbatches=1, remat=False)
    t2 = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-3), microbatches=4, remat=False)
    s1 = opt.init_state(params)
    p1, _, m1 = jax.jit(make_train_step(model, CTX, t1))(params, s1, batch)
    s2 = opt.init_state(params)
    p2, _, m2 = jax.jit(make_train_step(model, CTX, t2))(params, s2, batch)
    # same data, same update (microbatch mean == full mean for mean losses)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_restart_resumes_identically(tmp_path):
    from repro.train import checkpoint

    cfg, model, params, tcfg = _setup()
    pipe = TokenPipeline(cfg, 32, 8)
    step_fn = jax.jit(make_train_step(model, CTX, tcfg))
    state = opt.init_state(params)
    # run 6 steps, checkpoint at 3
    p, s = params, state
    for i in range(3):
        p, s, _ = step_fn(p, s, pipe.batch(i))
    checkpoint.save(str(tmp_path), 3, p)
    checkpoint.save(str(tmp_path / "opt"), 3, s)
    p_cont, s_cont = p, s
    for i in range(3, 6):
        p_cont, s_cont, m_direct = step_fn(p_cont, s_cont, pipe.batch(i))
    # restart from disk
    p_r = checkpoint.restore(str(tmp_path), 3, p)
    s_r = checkpoint.restore(str(tmp_path / "opt"), 3, s)
    for i in range(3, 6):
        p_r, s_r, m_restart = step_fn(p_r, s_r, pipe.batch(i))
    np.testing.assert_allclose(float(m_direct["loss"]), float(m_restart["loss"]), rtol=1e-6)


def test_batched_server_matches_sequential_decode():
    """Continuous batching must produce the same tokens as dedicated decoding."""
    cfg, model, params, _ = _setup()
    prompt = np.array([5, 17, 3], np.int32)
    server = BatchedServer(model, CTX, params, slots=2, max_len=32)
    results = server.run([Request(0, prompt, 5), Request(1, prompt, 5)])
    # identical prompts -> identical generations, regardless of slot
    assert results[0] == results[1]
    # reference: single-sequence decode
    cache = model.make_cache(1, 32, dtype=jnp.float32)
    tok = None
    for t in prompt:
        lg, cache = model.decode_step(params, jnp.array([[t]]), cache, CTX)
        tok = int(np.asarray(lg[0, 0]).argmax())
    gen = [tok]
    for _ in range(4):
        lg, cache = model.decode_step(params, jnp.array([[gen[-1]]]), cache, CTX)
        gen.append(int(np.asarray(lg[0, 0]).argmax()))
    assert results[0] == gen


def test_max_new_one_returns_single_token():
    """max_new=1 is satisfied by the prefill token alone — no extra decode."""
    cfg, model, params, _ = _setup()
    server = BatchedServer(model, CTX, params, slots=1, max_len=32)
    out = server.run([Request(0, np.array([5, 17, 3], np.int32), 1)])
    assert len(out[0]) == 1


def test_sequential_prefill_isolated_from_active_slots():
    """Recurrent-state families prefill into a fresh row cache: admitting a
    request must never advance other active slots' state (two identical
    prompts across slots generate identically, matching a dedicated server)."""
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("mamba2-780m"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = np.array([5, 17, 3], np.int32)
    server = BatchedServer(model, CTX, params, slots=2, max_len=32)
    assert not server.batched_prefill  # ssm takes the sequential path
    out = server.run([Request(0, p, 4), Request(1, p, 4)])
    assert out[0] == out[1]
    ref = BatchedServer(model, CTX, params, slots=1, max_len=32).run(
        [Request(0, p, 4)]
    )
    assert out[0] == ref[0]


def test_slot_reuse_after_eviction():
    """A new request admitted into a used slot must not see stale cache."""
    cfg, model, params, _ = _setup()
    p1 = np.array([5, 17, 3], np.int32)
    p2 = np.array([9, 2, 44], np.int32)
    # serve p2 alone on a fresh server
    fresh = BatchedServer(model, CTX, params, slots=1, max_len=32)
    ref = fresh.run([Request(0, p2, 4)])[0]
    # serve p1 then p2 through the SAME slot
    server = BatchedServer(model, CTX, params, slots=1, max_len=32)
    out = server.run([Request(0, p1, 4), Request(1, p2, 4)])
    assert out[1] == ref
