"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — encoder-decoder, audio stub.

24-layer speech encoder (precomputed frame embeddings via ``input_specs()`` —
the conformer frontend is a stub per the assignment) + 24-layer text decoder
with cross-attention. head_dim = 1024/16 = 64.
"""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    norm_type="layernorm",
    act="relu",
    glu=False,
    rope_theta=1e4,
    encdec=EncDecConfig(encoder_layers=24, encoder_seq_factor=1.0),
    frontend="audio",
)
