"""Pure-jnp oracle for the cordic_mac kernel.

The kernel computes, exactly:

    out = (x_q.astype(i32) @ w_q.astype(i32)) * x_scale * w_scale   [+ relu]

where x_q is the per-row-scale quantization of x and w_q the depth-d
signed-digit quantization of w (see ops.py). The oracle reproduces that
arithmetic with plain jnp ops — integer matmul carried in float32 is exact
for the value ranges involved (|acc| < 2^22 for K <= 2^8 tiles at int8).
"""
from __future__ import annotations

import jax.numpy as jnp


def mac_matmul_ref(x_q, w_q, x_scale, w_scale, *, fuse_relu: bool = False):
    acc = jnp.dot(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    out = acc.astype(jnp.float32) * x_scale * w_scale
    if fuse_relu:
        out = jnp.maximum(out, 0.0)
    return out
