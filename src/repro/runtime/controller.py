"""The serving-loop mode controller: per-observation execution-point selection.

Once per observation — a classic decode step, a speculative round, or a whole
decode burst — the :class:`ModeController` reads :class:`StepSignals` (cheap
telemetry the server already has in hand) and votes to demote (move to a
cheaper execution point), promote (toward accurate), or hold:

* **cycle budget**: an EMA of the relative MAC-cycle cost of recent steps is
  steered toward ``cycle_budget`` (a fraction of the all-accurate cost, e.g.
  0.75). Over budget always demotes and blocks promotion — the latency
  target is hard.
* **admission pressure**: a non-empty queue with zero free slots demotes —
  approximate tokens now beat accurate tokens later under load.
* **logit margin**: when the *least confident* active slot still has a top-2
  logit margin above ``margin_demote``, approximation is safe (argmax will
  not flip); a margin below ``margin_promote`` asks for accuracy back.

Votes must repeat ``hysteresis`` consecutive observations before the
controller moves one rung on the bank's cheap->accurate ladder, so transient
signals do not thrash the jit cache; under burst serving the cadence is one
vote per burst, which is exactly the coarse reconfiguration interval the
engine wants (switching mid-burst would force a host sync). The accuracy floor is structural, not a vote: every
reachable point pins critical layers accurate (``pin_critical``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .bank import MultiPointBank

__all__ = ["ControllerConfig", "ModeController", "StepSignals"]


@dataclasses.dataclass(frozen=True)
class StepSignals:
    """One observation's telemetry, as seen by the controller.

    With burst serving the server aggregates a whole decode burst into one
    observation: ``min_margin`` is the minimum over every token the burst
    emitted, and ``steps`` is the number of engine steps it covered (so the
    cycle-budget EMA advances as if each step had been observed
    individually — burst-granular adaptivity costs zero extra device syncs
    and no budget-tracking fidelity).
    """

    active: int = 0
    queue_depth: int = 0
    free_slots: int = 0
    min_margin: Optional[float] = None  # top-2 logit margin, least confident slot
    steps: int = 1                      # engine steps this observation covers
    # overload telemetry (resilient serving): deadline misses and shed
    # requests since the last observation. The base controller ignores both;
    # a DegradationPolicy wrapper reads them as pressure signals.
    deadline_misses: int = 0
    shed: int = 0


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    margin_demote: float = 6.0      # min margin above which approx is safe
    margin_promote: float = 1.5     # min margin below which accuracy is wanted
    cycle_budget: Optional[float] = None  # target mean relative cycles (0, 1]
    hysteresis: int = 2             # consecutive same-direction votes per move
    ema: float = 0.9                # smoothing of the relative-cycle estimate
    pin: Optional[str] = None       # fix the controller to one point (no adaptation)
    start: Optional[str] = None     # initial point (default: the reference)


class ModeController:
    """Feedback loop selecting the bank execution point for each decode step."""

    def __init__(self, bank: MultiPointBank, config: Optional[ControllerConfig] = None):
        self.bank = bank
        self.cfg = config or ControllerConfig()
        for name in (self.cfg.pin, self.cfg.start):
            if name is not None and name not in bank.names:
                raise ValueError(f"unknown execution point {name!r}; bank has {bank.names}")
        if self.cfg.cycle_budget is not None and not 0.0 < self.cfg.cycle_budget:
            raise ValueError("cycle_budget must be positive")
        # optional switch listener ``(old_point, new_point, signals)`` —
        # serving observability subscribes here so every ladder move lands on
        # the trace with the StepSignals that caused it. Survives reset()
        # (the wiring is per server run, not per controller episode).
        self.on_switch = None
        self.reset()

    def reset(self) -> None:
        """Return to the configured initial point with no accumulated state.

        ``BatchedServer.run`` calls this on entry so consecutive ``run()``
        invocations are independent (no EMA / streak / switch-count leakage).
        """
        initial = self.cfg.pin or self.cfg.start or self.bank.reference
        self._idx = self.bank.index(initial)
        self._streak = 0
        self.switches = 0
        self._rel_ema = self.bank.rel_cycles(initial)

    # -- state ----------------------------------------------------------------
    @property
    def point(self) -> str:
        """The execution point the NEXT step will run at."""
        return self.bank.points[self._idx].name

    def tree(self):
        """The prepared weight tree for the current point (zero-copy switch)."""
        return self.bank.tree(self.point)

    @property
    def rel_cycles_ema(self) -> float:
        return self._rel_ema

    # -- feedback -------------------------------------------------------------
    def observe(self, signals: StepSignals) -> str:
        """Account for the step/burst just executed and pick the next point.

        An observation covering ``signals.steps`` engine steps moves the
        relative-cycle EMA exactly as far as that many single-step
        observations at the same point would have.
        """
        cfg = self.cfg
        alpha = cfg.ema ** max(signals.steps, 1)
        self._rel_ema = alpha * self._rel_ema + (1.0 - alpha) * self.bank.rel_cycles(
            self.point
        )
        if cfg.pin is not None:
            return self.point

        over_budget = cfg.cycle_budget is not None and self._rel_ema > cfg.cycle_budget
        pressure = signals.queue_depth > 0 and signals.free_slots == 0
        margin = signals.min_margin
        # a NaN/Inf margin means the logits themselves are suspect (a fault
        # the serving loop quarantines separately) — it must never read as
        # "confident" or "uncertain", so it votes exactly like no margin
        if margin is not None and not math.isfinite(margin):
            margin = None
        confident = margin is not None and margin >= cfg.margin_demote
        uncertain = margin is not None and margin < cfg.margin_promote

        if uncertain and not over_budget and not pressure:
            want = +1
        elif over_budget or pressure or confident:
            want = -1
        else:
            want = 0

        if want == 0:
            self._streak = 0
            return self.point
        self._streak = want if self._streak * want <= 0 else self._streak + want
        if abs(self._streak) >= cfg.hysteresis:
            new_idx = min(max(self._idx + (1 if want > 0 else -1), 0),
                          len(self.bank.points) - 1)
            if new_idx != self._idx:
                old = self.point
                self._idx = new_idx
                self.switches += 1
                if self.on_switch is not None:
                    self.on_switch(old, self.point, signals)
            self._streak = 0
        return self.point
