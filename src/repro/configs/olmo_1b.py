"""olmo-1b [arXiv:2402.00838; hf] — dense, non-parametric LayerNorm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparametric",
    act="swish",
    glu=True,
    rope_theta=1e4,
    tie_embeddings=True,
)
