"""Encoder-decoder model (seamless-m4t-large-v2).

Speech encoder (24 bidirectional layers over stub frame embeddings — the
conformer frontend is a STUB per the assignment; ``input_specs`` supplies
precomputed frames) + text decoder (24 causal layers with cross-attention).

The audio frontend stub still exercises CARMEN's AAD pooling unit: frames are
2x-downsampled with ``aad_pool_1d`` before entering the encoder, mirroring the
paper's "on-the-fly AAD pooling" peripheral.

Decode: decoder self-attn KV caches + cross-attn K/V computed once from the
encoder output at prefill (cached thereafter).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext
from repro.core.pooling import aad_pool_1d

from repro.sharding.partition import constrain

from . import blocks
from .params import ParamSpec, stack_layers


def _enc_layer_specs(cfg: ModelConfig):
    return {
        "attn_norm": blocks.norm_spec(cfg),
        "attn": blocks.attention_specs(cfg),
        "mlp_norm": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_specs(cfg),
    }


def _dec_layer_specs(cfg: ModelConfig):
    return {
        "self_norm": blocks.norm_spec(cfg),
        "self_attn": blocks.attention_specs(cfg),
        "cross_norm": blocks.norm_spec(cfg),
        "cross_attn": blocks.attention_specs(cfg),
        "mlp_norm": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_specs(cfg),
    }


def encdec_specs(cfg: ModelConfig):
    e = cfg.encdec
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "enc_layers": stack_layers(lambda: _enc_layer_specs(cfg), e.encoder_layers),
        "enc_norm": blocks.norm_spec(cfg),
        "dec_layers": stack_layers(lambda: _dec_layer_specs(cfg), cfg.num_layers),
        "final_norm": blocks.norm_spec(cfg),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


def _cross_attention(p, x, enc_k, enc_v, cfg, ctx, name):
    """Query from decoder states against precomputed encoder K/V (H-layout)."""
    b, s, _ = x.shape
    g, hd = cfg.kv_groups, cfg.head_dim
    q = blocks._proj(ctx, x, p["wq"], p.get("bq"), f"{name}.q")  # (B,S,H,hd)
    ek = jnp.repeat(enc_k, g, axis=2) if g > 1 else enc_k
    ev = jnp.repeat(enc_v, g, axis=2) if g > 1 else enc_v
    t = enc_k.shape[1]
    out = blocks._sdpa_chunked(
        q, ek, ev, jnp.arange(s), jnp.arange(t), causal=False
    )
    out = out.reshape(b, s, cfg.num_heads * hd)
    wo = p["wo"].reshape(cfg.num_heads * hd, cfg.d_model)
    return ctx.linear(out, wo, name=f"{name}.o")


def _project_enc_kv(p, enc_out, cfg, ctx, name):
    k = blocks._proj(ctx, enc_out, p["wk"], p.get("bk"), f"{name}.k")
    v = blocks._proj(ctx, enc_out, p["wv"], p.get("bv"), f"{name}.v")
    return k, v


def encode(params, frames, cfg: ModelConfig, ctx: EngineContext, *, remat: bool = False):
    """frames: (B, T, D) stub embeddings -> (B, T/2, D) encoder states."""
    h = aad_pool_1d(frames.astype(jnp.float32), 2).astype(cfg.compute_dtype)
    h = constrain(h, "batch", None, None)
    positions = jnp.arange(h.shape[1])

    def layer(h, p):
        h = constrain(h, "batch", None, None)
        x = blocks.apply_norm(p["attn_norm"], h, cfg)
        out, _ = blocks.attention(
            p["attn"], x, cfg, ctx, positions=positions, name="enc.attn", causal=False
        )
        h = h + out
        x = blocks.apply_norm(p["mlp_norm"], h, cfg)
        h = h + blocks.mlp(p["mlp"], x, cfg, ctx, name="enc.mlp")
        return h, None

    body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
    h, _ = jax.lax.scan(lambda h, p: body(h, p), h, params["enc_layers"])
    return blocks.apply_norm(params["enc_norm"], h, cfg)


def forward(params, batch, cfg: ModelConfig, ctx: EngineContext, *, remat: bool = False):
    """Teacher-forced train/prefill: frames + decoder tokens -> logits."""
    enc_out = encode(params, batch["frontend_embeds"], cfg, ctx, remat=remat)
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = constrain(h, "batch", None, None)
    positions = jnp.arange(h.shape[1])

    def layer(h, p):
        h = constrain(h, "batch", None, None)
        x = blocks.apply_norm(p["self_norm"], h, cfg)
        out, _ = blocks.attention(
            p["self_attn"], x, cfg, ctx, positions=positions, name="dec.self", causal=True
        )
        h = h + out
        x = blocks.apply_norm(p["cross_norm"], h, cfg)
        ek, ev = _project_enc_kv(p["cross_attn"], enc_out, cfg, ctx, "dec.cross")
        h = h + _cross_attention(p["cross_attn"], x, ek, ev, cfg, ctx, "dec.cross")
        x = blocks.apply_norm(p["mlp_norm"], h, cfg)
        h = h + blocks.mlp(p["mlp"], x, cfg, ctx, name="dec.mlp")
        return h, None

    body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
    h, _ = jax.lax.scan(lambda h, p: body(h, p), h, params["dec_layers"])
    h = blocks.apply_norm(params["final_norm"], h, cfg)
    logits = ctx.linear(h, params["lm_head"], name="lm_head").astype(jnp.float32)
    return logits, {}


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16, abstract=False):
    """Self-attn caches per decoder layer + cross K/V cache per layer."""
    e = cfg.encdec
    enc_t = max_len  # stub: encoder length tracks decoder budget
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    n = cfg.num_layers

    def sds(shape, dt=dtype):
        return jax.ShapeDtypeStruct(shape, dt) if abstract else jnp.zeros(shape, dt)

    return {
        "self": {
            "k": sds((n, batch, max_len, kvh, hd)),
            "v": sds((n, batch, max_len, kvh, hd)),
            "index": sds((n, batch), jnp.int32),
        },
        "cross": {
            "k": sds((n, batch, enc_t // 2, kvh, hd)),
            "v": sds((n, batch, enc_t // 2, kvh, hd)),
        },
    }


def prefill_cross_kv(params, enc_out, cfg, ctx):
    """Compute per-layer cross K/V from encoder states (once per request)."""

    def layer(_, p):
        k, v = _project_enc_kv(p["cross_attn"], enc_out, cfg, ctx, "dec.cross")
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(layer, None, params["dec_layers"])
    return {"k": ks, "v": vs}


def decode_step(params, tokens, cache, cfg: ModelConfig, ctx: EngineContext):
    """One decoder token against cached self/cross attention."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = constrain(h, "batch", None, None)
    index = cache["self"]["index"][0]  # (B,)
    positions = index[:, None] + jnp.arange(tokens.shape[1])[None, :]  # (B, S)

    def layer(h, xs):
        p, ck, cv, idx, xk, xv = xs
        x = blocks.apply_norm(p["self_norm"], h, cfg)
        out, nc = blocks.attention(
            p["self_attn"], x, cfg, ctx, positions=positions, name="dec.self",
            cache={"k": ck, "v": cv, "index": idx},
        )
        h = h + out
        x = blocks.apply_norm(p["cross_norm"], h, cfg)
        h = h + _cross_attention(p["cross_attn"], x, xk, xv, cfg, ctx, "dec.cross")
        x = blocks.apply_norm(p["mlp_norm"], h, cfg)
        h = h + blocks.mlp(p["mlp"], x, cfg, ctx, name="dec.mlp")
        return h, (nc["k"], nc["v"], nc["index"])

    h, (nk, nv, nidx) = jax.lax.scan(
        layer,
        h,
        (
            params["dec_layers"],
            cache["self"]["k"],
            cache["self"]["v"],
            cache["self"]["index"],
            cache["cross"]["k"],
            cache["cross"]["v"],
        ),
    )
    h = blocks.apply_norm(params["final_norm"], h, cfg)
    logits = ctx.linear(h, params["lm_head"], name="lm_head").astype(jnp.float32)
    new_cache = {"self": {"k": nk, "v": nv, "index": nidx}, "cross": cache["cross"]}
    return logits, new_cache
