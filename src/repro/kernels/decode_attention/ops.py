"""jit'd wrappers for the decode-attention kernels (model layout in/out)."""
from __future__ import annotations

import functools

import jax

from . import kernel as _k


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def gqa_decode_attention(q, ck, cv, positions, *, scale: float,
                         interpret: bool | None = None):
    """Cache-decode GQA attention: q (B, S, H, hd) against slot caches
    ck/cv (B, T, KV, hd) with per-query positions (B, S)."""
    interpret = _interpret_default() if interpret is None else interpret
    groups = q.shape[2] // ck.shape[2]
    return _k.gqa_decode(q, ck, cv, positions, groups=groups, scale=scale,
                         interpret=interpret)


def mla_decode_attention(q_lat, q_rope, c_kv, k_rope, positions, *,
                         scale: float, interpret: bool | None = None):
    """Cache-decode absorbed-MLA attention; returns latent output f32."""
    interpret = _interpret_default() if interpret is None else interpret
    return _k.mla_decode(q_lat, q_rope, c_kv, k_rope, positions, scale=scale,
                         interpret=interpret)
