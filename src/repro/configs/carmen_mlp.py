"""The paper's own MLP workload (Table V rows: "196-64-32-32-10").

This is the network the compared CORDIC accelerators (TCAS-I'22 [23],
ISCAS'25 [5], ICIIS'25 [1]) run; we use it for the fig3 accuracy sweep and
the table5 scaling benchmark.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str = "carmen-mlp-196"
    layer_sizes: Tuple[int, ...] = (196, 64, 32, 32, 10)
    act: str = "sigmoid"  # the classic benchmark uses sigmoid hidden units


CONFIG = MLPConfig()
