"""VectorEngine dispatch: mode agreement, STE gradients, traced-depth switching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FXP8,
    FXP16,
    EngineContext,
    PrecisionPolicy,
    carmen_dot,
    full_depth,
    int8_dot,
)
from repro.core.engine import sd_round_traced
from repro.core.cordic import signed_digit_round


def test_exact_mode_matches_matmul(rng):
    ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
    x = rng.standard_normal((4, 32)).astype(np.float32)
    w = rng.standard_normal((32, 8)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ctx.dot(x, w)), x @ w, rtol=1e-4, atol=1e-4)


def test_carmen_mode_error_bounded(rng):
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16), compute_dtype=jnp.float32)
    x = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
    w = rng.uniform(-1, 1, (64, 16)).astype(np.float32)
    out = np.asarray(ctx.dot(x, w, name="mlp.up"))
    rel = np.abs(out - x @ w) / (np.abs(x @ w) + 1.0)
    assert np.max(rel) < 0.01


def test_int8_mode_error_bounded(rng):
    ctx = EngineContext(mode="int8", policy=PrecisionPolicy.accurate(FXP8), compute_dtype=jnp.float32)
    x = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
    w = rng.uniform(-1, 1, (64, 16)).astype(np.float32)
    out = np.asarray(ctx.dot(x, w, name="mlp.up"))
    rel = np.abs(out - x @ w) / (np.abs(x @ w) + 1.0)
    assert np.max(rel) < 0.05


def test_int8_effective_bits_monotone(rng):
    x = rng.uniform(-1, 1, (8, 64)).astype(np.float32)
    w = rng.uniform(-1, 1, (64, 16)).astype(np.float32)
    errs = []
    for bits in (8, 6, 4, 2):
        out = np.asarray(int8_dot(x, w, effective_bits=bits))
        errs.append(np.mean(np.abs(out - x @ w)))
    assert errs[0] < errs[-1]


def test_ste_gradient_flows(rng):
    """carmen mode must be trainable: grads equal the exact-matmul grads (STE)."""
    x = rng.uniform(-1, 1, (4, 16)).astype(np.float32)
    w = rng.uniform(-1, 1, (16, 4)).astype(np.float32)

    def loss_carmen(w):
        return jnp.sum(carmen_dot(x, w, full_depth(FXP16)) ** 2) / 2

    g = jax.grad(loss_carmen)(w)
    # STE backward uses exact matmul; forward is quantized — compare against
    # d/dw of 0.5*||xw_q||^2 = x^T (x w_q)
    fwd = np.asarray(carmen_dot(x, w, full_depth(FXP16)))
    expected = x.T @ fwd
    np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-4, atol=1e-5)
    assert np.any(np.asarray(g) != 0)


def test_traced_depth_one_program_many_depths(rng):
    """Runtime-adaptive switching: a single jitted program serves any depth."""
    w = rng.uniform(-1.9, 1.9, 256).astype(np.float32)
    f = jax.jit(lambda d: sd_round_traced(w, d, FXP16))
    for d in (3, 7, 15):
        traced = np.asarray(f(d))
        static = np.asarray(signed_digit_round(w, d, FXP16))
        np.testing.assert_array_equal(traced, static)


def test_policy_overrides_apply():
    pol = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode="carmen", policy=pol)
    assert ctx.layer_precision("anything").depth == full_depth(FXP8)
    from repro.core import LayerPrecision

    pol2 = PrecisionPolicy(LayerPrecision(FXP8, 7), {"mlp": LayerPrecision(FXP16, 4)})
    ctx2 = EngineContext(mode="carmen", policy=pol2)
    assert ctx2.layer_precision("layer3.mlp.up").fmt == FXP16
    assert ctx2.layer_precision("layer3.attn.q").fmt == FXP8
