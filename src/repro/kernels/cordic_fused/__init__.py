from .kernel import FUSED_AFS, POINT_LEN, af_epilogue, make_point
from .ops import fused_dot_af, fused_dot_af_ref, fuse_supported

__all__ = [
    "FUSED_AFS",
    "POINT_LEN",
    "af_epilogue",
    "make_point",
    "fused_dot_af",
    "fused_dot_af_ref",
    "fuse_supported",
]
