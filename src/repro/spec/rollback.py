"""KV-cache rollback: truncate drafted rows past the accepted prefix.

Attention/MLA decode caches are (rows, write index) pairs per layer; the
per-query-causal mask (``key_pos <= query_pos``) makes every row at a position
``>= index`` invisible. Truncation is therefore a pure index rewrite: rows
past the accepted prefix stay resident as garbage and are overwritten by the
next draft/verify round. Recurrent-state families (ssm/hybrid/audio) carry no
positional index and cannot roll back — ``BatchedServer`` rejects speculation
for them.

Index leaves are identified exactly as ``transformer._cache_index`` does:
integer dtype, stacked ``(layers, batch)`` shape; every attention layer
advances in lockstep so one ``(B,)`` vector describes the whole cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_index(leaf) -> bool:
    return (
        hasattr(leaf, "dtype")
        and jnp.issubdtype(leaf.dtype, jnp.integer)
        and getattr(leaf, "ndim", 0) >= 2
    )


def cache_positions(cache):
    """Per-slot committed row counts, ``(B,)`` int32 (layer 0 is authoritative)."""
    for leaf in jax.tree.leaves(cache):
        if _is_index(leaf):
            return leaf[0]
    raise ValueError(
        "cache carries no write index — recurrent-state caches cannot be "
        "positioned/rolled back"
    )


def with_cache_positions(cache, positions):
    """Rewrite every layer's write index to ``positions`` ((B,) int32)."""
    positions = jnp.asarray(positions, jnp.int32)

    def put(leaf):
        if _is_index(leaf):
            return jnp.broadcast_to(positions, leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree.map(put, cache)


def rollback(cache, committed):
    """Truncate each slot's cache to its ``committed`` row count.

    Rows at positions ``>= committed[b]`` (rejected drafts, the speculative
    scratch region) become invisible to all subsequent queries and are
    reclaimed by the next round's writes.
    """
    return with_cache_positions(cache, committed)
