"""qwen2.5-14b [hf:Qwen/Qwen2.5-*] — dense GQA with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=1e6,
)
