"""Refresh dry-run artifacts from their dumped HLO (analyzer iterations are
offline — no recompilation needed). Usage:
    PYTHONPATH=src python -m repro.launch.reanalyze [--dir artifacts/dryrun]
"""
import argparse
import glob
import gzip
import json
import os

from repro.launch import hlo_analysis


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")
    ap.add_argument("--dir", default=default_dir)
    args = ap.parse_args()
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        stem = os.path.basename(path)[:-5]
        hlo_path = os.path.join(args.dir, "hlo", stem + ".hlo.gz")
        if not os.path.exists(hlo_path):
            print(f"[miss] {stem}: no HLO dump")
            continue
        with gzip.open(hlo_path, "rt") as f:
            costs = hlo_analysis.analyze(f.read())
        rec.update(
            flops_dev=costs.dot_flops,
            hbm_bytes_dev=costs.hbm_bytes,
            hbm_bytes_upper_dev=costs.hbm_bytes_upper,
            coll_bytes_dev=costs.collective_bytes,
            coll_by_kind={k: float(v) for k, v in costs.collective_by_kind.items()},
            while_trips=costs.while_trips[:64],
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[ok] {stem}: flops {costs.dot_flops:.3e} hbm {costs.hbm_bytes/1e9:.0f}GB "
              f"coll {costs.collective_bytes/1e9:.1f}GB")


if __name__ == "__main__":
    main()
