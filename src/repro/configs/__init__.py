"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    EncDecConfig,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    reduced,
    shape_applicable,
)

from . import (
    deepseek_v3_671b,
    internvl2_2b,
    llama4_maverick,
    mamba2_780m,
    olmo_1b,
    qwen2_5_14b,
    qwen3_8b,
    seamless_m4t_v2,
    yi_9b,
    zamba2_7b,
)
from . import carmen_mlp, carmen_vgg16  # the paper's own workloads

ARCHS = {
    "olmo-1b": olmo_1b.CONFIG,
    "qwen3-8b": qwen3_8b.CONFIG,
    "qwen2.5-14b": qwen2_5_14b.CONFIG,
    "yi-9b": yi_9b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick.CONFIG,
    "internvl2-2b": internvl2_2b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_v2.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[arch]
    cfg.validate()
    return cfg


__all__ = [
    "ARCHS",
    "get_config",
    "reduced",
    "shape_applicable",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "HybridConfig",
    "EncDecConfig",
    "ShapeConfig",
    "SHAPES",
    "ALL_SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
