"""Throughput-trend gate: fresh BENCH records vs the committed snapshots.

CI regenerates ``artifacts/bench/BENCH_*.json`` every run; the committed
copies are the last reviewed snapshot. This check diffs every throughput
metric (any numeric ``tok_s``-keyed field, matched by its JSON path)
between the fresh files on disk and the committed baseline
(``git show <ref>:<path>``), and exits nonzero when any metric regresses
more than ``--tolerance`` (default 10%).

Raw ratios would gate on machine speed, not code: CI runners differ run to
run. So each file's ratios are normalized by the median fresh/baseline
ratio across ALL of that file's metrics — a uniformly slower machine moves
every ratio equally and normalizes away, while a single config regressing
against its siblings stands out. A file where *everything* regressed
together is indistinguishable from a slow machine by construction; that
case is surfaced in the report (median printed per file) but not gated.

    PYTHONPATH=src python -m benchmarks.check_trend --tolerance 0.10

Files missing on either side (new benchmarks, removed ones) are reported
and skipped, not failed — the gate compares only paths present in both.
Skips are always *with notice*: a brand-new ``BENCH_*.json`` (no committed
baseline yet — the state every PR that lands a new benchmark creates), new
metric paths inside an existing file, and a git lookup that cannot run at
all are each printed and tallied in the final summary, so "nothing gated"
is visible rather than a silent pass.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

__all__ = ["collect_tok_s", "compare_records", "main"]


def collect_tok_s(node, path: str = "") -> List[Tuple[str, float]]:
    """Every numeric ``tok_s``-keyed metric in a JSON document, with its
    path (``configs.dense.sweep[1].tok_s``) as the join key."""
    out = []
    if isinstance(node, dict):
        for key, val in node.items():
            sub = f"{path}.{key}" if path else key
            if "tok_s" in key and isinstance(val, (int, float)) \
                    and not isinstance(val, bool):
                out.append((sub, float(val)))
            else:
                out.extend(collect_tok_s(val, sub))
    elif isinstance(node, list):
        for i, val in enumerate(node):
            out.extend(collect_tok_s(val, f"{path}[{i}]"))
    return out


def compare_records(fresh: Dict, baseline: Dict, *,
                    tolerance: float) -> Tuple[List[Dict], Optional[float]]:
    """(regressions, median_ratio) for one fresh/baseline record pair.

    Ratios are fresh/baseline per common path, normalized by their median;
    a regression is a normalized ratio below ``1 - tolerance``.
    """
    fresh_m = dict(collect_tok_s(fresh))
    base_m = dict(collect_tok_s(baseline))
    common = [p for p in fresh_m if p in base_m and base_m[p] > 0]
    if not common:
        return [], None
    ratios = {p: fresh_m[p] / base_m[p] for p in common}
    median = statistics.median(ratios.values())
    if median <= 0:
        return [], median
    regressions = []
    for p in common:
        normalized = ratios[p] / median
        if normalized < 1.0 - tolerance:
            regressions.append({
                "path": p,
                "fresh": fresh_m[p],
                "baseline": base_m[p],
                "normalized_ratio": round(normalized, 4),
            })
    return regressions, median


def new_paths(fresh: Dict, baseline: Dict) -> List[str]:
    """Metric paths present in ``fresh`` but absent from ``baseline`` — new
    configs inside an existing benchmark file. They cannot be gated (nothing
    to compare against), so the caller reports them instead of letting them
    vanish silently."""
    base_m = dict(collect_tok_s(baseline))
    return [p for p, _ in collect_tok_s(fresh) if p not in base_m]


def _baseline_json(ref: str, repo_path: str) -> Optional[Dict]:
    """The committed copy of ``repo_path`` at ``ref`` (None if absent or if
    git itself cannot run — both are skip-with-notice, never a crash)."""
    try:
        proc = subprocess.run(
            ["git", "show", f"{ref}:{repo_path}"],
            capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(BENCH_DIR)),
        )
    except OSError as e:
        print(f"check_trend: git show {ref}:{repo_path} could not run "
              f"({e}) — treating as no baseline")
        return None
    if proc.returncode != 0:
        return None
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError:
        return None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=BENCH_DIR,
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref whose committed artifacts are the baseline")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="max fractional tok/s regression after "
                         "median-normalization")
    args = ap.parse_args(argv)

    fresh_paths = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not fresh_paths:
        print(f"check_trend: no BENCH_*.json under {args.dir}; nothing to do")
        return

    failures = []
    skipped: List[str] = []
    gated = 0
    for path in fresh_paths:
        name = os.path.basename(path)
        with open(path) as f:
            try:
                fresh = json.load(f)
            except json.JSONDecodeError:
                failures.append(f"{name}: fresh file is not valid JSON")
                continue
        baseline = _baseline_json(args.baseline_ref,
                                  f"artifacts/bench/{name}")
        if baseline is None:
            print(f"check_trend: NOTICE {name}: no committed baseline at "
                  f"{args.baseline_ref} (new benchmark?) — skipped, will be "
                  "gated once this file is committed")
            skipped.append(f"{name} (no baseline)")
            continue
        fresh_only = new_paths(fresh, baseline)
        if fresh_only:
            sample = ", ".join(fresh_only[:3])
            print(f"check_trend: NOTICE {name}: {len(fresh_only)} new metric "
                  f"path(s) with no committed baseline (e.g. {sample}) — "
                  "not gated until committed")
        regressions, median = compare_records(fresh, baseline,
                                              tolerance=args.tolerance)
        if median is None:
            print(f"check_trend: NOTICE {name}: no common tok_s metrics — "
                  "skipped")
            skipped.append(f"{name} (no common metrics)")
            continue
        gated += 1
        print(f"check_trend: {name}: "
              f"{len(dict(collect_tok_s(fresh)))} metrics, "
              f"median fresh/baseline ratio {median:.3f}, "
              f"{len(regressions)} regression(s)")
        for reg in regressions:
            failures.append(
                f"{name}: {reg['path']} at {reg['normalized_ratio']}x of its "
                f"siblings' trend (fresh {reg['fresh']}, committed "
                f"{reg['baseline']}, tolerance {args.tolerance})")

    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    note = f", {len(skipped)} file(s) skipped with notice" if skipped else ""
    print(f"check_trend: no per-config tok/s regressions beyond "
          f"{args.tolerance:.0%} ({gated} file(s) gated{note})")


if __name__ == "__main__":
    main()
