"""Property tests for the unified CORDIC core — the paper's central invariant:
iteration depth d bounds the multiplier residual by 2^-(d-1)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    FXP8,
    FXP8_UNIT,
    FXP16,
    FXP16_UNIT,
    cordic_div,
    cordic_exp,
    cordic_mul,
    dequantize,
    full_depth,
    quantize,
    signed_digit_round,
)
from repro.core.cordic import hyperbolic_sequence, linear_rotate


def test_hyperbolic_sequence_repeats():
    seq = hyperbolic_sequence(20)
    assert seq[:6] == (1, 2, 3, 4, 4, 5)
    assert seq.count(4) == 2 and seq.count(13) == 2


@pytest.mark.parametrize("fmt,w_fmt", [(FXP8, FXP8_UNIT), (FXP16, FXP16_UNIT)], ids=["fxp8", "fxp16"])
@pytest.mark.parametrize("depth_frac", [1.0, 2 / 3, 0.5])
def test_mul_error_bound(fmt, w_fmt, depth_frac, rng):
    """|cordic_mul(x,w) - x*w| <= |x| 2^-(d-1) + d LSB(x) (sd residual + shift truncation)."""
    depth = max(2, int(full_depth(w_fmt) * depth_frac))
    x = rng.uniform(fmt.min_value, fmt.max_value, 2048).astype(np.float32)
    w = rng.uniform(-1.98, 1.98, 2048).astype(np.float32)
    xq, wq = quantize(x, fmt), quantize(w, w_fmt)
    y = np.asarray(dequantize(cordic_mul(xq, wq, depth, w_fmt), fmt))
    true = np.asarray(dequantize(xq, fmt)) * np.asarray(dequantize(wq, w_fmt))
    bound = np.abs(np.asarray(dequantize(xq, fmt))) * 2.0 ** (-(depth - 1)) + depth * fmt.scale
    assert np.all(np.abs(y - true) <= bound + 1e-6)


@given(w=st.floats(-1.9375, 1.9375, allow_nan=False, width=32), depth=st.integers(2, 15))
@settings(max_examples=300, deadline=None)
def test_signed_digit_residual(w, depth):
    """sd_round is w rounded onto the depth-d signed-digit grid: residual <= 2^-(d-1)."""
    sd = float(signed_digit_round(np.float32(w), depth, FXP16_UNIT))
    wq = float(dequantize(quantize(np.float32(w), FXP16_UNIT), FXP16_UNIT))
    assert abs(sd - wq) <= 2.0 ** (-(depth - 1)) + FXP16_UNIT.scale


def test_depth_monotonicity(rng):
    """More iterations never hurt (on average): mean |err| shrinks with depth."""
    x = rng.uniform(-1.9, 1.9, 4096).astype(np.float32)
    w = rng.uniform(-1.9, 1.9, 4096).astype(np.float32)
    xq, wq = quantize(x, FXP16), quantize(w, FXP16_UNIT)
    true = np.asarray(dequantize(xq, FXP16)) * np.asarray(dequantize(wq, FXP16_UNIT))
    errs = []
    for d in (3, 6, 9, 12, 15):
        y = np.asarray(dequantize(cordic_mul(xq, wq, d, FXP16_UNIT), FXP16))
        errs.append(np.mean(np.abs(y - true)))
    assert all(errs[i + 1] < errs[i] for i in range(len(errs) - 1)), errs


def test_cycle_reduction_claim():
    """Paper C2: approximate mode saves ~33% of iterations."""
    from repro.core import approx_depth, mac_cycles

    full, approx = full_depth(FXP16_UNIT), approx_depth(FXP16_UNIT)
    saving = 1 - mac_cycles(64, approx) / mac_cycles(64, full)
    assert 0.25 <= saving <= 0.40, saving


@pytest.mark.parametrize("fmt", [FXP16], ids=str)
def test_div(fmt, rng):
    num = rng.uniform(0.0, 1.0, 2048).astype(np.float32)
    den = rng.uniform(1.0, 2.0, 2048).astype(np.float32)
    q = np.asarray(dequantize(cordic_div(quantize(num, fmt), quantize(den, fmt), full_depth(fmt), fmt), fmt))
    assert np.max(np.abs(q - num / den)) <= 8 * fmt.scale


def test_exp_accuracy(rng):
    x = rng.uniform(-8.0, 0.0, 4096).astype(np.float32)
    e = np.asarray(dequantize(cordic_exp(quantize(x, FXP16), full_depth(FXP16), FXP16), FXP16))
    assert np.max(np.abs(e - np.exp(x))) <= 16 * FXP16.scale


def test_exp_range_reduction_boundaries():
    """Exercise quotient rounding around multiples of ln2 (incl. negatives)."""
    pts = np.array([k * math.log(2) + d for k in range(-8, 1) for d in (-0.01, 0.0, 0.01)], np.float32)
    e = np.asarray(dequantize(cordic_exp(quantize(pts, FXP16), full_depth(FXP16), FXP16), FXP16))
    assert np.max(np.abs(e - np.exp(pts))) <= 16 * FXP16.scale


def test_linear_rotate_residual_returned(rng):
    import jax.numpy as jnp

    x = quantize(np.float32(1.0), FXP16)
    z = quantize(np.float32(0.7), FXP16_UNIT)
    y, zres = linear_rotate(x, jnp.int32(0), z, 10, FXP16_UNIT)
    assert abs(int(zres)) <= FXP16_UNIT.one >> 8  # |z residual| <= 2^-(d-2) raw
