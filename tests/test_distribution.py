"""Distribution layer: sharding rules, constraint helper, HLO analyzer,
and small-mesh lowering of the real train/decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch.hlo_analysis import analyze
from repro.models import get_model
from repro.models.params import ParamSpec
from repro.sharding import partition


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) != 1:
        pytest.skip("host-device test")
    return jax.make_mesh((1, 1), ("data", "model"))


def _pspec_entries(ps):
    """Normalize PartitionSpec entries for version-robust comparison — jax
    releases disagree on whether ``P(("data",), m)`` equals ``P("data", m)``."""
    return tuple(
        None if e is None else (e,) if isinstance(e, str) else tuple(e)
        for e in ps
    )


def test_param_pspec_rules(mesh):
    spec = ParamSpec((64, 16, 128), ("embed", "heads", "head_dim"))
    ps = partition.param_pspec(spec, mesh)
    # head_dim replicated -> trailing None trimmed
    assert _pspec_entries(ps) == (("data",), ("model",))


def test_param_pspec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # dims of size 1 divide anything; force non-divisible with a fake extent via
    # a 3-wide dim against model axis of 1 -> still divides. Use axis not in rules:
    spec = ParamSpec((7,), ("conv",))
    assert partition.param_pspec(spec, mesh) == P()


def test_no_duplicate_mesh_axes(mesh):
    spec = ParamSpec((64, 64), ("mlp", "experts"))  # both want "model"
    ps = partition.param_pspec(spec, mesh)
    used = [e for e in ps if e is not None]
    assert len(used) <= 1  # second claim on "model" must be dropped


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    out = partition.constrain(x, "batch", None)
    assert out.shape == x.shape


def test_constrain_inside_mesh(mesh):
    with mesh:
        f = jax.jit(lambda x: partition.constrain(x * 2, "batch", None))
        np.testing.assert_allclose(np.asarray(f(jnp.ones((4, 4)))), 2.0)


def test_hlo_analyzer_scan_correction():
    """The analyzer must multiply while-body costs by the trip count."""

    def body(h, w):
        return jnp.tanh(h @ w), None

    def scanned(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    costs = analyze(jax.jit(scanned).lower(h, ws).compile().as_text())
    assert costs.dot_flops == 5 * 2 * 32 * 64 * 64
    assert 5 in costs.while_trips


def test_hlo_analyzer_grad_counts_backward():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    fwd = analyze(jax.jit(loss).lower(w, x).compile().as_text()).dot_flops
    bwd = analyze(jax.jit(jax.grad(loss)).lower(w, x).compile().as_text()).dot_flops
    assert bwd >= 2 * fwd  # dL/dw and dL/dx dots


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-v3-671b", "mamba2-780m"])
def test_reduced_train_step_lowers_with_shardings(arch, mesh):
    """The full train step (sharded params/opt) lowers+compiles on a 1x1 mesh."""
    from repro.core import EngineContext
    from repro.train import optimizer as opt
    from repro.train.train_loop import TrainConfig, make_train_step

    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    with mesh:
        specs = model.specs()
        param_sh, _ = partition.param_shardings(specs, mesh)
        aparams = model.abstract_params(jnp.float32)
        aopt = opt.abstract_state(aparams)
        step = make_train_step(model, EngineContext(mode="exact", compute_dtype=jnp.float32),
                               TrainConfig(remat=True))
        batch = {
            "tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
            "targets": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        }
        compiled = jax.jit(step).lower(aparams, aopt, batch).compile()
        assert compiled.cost_analysis() is not None


def test_cache_shardings_skip_unsplittable_batch(mesh):
    cfg = reduced(get_config("mamba2-780m"))
    model = get_model(cfg)
    cache = model.make_cache(1, 16, jnp.float32, abstract=True)
    sh = partition.cache_shardings(cache, mesh, cfg)
    for leaf in jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)):
        assert isinstance(leaf, jax.sharding.NamedSharding)
