"""Exact backend: FP32/bf16 matmul — the paper's FP32 baseline."""
from __future__ import annotations

import jax.numpy as jnp

from .base import Backend, PreparedWeight

__all__ = ["ExactBackend"]


class ExactBackend(Backend):
    name = "exact"

    def dot(self, ctx, x, w, *, name: str = ""):
        if isinstance(w, PreparedWeight):
            w = w.data
        out_dt = ctx.compute_dtype if ctx.tp_reduce_bf16 else jnp.float32
        return jnp.dot(
            x.astype(ctx.compute_dtype),
            w.astype(ctx.compute_dtype),
            preferred_element_type=out_dt,
        ).astype(ctx.compute_dtype)
