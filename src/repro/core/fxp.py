"""Fixed-point (FxP) number formats and quantization — CARMEN's multi-precision substrate.

CARMEN supports FxP-8 and FxP-16 operands (paper Table I, "Precision: FxP-8/16").
A format is ``Q<int>.<frac>`` with one sign bit: ``bits = 1 + int_bits + frac``.
Raw values are carried as int32 regardless of storage width so that CORDIC
shift-add arithmetic (``core/cordic.py``) has headroom; the *storage* dtype
(int8/int16) only matters at the memory interface (kernels, checkpoints).

Two quantization regimes coexist in the framework:

* **Binary-point FxP** (this module): scale is a power of two fixed by the
  format. This is what the silicon datapath uses and what the bit-faithful
  CORDIC simulation consumes.
* **Scaled integer quantization** (``repro/quant``): per-tensor/per-channel
  float scales for production int8 inference on the MXU. The precision policy
  maps CORDIC depth -> effective mantissa bits for both regimes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "FxPFormat",
    "FXP8",
    "FXP16",
    "FXP8_UNIT",
    "FXP16_UNIT",
    "quantize",
    "dequantize",
    "saturate",
    "requantize",
]


@dataclasses.dataclass(frozen=True)
class FxPFormat:
    """Signed fixed-point format: ``bits`` total (incl. sign), ``frac`` fractional bits."""

    bits: int
    frac: int

    def __post_init__(self):
        if self.frac < 0 or self.frac > self.bits - 1:
            raise ValueError(f"invalid FxP format Q{self.int_bits}.{self.frac} ({self.bits} bits)")

    @property
    def int_bits(self) -> int:
        return self.bits - 1 - self.frac

    @property
    def scale(self) -> float:
        """Value of one LSB."""
        return 2.0 ** (-self.frac)

    @property
    def one(self) -> int:
        """Raw representation of +1.0."""
        return 1 << self.frac

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def max_value(self) -> float:
        return self.qmax * self.scale

    @property
    def min_value(self) -> float:
        return self.qmin * self.scale

    @property
    def storage_dtype(self):
        if self.bits <= 8:
            return jnp.int8
        if self.bits <= 16:
            return jnp.int16
        return jnp.int32

    def __str__(self) -> str:  # e.g. "Q1.6"
        return f"Q{self.int_bits}.{self.frac}"


# Activation formats: FxP8 = Q1.6 (range [-2, 2)), FxP16 = Q3.12 (range [-8, 8)).
FXP8 = FxPFormat(8, 6)
FXP16 = FxPFormat(16, 12)
# Weight / multiplier formats: |w| < 2 is required for linear-CORDIC convergence
# (sum_k 2^-k = 2), so multipliers always use one integer bit.
FXP8_UNIT = FxPFormat(8, 6)
FXP16_UNIT = FxPFormat(16, 14)


def saturate(raw, fmt: FxPFormat):
    """Clip raw int32 values into the representable range of ``fmt``."""
    return jnp.clip(raw, fmt.qmin, fmt.qmax)


def quantize(x, fmt: FxPFormat, *, rounding: str = "nearest"):
    """Float -> raw int32 in ``fmt`` with saturation.

    ``rounding``: "nearest" (round half to even — what jnp.round implements,
    and the cheapest faithful choice for an RTL round-to-nearest stage) or
    "floor" (pure truncation, the cheapest silicon option).
    """
    scaled = jnp.asarray(x, jnp.float32) * float(1 << fmt.frac)
    if rounding == "nearest":
        q = jnp.round(scaled)
    elif rounding == "floor":
        q = jnp.floor(scaled)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    return saturate(q.astype(jnp.int32), fmt)


def dequantize(raw, fmt: FxPFormat):
    return jnp.asarray(raw, jnp.float32) * np.float32(fmt.scale)


def requantize(raw, src: FxPFormat, dst: FxPFormat):
    """Change binary point (and saturate into the destination format)."""
    raw = jnp.asarray(raw, jnp.int32)
    if dst.frac >= src.frac:
        out = raw << (dst.frac - src.frac)
    else:
        sh = src.frac - dst.frac
        # round-to-nearest on the dropped bits (add half LSB before shifting)
        out = (raw + (1 << (sh - 1))) >> sh
    return saturate(out, dst)
