"""PE-array simulator: cost model units, calibration fits, trace replay.

Three layers of pinning, cheapest first:

* **array** — ``dot_pass_cost`` on a degenerate config reproduces the
  analytic ``mac_cycles`` model exactly; waves, stalls, format bits, and
  the parallel penalty each move cost in the documented direction.
* **calibration** — ``fit_calibration`` recovers known constants from
  synthetic measurements, degrades gracefully when the depth signal is
  noise, and round-trips through JSON into ``estimate_point_cycles`` /
  ``build_bank`` without changing any pinned-controller serving decision
  (bit-identity: calibration refines the cost *scale*, never the greedy
  token stream).
* **replay** — a real serve trace replays deterministically, attributes
  cycles to every request/phase/layer, and reproduces the serving loop's
  own ``est_cycle_savings_frac`` (adaptive and speculative mirrors) from
  the trace alone.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (
    EngineContext,
    FXP8,
    PrecisionPolicy,
    mac_cycles,
)
from repro.models import get_model
from repro.obs import ServingObserver, iter_trace, read_trace
from repro.runtime import (
    ControllerConfig,
    ModeController,
    build_bank,
    default_points,
)
from repro.runtime.telemetry import calibration_id, estimate_point_cycles
from repro.serve.engine import BatchedServer, Request
from repro.sim import (
    ArrayConfig,
    dot_pass_cost,
    fit_calibration,
    load_calibration,
    replay_trace,
    save_calibration,
)
from repro.sim.analyze import (
    ordering_inversions,
    render,
    report_dict,
    savings_drift,
)
from repro.spec import SpecConfig


# -- array cost model ---------------------------------------------------------

IDEAL_1PE = ArrayConfig(n_pes=1, af_blocks=1, weight_bits_per_cycle=1e12,
                        af_cycles_per_elem=0.0)


def test_single_pe_reproduces_analytic_mac_cycles():
    # one PE, one lane, no stalls: the simulator IS mac_cycles
    for k, depth in ((1, 0), (64, 4), (256, 7), (512, 13)):
        c = dot_pass_cost(IDEAL_1PE, k, 1, depth)
        assert c.total == mac_cycles(k, depth)
        assert c.weight_stall == 0.0 and c.af_stall == 0.0


def test_wave_quantization_charges_partial_waves_fully():
    cfg = ArrayConfig(n_pes=256)
    full = dot_pass_cost(cfg, 64, 256, 7)
    partial = dot_pass_cost(cfg, 64, 257, 7)  # one extra lane -> whole wave
    assert partial.compute == pytest.approx(2 * full.compute)


def test_weight_stream_stall_binds_at_low_bandwidth():
    starved = ArrayConfig(n_pes=256, weight_bits_per_cycle=1.0)
    c = dot_pass_cost(starved, 64, 256, 7)
    assert c.weight_stall > 0
    # the bound resource's time is the total: stream = compute + stall
    assert c.total == pytest.approx(c.compute + c.weight_stall)
    assert dot_pass_cost(ArrayConfig(n_pes=256), 64, 256, 7).weight_stall == 0


def test_fxp16_streams_twice_the_bits():
    tight = ArrayConfig(n_pes=256, weight_bits_per_cycle=64.0)
    w8 = dot_pass_cost(tight, 64, 256, 7, bits=8)
    w16 = dot_pass_cost(tight, 64, 256, 7, bits=16)
    assert w16.weight_stall > w8.weight_stall


def test_af_contention_stalls_small_k_dots():
    # k tiny, n huge: the AF block outlives the MAC shadow
    cfg = ArrayConfig(n_pes=256, af_blocks=1)
    c = dot_pass_cost(cfg, 1, 4096, 7)
    assert c.af_stall > 0
    # more AF blocks drain the same work faster
    more = dot_pass_cost(ArrayConfig(n_pes=256, af_blocks=64), 1, 4096, 7)
    assert more.af_stall < c.af_stall


def test_af_cost_rides_the_depth_ladder():
    # AF is CORDIC-iterative: with af_iter_cycles fitted, per-point cost
    # stays proportional to depth+1 — the property that keeps calibrated
    # savings fractions equal to analytic ones
    cfg = ArrayConfig(n_pes=64, af_blocks=1, af_iter_cycles=4.0)
    c4 = dot_pass_cost(cfg, 8, 512, 4)
    c7 = dot_pass_cost(cfg, 8, 512, 7)
    assert c7.total / c4.total == pytest.approx((7 + 1) / (4 + 1))


def test_parallel_penalty_and_scaled_override():
    base = ArrayConfig(n_pes=256)
    penalized = base.scaled(parallel_overhead_exp=0.5)
    c0 = dot_pass_cost(base, 64, 256, 7)
    c1 = dot_pass_cost(penalized, 64, 256, 7)
    assert c1.total == pytest.approx(c0.total * 256 ** 0.5)
    assert penalized.n_pes == 256  # scaled() replaces only what it is given


def test_lane_scaling_exponent_round_trips():
    # the Table 5 shape: an N-lane dot on an N-PE array; the fitted exponent
    # must come back out of the full cost model
    exp = 0.37
    cost = {}
    for n in (64, 256):
        cfg = ArrayConfig(n_pes=n, parallel_overhead_exp=exp)
        cost[n] = dot_pass_cost(cfg, 512, n, 7, positions=128).total
    assert math.log(cost[256] / cost[64]) / math.log(4) == pytest.approx(exp)


def test_array_config_validates():
    with pytest.raises(ValueError):
        ArrayConfig(n_pes=0)
    with pytest.raises(ValueError):
        ArrayConfig(af_blocks=0)


# -- calibration --------------------------------------------------------------

def _synthetic_measurements(*, sec_per_iter=2e-9, mac_overhead=0.25,
                            dispatch_s=1e-4, af_iter=3.0, exponent=0.5):
    m, k, n = 64, 256, 64
    macs = m * k * n
    times = {d: dispatch_s + macs * sec_per_iter * (d + 1 + mac_overhead)
             for d in (2, 4, 7)}
    n_elems = 64 * 512
    af_depth = 7
    af_t = dispatch_s + n_elems * af_iter * (af_depth + 1) * sec_per_iter
    return {
        "mac": {"shape": [m, k, n], "times_by_depth": times},
        "dispatch_s": dispatch_s,
        "af": {"shape": [64, 512], "depth": af_depth, "n_elems": n_elems,
               "times_by_mode": {"relu": af_t, "gelu": af_t}},
        "lanes": {"shape": [1024, 256],
                  "times_by_n": {64: 1.0, 256: 4.0 ** exponent}},
        "smoke": True,
    }


def test_fit_recovers_known_constants():
    cal = fit_calibration(_synthetic_measurements())
    c = cal["constants"]
    assert c["sec_per_cycle"] == pytest.approx(2e-9, rel=1e-6)
    assert c["mac_overhead"] == pytest.approx(0.25, rel=1e-3)
    assert c["af_iter_cycles"] == pytest.approx(3.0, rel=0.05)
    assert c["parallel_overhead_exp"] == pytest.approx(0.5, rel=1e-6)
    assert c["host_sync_cycles"] == pytest.approx(1e-4 / 2e-9, rel=1e-6)
    assert not cal["fit"]["mac_slope_fallback"]
    assert cal["fit"]["mac_fit_max_rel_resid"] < 1e-9
    assert cal["id"].startswith("calib-")


def test_fit_degrades_gracefully_without_depth_signal():
    meas = _synthetic_measurements()
    # depth-independent timings: the fast error-model's signature
    meas["mac"]["times_by_depth"] = {2: 3e-4, 4: 3e-4, 7: 3e-4}
    cal = fit_calibration(meas)
    assert cal["fit"]["mac_slope_fallback"]
    assert cal["constants"]["sec_per_cycle"] > 0
    assert 0.0 <= cal["constants"]["mac_overhead"] <= 1.0


def test_fit_requires_two_depths():
    meas = _synthetic_measurements()
    meas["mac"]["times_by_depth"] = {7: 1e-3}
    with pytest.raises(ValueError):
        fit_calibration(meas)


def test_calibration_roundtrip_and_guards(tmp_path):
    cal = fit_calibration(_synthetic_measurements())
    path = str(tmp_path / "cal.json")
    save_calibration(cal, path)
    loaded = load_calibration(path)
    assert loaded["constants"] == pytest.approx(cal["constants"])
    assert calibration_id(loaded) == cal["id"]
    assert calibration_id(None) == "analytic"

    cfg = ArrayConfig.from_calibration(loaded)
    assert cfg.mac_overhead == pytest.approx(0.25, rel=1e-3)
    assert cfg.sec_per_cycle == pytest.approx(2e-9, rel=1e-6)
    assert ArrayConfig.from_calibration(None) == ArrayConfig()

    bad = dict(cal, schema="something-else")
    bad_path = str(tmp_path / "bad.json")
    with open(bad_path, "w") as f:
        json.dump(bad, f)
    with pytest.raises(ValueError, match="schema"):
        load_calibration(bad_path)
    future = dict(cal, version=99)
    with open(bad_path, "w") as f:
        json.dump(future, f)
    with pytest.raises(ValueError, match="newer"):
        load_calibration(bad_path)


# -- calibration -> runtime costs --------------------------------------------

def _setup(d_model=64):
    cfg = reduced(get_config("olmo-1b"), layers=2, d_model=d_model)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_estimate_point_cycles_calibrated_preserves_ordering():
    _, model, params = _setup()
    policies = {
        "approx": PrecisionPolicy.approximate(FXP8),
        "accurate": PrecisionPolicy.accurate(FXP8),
    }
    cal = fit_calibration(_synthetic_measurements())
    for name in policies:
        analytic = estimate_point_cycles(params, policies[name],
                                         specs=model.specs())
        calibrated = estimate_point_cycles(params, policies[name],
                                           specs=model.specs(),
                                           calibration=cal)
        # mac_overhead only ever adds cycles
        assert calibrated > analytic
    # and the ladder ordering survives calibration
    a = estimate_point_cycles(params, policies["approx"], specs=model.specs(),
                              calibration=cal)
    b = estimate_point_cycles(params, policies["accurate"],
                              specs=model.specs(), calibration=cal)
    assert a < b


def _requests(cfg, n, *, max_new=6):
    rng = np.random.default_rng(0)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 4 + i).astype(np.int32),
                max_new)
        for i in range(n)
    ]


def test_calibrated_bank_pinned_controller_bit_identity():
    """Calibration rescales every point's cost estimate; a pinned controller
    must serve the exact same tokens either way, and the bank must record
    which cycle model priced it."""
    cfg, model, params = _setup()
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    cal = fit_calibration(_synthetic_measurements())
    outs = {}
    banks = {}
    for label, calibration in (("analytic", None), ("calibrated", cal)):
        bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                          specs=model.specs(), calibration=calibration)
        server = BatchedServer(
            model, ctx, params, slots=2, max_len=24,
            controller=ModeController(bank,
                                      ControllerConfig(pin=bank.reference)),
        )
        outs[label] = server.run(_requests(cfg, 3))
        banks[label] = bank
        assert server.telemetry.to_dict()["cycle_model"] \
            == calibration_id(calibration)
    assert outs["analytic"] == outs["calibrated"]
    assert banks["analytic"].cycle_model == "analytic"
    assert banks["calibrated"].cycle_model == cal["id"]
    # calibrated absolute costs differ...
    assert banks["calibrated"].cycles_per_token != \
        banks["analytic"].cycles_per_token
    # ...but relative cost (what the controller compares) is preserved
    for name in banks["analytic"].names:
        assert banks["calibrated"].rel_cycles(name) == pytest.approx(
            banks["analytic"].rel_cycles(name), rel=0.08)


# -- replay -------------------------------------------------------------------

@pytest.fixture(scope="module")
def adaptive_trace(tmp_path_factory):
    """One adaptive serve run with a live controller, traced to JSONL."""
    cfg, model, params = _setup()
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs())
    server = BatchedServer(
        model, ctx, params, slots=2, max_len=24, burst=4,
        controller=ModeController(bank, ControllerConfig(cycle_budget=0.75)),
    )
    server.observer = ServingObserver(trace=True)
    out = server.run(_requests(cfg, 3, max_new=8))
    path = str(tmp_path_factory.mktemp("sim") / "adaptive.jsonl")
    server.observer.trace.write_jsonl(path)
    return path, out, server.telemetry.summary()


@pytest.fixture(scope="module")
def spec_trace(tmp_path_factory):
    """One speculative serve run, traced to JSONL."""
    cfg, model, params = _setup()
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs())
    server = BatchedServer(model, ctx, params, slots=2, max_len=32, bank=bank,
                           speculate=SpecConfig(draft_len=3))
    server.observer = ServingObserver(trace=True)
    out = server.run(_requests(cfg, 3, max_new=8))
    path = str(tmp_path_factory.mktemp("sim") / "spec.jsonl")
    server.observer.trace.write_jsonl(path)
    return path, out, server.spec_telemetry.summary()


def test_replay_reproduces_reported_savings(adaptive_trace):
    path, _, telemetry = adaptive_trace
    result = replay_trace(path)
    # the analytic array and the analytic bank are the same cost model: the
    # token-weighted savings mirror must land exactly on the reported value
    # (summary() rounds for printing; the drift vs the trace's full-precision
    # record is the exact check)
    assert result.savings["est_cycle_savings_frac"] == pytest.approx(
        telemetry["est_cycle_savings_frac"], abs=1e-4)
    assert savings_drift(result) == pytest.approx(0.0, abs=1e-9)


def test_replay_is_deterministic(adaptive_trace):
    path, _, _ = adaptive_trace
    a = report_dict(replay_trace(path))
    b = report_dict(replay_trace(path))
    assert json.loads(json.dumps(a)) == json.loads(json.dumps(b))


def test_replay_attributes_every_request_and_token(adaptive_trace):
    path, out, _ = adaptive_trace
    result = replay_trace(path)
    assert set(result.requests) == {str(r) for r in out}
    for rid, generated in out.items():
        assert result.requests[str(rid)]["tokens"] == len(generated)
        assert result.requests[str(rid)]["cycles"] > 0
    assert result.measured["tokens"] == sum(len(v) for v in out.values())
    # request cycle attribution tiles the charged decode+prefill cycles
    attributed = sum(r["cycles"] for r in result.requests.values())
    charged = result.phases.get("prefill", 0) + result.phases.get("decode", 0)
    assert attributed == pytest.approx(charged, rel=1e-9)


def test_replay_totals_are_consistent(adaptive_trace):
    path, _, _ = adaptive_trace
    result = replay_trace(path)
    t = result.totals
    assert t["total_cycles"] == pytest.approx(
        t["array_cycles"] + t["host_sync_cycles"])
    assert 0 < t["pe_occupancy"] <= 1.0
    assert t["predicted_wall_s"] is None  # analytic array has no wall anchor
    assert sum(result.phases.values()) == pytest.approx(t["total_cycles"])
    assert set(result.points) <= {"approx", "accurate"}
    assert result.counts["switches"] >= 1  # live controller actually moved
    assert result.measured["wall_s"] > 0


def test_replay_calibrated_array_keeps_savings(adaptive_trace):
    """The calibrated model rescales cycles but prices every point on the
    same depth ladder, so the savings fraction survives calibration — the
    bench_sim acceptance gate, pinned as a unit test."""
    path, _, telemetry = adaptive_trace
    cal = fit_calibration(_synthetic_measurements(mac_overhead=0.0))
    result = replay_trace(path, calibration=cal)
    assert result.savings["est_cycle_savings_frac"] == pytest.approx(
        telemetry["est_cycle_savings_frac"], abs=1e-4)
    assert savings_drift(result) == pytest.approx(0.0, abs=1e-9)
    assert result.totals["predicted_wall_s"] > 0
    assert result.totals["host_sync_cycles"] > 0


def test_replay_spec_trace_mirrors_spec_telemetry(spec_trace):
    path, out, telemetry = spec_trace
    result = replay_trace(path)
    assert result.counts["spec_rounds"] > 0
    assert result.phases["spec_draft"] > 0
    assert result.phases["spec_verify"] > 0
    spec = result.savings["speculative"]
    assert spec["est_cycle_savings_frac"] == pytest.approx(
        telemetry["est_cycle_savings_frac"], abs=1e-4)
    assert spec["rel_diff_vs_reported"] == pytest.approx(0.0, abs=1e-9)
    assert result.measured["tokens"] == sum(len(v) for v in out.values())


def test_replay_rejects_traces_without_engine_block(tmp_path):
    from repro.obs import TraceRecorder

    tr = TraceRecorder()
    tr.begin("run", track="run")
    tr.end("run", track="run")
    path = str(tmp_path / "bare.jsonl")
    tr.write_jsonl(path)
    with pytest.raises(ValueError, match="engine cost table"):
        replay_trace(path)


def test_replay_cli_writes_json_report(adaptive_trace, tmp_path, capsys):
    from repro.sim.replay import main

    path, _, _ = adaptive_trace
    out = str(tmp_path / "report.json")
    main([path, "--json", out])
    capsys.readouterr()
    with open(out) as f:
        report = json.load(f)
    assert report["totals"]["total_cycles"] > 0
    assert report["savings"]["reference"] == "accurate"


def test_render_report_is_human_readable(adaptive_trace):
    path, _, _ = adaptive_trace
    text = render(replay_trace(path))
    for needle in ("PE-array replay", "where cycles go", "savings",
                   "requests"):
        assert needle in text


# -- streaming trace reader (satellite of the replay path) --------------------

def test_iter_trace_streams_and_matches_read_trace(adaptive_trace):
    path, _, _ = adaptive_trace
    header, events = read_trace(path)
    with iter_trace(path) as tr:
        assert tr.header == header
        streamed = list(tr)
    assert streamed == events


def test_iter_trace_validates_header_eagerly(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"schema": "not-a-trace", "version": 1}) + "\n")
    with pytest.raises(ValueError, match="not-a-trace"):
        iter_trace(str(bad))


# -- analyze gates ------------------------------------------------------------

def test_ordering_inversions_detects_and_excludes():
    rows = [("a", 100.0, 1.0), ("b", 200.0, 0.5), ("c", 205.0, 2.0)]
    inv = ordering_inversions(rows, margin=0.10)
    # a vs b: predicted says b costs more, measured says b is faster
    assert {tuple(i["pair"]) for i in inv} >= {("a", "b")}
    # b vs c is a predicted near-tie: excluded even though measured inverts
    assert ("b", "c") not in {tuple(i["pair"]) for i in inv}
    # measured near-ties are excluded symmetrically
    assert ordering_inversions([("a", 100.0, 1.0), ("b", 200.0, 0.99)]) == []
    # rows without measurements never compare
    assert ordering_inversions([("a", 100.0, None), ("b", 200.0, 1.0)]) == []


def test_check_trend_normalizes_machine_speed():
    from benchmarks.check_trend import collect_tok_s, compare_records

    baseline = {"configs": {"x": {"tok_s": 100.0}, "y": {"tok_s": 200.0},
                            "z": {"sweep": [{"tok_s": 50.0}]}}}
    # uniformly 2x slower machine: no regression after normalization
    slower = {"configs": {"x": {"tok_s": 50.0}, "y": {"tok_s": 100.0},
                          "z": {"sweep": [{"tok_s": 25.0}]}}}
    regs, median = compare_records(slower, baseline, tolerance=0.10)
    assert regs == [] and median == pytest.approx(0.5)
    # one config regressing against its siblings is flagged
    one_bad = {"configs": {"x": {"tok_s": 100.0}, "y": {"tok_s": 200.0},
                           "z": {"sweep": [{"tok_s": 30.0}]}}}
    regs, _ = compare_records(one_bad, baseline, tolerance=0.10)
    assert [r["path"] for r in regs] == ["configs.z.sweep[0].tok_s"]
    # path collection sees nested and list-indexed keys
    paths = dict(collect_tok_s(baseline))
    assert set(paths) == {"configs.x.tok_s", "configs.y.tok_s",
                          "configs.z.sweep[0].tok_s"}


def test_check_trend_new_paths_helper():
    from benchmarks.check_trend import new_paths

    baseline = {"configs": {"x": {"tok_s": 100.0}}}
    fresh = {"configs": {"x": {"tok_s": 99.0},
                         "y": {"tok_s": 50.0},
                         "z": {"sweep": [{"tok_s": 10.0}]}}}
    assert set(new_paths(fresh, baseline)) == {
        "configs.y.tok_s", "configs.z.sweep[0].tok_s"}
    assert new_paths(baseline, fresh) == []


def test_check_trend_skips_new_bench_with_notice(tmp_path, capsys):
    """A fresh BENCH file with no committed baseline (the state every PR
    landing a new benchmark creates) must neither crash nor silently pass:
    check_trend exits 0 with an explicit NOTICE + skip tally."""
    from benchmarks.check_trend import main as trend_main

    bench = tmp_path / "BENCH_brand_new_subsystem.json"
    bench.write_text(json.dumps({"configs": {"a": {"tok_s": 123.0}}}))
    trend_main(["--dir", str(tmp_path)])  # must not sys.exit(1)
    out = capsys.readouterr().out
    assert "NOTICE" in out and "no committed baseline" in out
    assert "1 file(s) skipped with notice" in out
    assert "0 file(s) gated" in out


def test_check_trend_git_failure_is_notice(tmp_path, capsys, monkeypatch):
    """git itself failing to run (no git on PATH, not a repo) is
    skip-with-notice, never a crash."""
    import benchmarks.check_trend as ct

    def boom(*a, **kw):
        raise OSError("no git binary")

    monkeypatch.setattr(ct.subprocess, "run", boom)
    bench = tmp_path / "BENCH_whatever.json"
    bench.write_text(json.dumps({"configs": {"a": {"tok_s": 1.0}}}))
    ct.main(["--dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert "could not run" in out and "skipped with notice" in out


def test_replay_accepts_chunked_frontend_trace(tmp_path):
    """Traces recorded through the continuous-batching frontend use the
    prefill_chunk / admission_tick vocabulary instead of monolithic prefill
    spans; replay must price them (one multi-position pass per chunk, the
    final chunk carrying the per-request attribution and host-sync charge)
    alongside the batch vocabulary — the docs/trace-schema.md v1
    compatibility note, pinned."""
    from repro.serve.frontend import ContinuousScheduler, FrontendConfig

    cfg, model, params = _setup()
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs())
    server = BatchedServer(
        model, ctx, params, slots=2, max_len=24, burst=4,
        controller=ModeController(bank, ControllerConfig(pin=bank.reference)))
    server.observer = ServingObserver(trace=True)
    reqs = _requests(cfg, 3, max_new=8)
    sched = ContinuousScheduler(server, FrontendConfig(chunk_tokens=2))
    with sched:
        for r in reqs:
            sched.submit(r)
        out = sched.drain()

    path = str(tmp_path / "frontend.jsonl")
    server.observer.trace.write_jsonl(path)
    result = replay_trace(path)
    header, _ = read_trace(path)
    assert header["run"]["frontend"] == {"chunk_tokens": 2,
                                         "monolithic_prefill": False}
    assert result.counts["prefill_chunks"] > 3  # prompts really chunked
    assert result.counts["prefills"] == 3  # one admit (final chunk) each
    assert result.counts["admission_ticks"] > 0
    assert set(result.requests) == {str(r.rid) for r in reqs}
    for rid, generated in out.items():
        assert result.requests[str(rid)]["tokens"] == len(generated)
    assert result.phases.get("prefill", 0) > 0
    assert sum(result.phases.values()) == pytest.approx(
        result.totals["total_cycles"])
