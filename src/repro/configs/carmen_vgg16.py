"""VGG-16 layer schedule (paper Fig. 4: layer-wise execution time / power).

Captured as (name, kind, shape params) so benchmarks/fig4 can compute
per-layer MAC counts and run the precision-aware schedule over it.
Input 224x224x3, standard VGG-16 D configuration.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    in_ch: int
    out_ch: int
    spatial: int  # output H=W
    kind: str = "conv3x3"

    @property
    def macs(self) -> int:
        if self.kind == "conv3x3":
            return self.spatial * self.spatial * self.out_ch * self.in_ch * 9
        return self.in_ch * self.out_ch  # fc


VGG16_LAYERS: Tuple[ConvSpec, ...] = (
    ConvSpec("conv1_1", 3, 64, 224),
    ConvSpec("conv1_2", 64, 64, 224),
    ConvSpec("conv2_1", 64, 128, 112),
    ConvSpec("conv2_2", 128, 128, 112),
    ConvSpec("conv3_1", 128, 256, 56),
    ConvSpec("conv3_2", 256, 256, 56),
    ConvSpec("conv3_3", 256, 256, 56),
    ConvSpec("conv4_1", 256, 512, 28),
    ConvSpec("conv4_2", 512, 512, 28),
    ConvSpec("conv4_3", 512, 512, 28),
    ConvSpec("conv5_1", 512, 512, 14),
    ConvSpec("conv5_2", 512, 512, 14),
    ConvSpec("conv5_3", 512, 512, 14),
    ConvSpec("fc6", 25088, 4096, 1, "fc"),
    ConvSpec("fc7", 4096, 4096, 1, "fc"),
    ConvSpec("fc8", 4096, 1000, 1, "fc"),
)

CONFIG = VGG16_LAYERS
