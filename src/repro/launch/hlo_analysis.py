"""Post-SPMD HLO cost analyzer with loop-trip-count correction.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which under-reports
every scanned layer stack by ~num_layers x (verified empirically — see
EXPERIMENTS.md §Roofline methodology). This module parses the optimized HLO
text and walks the call graph with multipliers:

* fusion / call / custom-call -> x1
* conditional                  -> max over branches
* while                        -> trip count (the max s32 literal in the init
  tuple of the while — jax scans/fori lower to 0..N counters, so the bound is
  the largest s32 constant; validated against unrolled references in tests)

Per computation it extracts:
* dot FLOPs        2 * result_elems * contracted_dims   (MXU term)
* HBM bytes        operand + result bytes of every top-level op in scheduled
                   computations (fusion-internal ops excluded — they live in
                   registers/VMEM)
* collective bytes all-gather / all-reduce / reduce-scatter / all-to-all /
                   collective-permute result bytes (ICI term)

This is a structural model of the compiled program — the profile source the
perf loop iterates on (no real TPU in this container).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "c64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"
)

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OP_CALL = re.compile(r"([\w\-]+)\(")
_REGION_REF = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_TOKEN.findall(text)


@dataclasses.dataclass
class OpInfo:
    name: str
    op: str
    result_bytes: int
    result_elems: int
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo]
    order: List[str]
    param_shapes: Dict[str, Tuple[str, str]]


def _split_args(s: str) -> List[str]:
    """Split an HLO operand list on top-level commas only — operand shapes
    (``f32[32,64]{1,0}``) carry commas inside brackets/braces."""
    parts: List[str] = []
    depth, cur = 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _parse_operands(line: str, op: str) -> List[str]:
    m = re.search(re.escape(op) + r"\(([^)]*)\)", line)
    if not m:
        return []
    names = []
    for tok in _split_args(m.group(1)):
        # typed operand form: "f32[32,64]{1,0} %name" — the reference is the
        # trailing whitespace-separated token; bare "%name"/"name" pass through
        fields = tok.split()
        tok = fields[-1] if fields else tok
        if tok.startswith("%"):
            names.append(tok[1:])
        elif re.match(r"^[\w.\-]+$", tok):
            names.append(tok)
    return names


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        header = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$", line)
        if header and "=" not in line.split("(")[0]:
            params = {}
            for pm in re.finditer(r"([\w.\-]+):\s*([a-z][a-z0-9]*)\[([0-9,]*)\]", header.group(2)):
                params[pm.group(1)] = (pm.group(2), pm.group(3))
            cur = Computation(header.group(1), {}, [], params)
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_LINE.match(line)
        if not dm:
            continue
        name, rhs = dm.groups()
        shapes = _first_shapes(rhs.split("(")[0] + "(")  # result shape(s) before op name
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        relems = sum(
            int(__import__("numpy").prod([int(d) for d in dims.split(",") if d] or [1]))
            for dt, dims in shapes
        )
        opm = None
        # op name = token immediately before the first '(' after the shape
        after_shape = rhs
        for dt, dims in shapes:
            after_shape = after_shape.replace(f"{dt}[{dims}]", "", 1)
        oc = _OP_CALL.search(after_shape)
        opm = oc.group(1) if oc else "unknown"
        cur.ops[name] = OpInfo(
            name, opm, rbytes, relems, _parse_operands(rhs, opm), line.strip()
        )
        cur.order.append(name)
    return comps


def _operand_shape(comp: Computation, name: str) -> Optional[Tuple[str, str]]:
    if name in comp.ops:
        line = comp.ops[name].line
        m = _SHAPE_TOKEN.search(line.split("=", 1)[1])
        return (m.group(1), m.group(2)) if m else None
    if name in comp.param_shapes:
        return comp.param_shapes[name]
    return None


def _dot_flops(comp: Computation, op: OpInfo) -> float:
    """2 * result_elems * prod(contracted dims of lhs)."""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * op.result_elems  # degenerate
    lhs_shape = _operand_shape(comp, op.operands[0])
    if lhs_shape is None:
        return 2.0 * op.result_elems
    dims = [int(d) for d in lhs_shape[1].split(",") if d]
    k = 1
    for i in [int(x) for x in m.group(1).split(",") if x]:
        if i < len(dims):
            k *= dims[i]
    return 2.0 * op.result_elems * k


def _while_trip(comp: Computation, op: OpInfo, comps: Dict[str, "Computation"]) -> int:
    """Trip heuristic: jax scans lower to `while i < N` with the bound N as an
    s32 literal inside the *condition* region (the induction var starts at an
    s32 0 in the init tuple). Take the max s32 literal in the condition;
    fall back to init-tuple literals, then 1."""
    consts: List[int] = []
    cm = re.search(r"condition=%?([\w.\-]+)", op.line)
    if cm and cm.group(1) in comps:
        for o in comps[cm.group(1)].ops.values():
            m = re.search(r"s32\[\]\s*constant\((\d+)\)", o.line)
            if m:
                consts.append(int(m.group(1)))
    if not consts:
        def collect(c: Computation, names, depth=0):
            if depth > 3:
                return
            for n in names:
                if n in c.ops:
                    o = c.ops[n]
                    m = re.search(r"s32\[\]\s*constant\((\d+)\)", o.line)
                    if m:
                        consts.append(int(m.group(1)))
                    elif o.op in ("tuple", "copy", "bitcast"):
                        collect(c, o.operands, depth + 1)
        collect(comp, op.operands)
    return max(consts) if consts else 1


@dataclasses.dataclass
class Costs:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0  # fused/TPU model: elementwise chains live in VMEM
    hbm_bytes_upper: float = 0.0  # literal model: every op materializes
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_trips: List[int] = dataclasses.field(default_factory=list)
    hbm_by_cat: Dict[str, float] = dataclasses.field(default_factory=dict)

    def cat(self, key: str, b: float):
        if b:
            self.hbm_by_cat[key] = self.hbm_by_cat.get(key, 0.0) + b

    def add(self, other: "Costs", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_bytes_upper += other.hbm_bytes_upper * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v * mult
        for k, v in other.hbm_by_cat.items():
            self.hbm_by_cat[k] = self.hbm_by_cat.get(k, 0.0) + v * mult
        self.while_trips.extend(other.while_trips)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "copy",
    "after-all", "partition-id", "replica-id", "unknown", "iota",
}

# ops whose results are HBM materialization points even under perfect fusion
_MATERIALIZE_OPS = {"dot", "reduce", "concatenate", "sort", "reduce-window", "convolution"}

# results at or under this size are assumed VMEM/register-resident when they
# are produced AND consumed inside the same computation (loop tiles, online-
# softmax accumulators); larger results spill to HBM. 8 MiB of the 16 MiB v5e
# VMEM: the XLA flash twin fuses all local (B x H) score tiles into one op
# (e.g. (2,3,512,512) f32 = 6.3 MB), while the realized Pallas kernel grids
# over (b, h) and keeps per-program tiles at 1 MiB — the twin's fused buffer
# is the upper bound of what the kernel pipelines through VMEM.
VMEM_TILE_BYTES = 8 * 1024 * 1024


def _locally_consumed(comp: Computation, op_name: str) -> bool:
    for o in comp.ops.values():
        if op_name in o.operands:
            return True
    return False

_TRANSPARENT_OPS = {"bitcast", "copy", "convert", "reshape"}


def _sliced_operand_bytes(sub_comp: Optional[Computation], index: int, full_bytes: int) -> int:
    """HBM bytes actually read from a fusion operand.

    When the fused computation consumes parameter ``index`` (possibly through
    bitcast/copy/convert chains) only via a dynamic-slice (scan reading layer i
    of a stacked tensor) or as the aliased buffer of a dynamic-update-slice
    (in-place stacking/cache write), only the slice region moves through HBM —
    charging the whole stacked operand would overcount by num_layers x trips.
    """
    if sub_comp is None:
        return full_bytes
    names = list(sub_comp.param_shapes)
    if index >= len(names):
        return full_bytes
    uses: Dict[str, list] = {}
    for o in sub_comp.ops.values():
        for opr in o.operands:
            uses.setdefault(opr, []).append(o)
    frontier = [names[index]]
    seen = set(frontier)
    charge = 0
    while frontier:
        n = frontier.pop()
        for o in uses.get(n, ()):
            if o.op == "dynamic-slice" and o.operands and o.operands[0] == n:
                charge = max(charge, o.result_bytes)
            elif o.op == "dynamic-update-slice" and o.operands and o.operands[0] == n:
                charge = max(charge, 0)  # aliased buffer; update charged by caller
            elif o.op in _TRANSPARENT_OPS:
                if o.name not in seen:
                    seen.add(o.name)
                    frontier.append(o.name)
            else:
                return full_bytes  # real (non-slice) use -> whole operand read
    return charge


def _fusion_result_bytes(sub_comp: Optional[Computation], result_bytes: int) -> int:
    """HBM bytes written by a fusion: in-place DUS fusions write only the
    update region (the surrounding whole-buffer converts are aliasing
    artifacts on the CPU backend)."""
    if sub_comp is None:
        return result_bytes
    dus = [o for o in sub_comp.ops.values() if o.op == "dynamic-update-slice"]
    if not dus:
        return result_bytes
    upd_bytes = 0
    for o in dus:
        sh = _operand_shape(sub_comp, o.operands[1]) if len(o.operands) > 1 else None
        upd_bytes += _shape_bytes(*sh) if sh else 0
    return min(result_bytes, 2 * upd_bytes)


def _operand_bytes(comp: Computation, op: OpInfo) -> int:
    return sum(
        _shape_bytes(*sh) for o in op.operands if (sh := _operand_shape(comp, o)) is not None
    )


def analyze(hlo: str) -> Costs:
    """Walk the call graph with loop multipliers; see module docstring.

    Byte accounting (both models accumulated in one pass):
      fused/TPU model (``hbm_bytes``): matmuls/reductions/collectives/slices
        move bytes; elementwise+convert+broadcast chains are fused into their
        producers/consumers (each materialized tensor charged write+read via
        2x result at its materialization point).
      literal model (``hbm_bytes_upper``): every non-skipped op charges
        operands + result — what a fusion-free backend would move.
    """
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        entry = next((n for n in comps if "main" in n), next(iter(comps)))

    memo: Dict[str, Costs] = {}

    def walk(name: str, depth=0) -> Costs:
        if name in memo:
            return memo[name]
        c = Costs()
        comp = comps.get(name)
        if comp is None or depth > 24:
            return c
        memo[name] = c  # placeholder against cycles
        for op_name in comp.order:
            op = comp.ops[op_name]
            kind = op.op
            if kind in _SKIP_BYTES_OPS:
                continue
            opnd = _operand_bytes(comp, op)
            if kind == "dot":
                c.dot_flops += _dot_flops(comp, op)
                # small tiles produced+consumed locally stay in VMEM; reads of
                # locally-produced small operands are free for the same reason
                small_local = (
                    op.result_bytes <= VMEM_TILE_BYTES and _locally_consumed(comp, op_name)
                )
                reads = 0
                for o in op.operands:
                    sh = _operand_shape(comp, o)
                    if sh is None:
                        continue
                    b = _shape_bytes(*sh)
                    if b <= VMEM_TILE_BYTES and o in comp.ops and comp.ops[o].op not in (
                        "parameter",
                    ):
                        continue  # VMEM-resident local tile
                    reads += b
                b_ = reads + (0 if small_local else 2 * op.result_bytes)
                c.hbm_bytes += b_
                c.cat("dot", b_)
                c.hbm_bytes_upper += opnd + op.result_bytes
            elif kind in COLLECTIVE_OPS or any(kind == k + "-start" for k in COLLECTIVE_OPS):
                base = kind.replace("-start", "")
                c.collective_bytes += op.result_bytes
                c.collective_by_kind[base] = (
                    c.collective_by_kind.get(base, 0.0) + op.result_bytes
                )
                c.hbm_bytes += 2 * op.result_bytes
                c.cat("collective", 2 * op.result_bytes)
                c.hbm_bytes_upper += 2 * op.result_bytes
            elif kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                trip = _while_trip(comp, op, comps)
                c.while_trips.append(trip)
                if bm:
                    c.add(walk(bm.group(1), depth + 1), trip)
                if cm:
                    c.add(walk(cm.group(1), depth + 1), trip)
            elif kind == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation|branch_computations=\{[^}]*)"
                    r"=?%?([\w.\-]+)", op.line)
                subs = [walk(b, depth + 1) for b in branches if b in comps]
                if subs:
                    c.add(max(subs, key=lambda s: s.dot_flops + s.hbm_bytes))
            elif kind == "dynamic-slice":
                c.hbm_bytes += 2 * op.result_bytes
                c.cat("slice", 2 * op.result_bytes)
                c.hbm_bytes_upper += 2 * op.result_bytes
            elif kind == "dynamic-update-slice":
                upd = _operand_shape(comp, op.operands[1]) if len(op.operands) > 1 else None
                b = 2 * (_shape_bytes(*upd) if upd else 0)
                c.hbm_bytes += b
                c.cat("dus", b)
                c.hbm_bytes_upper += b
            elif kind == "gather":
                idx = _operand_shape(comp, op.operands[1]) if len(op.operands) > 1 else None
                b = 2 * op.result_bytes + (_shape_bytes(*idx) if idx else 0)
                c.hbm_bytes += b
                c.cat("gather", b)
                c.hbm_bytes_upper += b
            elif kind in ("fusion", "call", "map", "reduce", "sort", "custom-call",
                          "scatter", "select-and-scatter", "reduce-window"):
                ref = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line)
                sub_comp = comps.get(ref.group(1)) if ref else None
                sub = walk(ref.group(1), depth + 1) if ref else Costs()
                # inner dots/collectives always count
                c.dot_flops += sub.dot_flops
                c.collective_bytes += sub.collective_bytes
                for k, v in sub.collective_by_kind.items():
                    c.collective_by_kind[k] = c.collective_by_kind.get(k, 0.0) + v
                has_dus = sub_comp is not None and any(
                    o.op == "dynamic-update-slice" for o in sub_comp.ops.values()
                )
                has_ds = sub_comp is not None and any(
                    o.op == "dynamic-slice" for o in sub_comp.ops.values()
                )
                has_mat = (sub.dot_flops > 0) or (
                    sub_comp is not None
                    and any(o.op in _MATERIALIZE_OPS for o in sub_comp.ops.values())
                ) or kind in ("reduce", "sort", "scatter", "custom-call",
                              "select-and-scatter", "reduce-window")
                # literal model: full boundary traffic (slice-aware)
                lit = _fusion_result_bytes(sub_comp, op.result_bytes)
                for i, o in enumerate(op.operands):
                    sh = _operand_shape(comp, o)
                    if sh:
                        lit += _sliced_operand_bytes(sub_comp, i, _shape_bytes(*sh))
                c.hbm_bytes_upper += lit
                # fused model: charge only materialization points; VMEM-tile
                # rule (as for dots): small results produced+consumed locally
                # over small local operands form a VMEM-resident pipeline
                # (flash-attention inner loops) and move no HBM bytes.
                small_local = (
                    op.result_bytes <= VMEM_TILE_BYTES
                    and _locally_consumed(comp, op_name)
                )
                reads = 0
                for i, o in enumerate(op.operands):
                    sh = _operand_shape(comp, o)
                    if sh is None:
                        continue
                    b = _shape_bytes(*sh)
                    if b <= VMEM_TILE_BYTES and o in comp.ops and comp.ops[o].op not in (
                        "parameter",
                    ):
                        continue  # locally-produced small tile: VMEM-resident
                    reads += _sliced_operand_bytes(sub_comp, i, b)
                if has_dus:
                    b_ = _fusion_result_bytes(sub_comp, op.result_bytes)
                    c.hbm_bytes += b_
                    c.cat("fusion-dus", b_)
                elif has_ds and not has_mat:
                    b_ = min(lit, reads + (0 if small_local else 2 * op.result_bytes))
                    c.hbm_bytes += b_
                    c.cat("fusion-slice", b_)
                elif has_mat:
                    b_ = reads + (0 if small_local else 2 * op.result_bytes)
                    c.hbm_bytes += b_
                    c.cat("fusion-mat", b_)
                # pure elementwise fusion -> fused away (0 bytes in fused model)
            else:
                # raw top-level op: elementwise fuses away; others materialize
                c.hbm_bytes_upper += opnd + op.result_bytes
                if kind in _MATERIALIZE_OPS:
                    c.hbm_bytes += opnd + 2 * op.result_bytes
                    c.cat("raw-mat", opnd + 2 * op.result_bytes)
        return c

    return walk(entry)
