"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437 §2.1).

Queries go through a low-rank down/up projection (q_lora_rank), keys/values
through a compressed latent c_kv (kv_lora_rank) plus a decoupled RoPE key of
qk_rope_head_dim shared across heads. The decode cache stores only
(c_kv, k_rope) — (512 + 64) per token instead of 2*128*128 — which is the
technique's entire point and what our cache specs reflect.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext
from repro.core.normalization import rmsnorm

from .blocks import Q_CHUNK, cache_row_write, rope
from .params import ParamSpec


def mla_specs(cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_a_norm": ParamSpec((m.q_lora_rank,), ("q_lora",), "ones"),
        "wq_b": ParamSpec((m.q_lora_rank, h, qk_head), ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora")),
        "kv_a_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), "ones"),
        "wk_b": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim), ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamSpec((m.kv_lora_rank, h, m.v_head_dim), ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def _q_proj(p, x, cfg, ctx, name):
    m = cfg.mla
    h = cfg.num_heads
    q_lat = ctx.linear(x, p["wq_a"], name=f"{name}.q_a")
    q_lat = rmsnorm(q_lat, p["q_a_norm"])
    wq_b = p["wq_b"].reshape(m.q_lora_rank, -1)
    q = ctx.linear(q_lat, wq_b, name=f"{name}.q_b")
    return q.reshape(x.shape[:-1] + (h, m.qk_nope_head_dim + m.qk_rope_head_dim))


def _kv_latent(p, x, cfg, ctx, name):
    m = cfg.mla
    kv_a = ctx.linear(x, p["wkv_a"], name=f"{name}.kv_a")
    c_kv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    c_kv = rmsnorm(c_kv, p["kv_a_norm"])
    return c_kv, k_rope


def mla_attention(p, x, cfg: ModelConfig, ctx: EngineContext, *, positions, name, cache=None):
    """Returns (out, new_cache); cache = {c_kv, k_rope, index}."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rdim, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = _q_proj(p, x, cfg, ctx, name)  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_kv, k_rope = _kv_latent(p, x, cfg, ctx, name)  # (B,S,R), (B,S,rdim)
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]

    if cache is not None:
        idx = cache["index"]  # (B,)
        c_kv = cache_row_write(cache["c_kv"], c_kv, idx)
        k_rope = cache_row_write(cache["k_rope"], k_rope, idx)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "index": idx + s}
        t = c_kv.shape[1]
        k_positions = jnp.arange(t)
        # per-query causal validity (s > 1 = batched prefill; see blocks.py)
        valid = k_positions[None, None, :] <= positions[:, :, None]  # (B, S, T)
    else:
        new_cache = None
        t = s
        k_positions = positions
        valid = None

    # absorbed-matmul form: score = q_nope^T (W_kb c_kv) + q_rope^T k_rope.
    # q_nope is mapped into latent space once (q_lat = q_nope @ W_kb^T), so the
    # per-token cache stays compressed — scores contract over kv_lora_rank.
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), p["wk_b"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + rdim)
    c_kv_f = c_kv.astype(jnp.float32)
    k_rope_f = k_rope.astype(jnp.float32)

    def _block(q_lat_i, q_rope_i, qpos_i):
        """One query chunk: (B, Qc, H, R/rdim) -> latent-space output (B,Qc,H,R)."""
        scores = jnp.einsum("bqhr,btr->bhqt", q_lat_i, c_kv_f)
        scores = scores + jnp.einsum("bqhr,btr->bhqt", q_rope_i.astype(jnp.float32), k_rope_f)
        scores = scores * scale
        if valid is not None:
            scores = jnp.where(valid[:, None], scores, -1e30)
        else:
            mask = qpos_i[:, None] >= k_positions[None, :]
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqt,btr->bqhr", probs, c_kv_f)

    if cache is None and ctx.attn_impl == "flash":
        # flash for MLA via the concat trick: [q_lat, q_rope] . [c_kv, k_rope]
        # equals the two-term score exactly, and the "value" is c_kv — MLA is
        # MQA-shaped in latent space, so the shared online-softmax path
        # (KV-chunked, tile-resident scores) applies unchanged.
        from .blocks import _sdpa_flash_xla

        scale_full = 1.0  # _sdpa_flash_xla scales by 1/sqrt(hd of q) below
        q_cat = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], axis=-1)
        # undo the helper's 1/sqrt(dim(q_cat)) and apply MLA's own scale
        q_cat = q_cat * (math.sqrt(q_cat.shape[-1]) * scale)
        k_cat = jnp.concatenate([c_kv_f, k_rope_f], axis=-1)[:, :, None, :]  # (B,T,1,R+r)
        kr = jnp.repeat(k_cat, h, axis=2)
        vr = jnp.repeat(c_kv_f[:, :, None, :], h, axis=2)
        o_lat = _sdpa_flash_xla(q_cat, kr, vr, positions, k_positions, causal=True)
    elif cache is not None and ctx.attn_impl == "decode_kernel":
        from repro.sharding.partition import current_mesh_axes

        if current_mesh_axes():
            o_lat = _block(q_lat, q_rope, positions)  # mesh: XLA chain
        else:
            # Pallas cache-decode kernel (absorbed/MQA-shaped in latent
            # space): both score terms, mask, softmax and the latent
            # contraction happen in one VMEM-resident pass per (batch, head)
            from repro.kernels.decode_attention import mla_decode_attention

            o_lat = mla_decode_attention(
                q_lat, q_rope.astype(jnp.float32), c_kv, k_rope, positions,
                scale=scale,
            )
    elif cache is None and s > Q_CHUNK and s % Q_CHUNK == 0:
        nc = s // Q_CHUNK
        ql = jnp.moveaxis(q_lat.reshape(b, nc, Q_CHUNK, h, -1), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, Q_CHUNK, h, rdim), 1, 0)
        qp = positions.reshape(nc, Q_CHUNK)
        _, o_lat = jax.lax.scan(lambda _, args: (None, _block(*args)), None, (ql, qr, qp))
        o_lat = jnp.moveaxis(o_lat, 0, 1).reshape(b, s, h, -1)
    else:
        o_lat = _block(q_lat, q_rope, positions)

    # up-project latent output with W_vb (absorbed form)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(jnp.float32)).astype(x.dtype)

    wo = p["wo"].reshape(h * vdim, cfg.d_model)
    return ctx.linear(out.reshape(b, s, h * vdim), wo, name=f"{name}.o"), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "index": jnp.zeros((batch,), jnp.int32),
    }


def mla_cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype),
        "index": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
