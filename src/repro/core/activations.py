"""CARMEN's time-multiplexed multi-AF block (paper §II-B).

Seven activation functions — ReLU, GELU, Softmax, Tanh, Sigmoid, Swish, SELU —
computed from **one shared CORDIC datapath**:

* ``exp``  — hyperbolic rotation (cosh + sinh) with ln2 range reduction
* ``div``  — linear vectoring
* ``mul``  — linear rotation
* ReLU and its variants — bypass logic (a compare + select), as in the paper

The silicon block time-multiplexes these sub-units across AF requests; the
software analogue is that every AF below is a composition of the same three
primitives, and the Pallas kernel (`kernels/cordic_af`) lowers exactly this
graph into a single VMEM-resident loop selected by a mode scalar.

Each AF has an exact float reference (``*_ref``) used by tests and by the
"exact" execution mode of the engine.
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .fxp import FxPFormat, dequantize, quantize, saturate

__all__ = [
    "AF_NAMES",
    "AF_INDEX",
    "multi_af",
    "multi_af_float",
    "af_ref",
    "cordic_softmax",
    "softmax_ref",
]

AF_NAMES = ("relu", "gelu", "tanh", "sigmoid", "swish", "selu", "softmax")
AF_INDEX = {name: i for i, name in enumerate(AF_NAMES)}

_SELU_ALPHA = 1.6732632423543772
_SELU_LAMBDA = 1.0507009873554805
_GELU_C = math.sqrt(2.0 / math.pi)


# ---------------------------------------------------------------------------
# Shared fixed-point sub-blocks (raw int32 in/out)
# ---------------------------------------------------------------------------


def _exp_neg(x_raw, depth: int, fmt: FxPFormat):
    """exp(x) for x <= 0 (the only exp the AF block needs): result in (0, 1]."""
    return cordic.cordic_exp(jnp.minimum(x_raw, 0), depth, fmt)


def _tanh_raw(x_raw, depth: int, fmt: FxPFormat):
    """tanh via shared exp + div: t = exp(-2|x|); tanh = (1-t)/(1+t) * sign."""
    ax = jnp.abs(jnp.asarray(x_raw, jnp.int32))
    t = _exp_neg(-(ax << 1), depth, fmt)  # exp(-2|x|) in (0, 1]
    num = fmt.one - t
    den = fmt.one + t
    mag = cordic.cordic_div(num, den, depth, fmt)  # ratio <= 1
    return jnp.where(x_raw >= 0, mag, -mag)


def _sigmoid_raw(x_raw, depth: int, fmt: FxPFormat):
    """sigmoid via shared exp + div, branchless over sign.

    x>=0: 1/(1+e^-x); x<0: e^x/(1+e^x). Both ratios <= 1.
    """
    t = _exp_neg(-jnp.abs(jnp.asarray(x_raw, jnp.int32)), depth, fmt)  # e^-|x|
    den = fmt.one + t
    num = jnp.where(x_raw >= 0, jnp.int32(fmt.one), t)
    return cordic.cordic_div(num, den, depth, fmt)


def _q1_sat(raw, fmt: FxPFormat):
    """Saturate a raw value into Q1.frac range (|value| < 2).

    The linear-CORDIC multiplier port converges only for |z| < 2; in silicon
    the port is physically Q1.f, so wider activations saturate on entry. The
    AFs below route values through this port only where the saturation is
    benign (tanh/sigmoid arguments past +-2 are already in their flat region).
    """
    lim = (1 << (fmt.frac + 1)) - 1
    return jnp.clip(jnp.asarray(raw, jnp.int32), -lim, lim)


def _mul_raw(a_raw, b_raw, depth: int, fmt: FxPFormat):
    """Product of two raw values; b is routed through the Q1 multiplier port."""
    return cordic.cordic_mul(a_raw, _q1_sat(b_raw, fmt), depth, fmt)


# ---------------------------------------------------------------------------
# Fixed-point AFs (raw int32 in ``fmt`` -> raw int32 in ``fmt``)
# ---------------------------------------------------------------------------


def _relu_fx(x, depth, fmt):
    return jnp.maximum(x, 0)


def _tanh_fx(x, depth, fmt):
    return saturate(_tanh_raw(x, depth, fmt), fmt)


def _sigmoid_fx(x, depth, fmt):
    return saturate(_sigmoid_raw(x, depth, fmt), fmt)


def _swish_fx(x, depth, fmt):
    s = _sigmoid_raw(x, depth, fmt)  # in [0, 1] -> valid Q1 multiplier
    return saturate(_mul_raw(x, s, depth, fmt), fmt)


def _gelu_fx(x, depth, fmt):
    # tanh-form GELU: 0.5 x (1 + tanh(c (x + 0.044715 x^3))).
    # The multiplier operand of each CORDIC mul must sit in Q1 range, so the
    # cubic is factored as x * (c1 * x^2) with c1 absorbing the small constant.
    c1 = quantize(np.float32(0.044715), fmt)
    x2 = _mul_raw(x, x, depth, fmt)                      # x^2
    x2c = _mul_raw(x2, c1, depth, fmt)                   # 0.044715 x^2 (small)
    x3c = _mul_raw(x, x2c, depth, fmt)                   # 0.044715 x^3
    inner = x + x3c
    cg = quantize(np.float32(_GELU_C), fmt)
    arg = _mul_raw(inner, cg, depth, fmt)
    t = _tanh_raw(arg, depth, fmt)
    half = quantize(np.float32(0.5), fmt)
    out = _mul_raw(x, fmt.one + t, depth, fmt)           # x * (1 + tanh)
    return saturate(_mul_raw(out, half, depth, fmt), fmt)


def _selu_fx(x, depth, fmt):
    lam = quantize(np.float32(_SELU_LAMBDA), fmt)
    e = _exp_neg(x, depth, fmt)  # exp(x) for x<=0 branch
    neg = _mul_raw(e - fmt.one, quantize(np.float32(_SELU_ALPHA), fmt), depth, fmt)
    pre = jnp.where(x > 0, x, neg)
    return saturate(_mul_raw(pre, lam, depth, fmt), fmt)


_FX_AFS = {
    "relu": _relu_fx,
    "gelu": _gelu_fx,
    "tanh": _tanh_fx,
    "sigmoid": _sigmoid_fx,
    "swish": _swish_fx,
    "selu": _selu_fx,
}


def multi_af(x_raw, mode: str, depth: int, fmt: FxPFormat):
    """Fixed-point multi-AF block: raw int32 in ``fmt`` -> raw int32 in ``fmt``.

    ``softmax`` needs a reduction axis — use :func:`cordic_softmax` directly.
    """
    if mode == "softmax":
        return cordic_softmax(x_raw, depth, fmt)
    return _FX_AFS[mode](jnp.asarray(x_raw, jnp.int32), depth, fmt)


def cordic_softmax(x_raw, depth: int, fmt: FxPFormat, axis: int = -1):
    """Softmax = shared exp + accumulate + shared div (paper: "exponentiation
    and normalization stages").

    Renormalization: when the lane count could overflow the int32 accumulator
    (sum of N values < 1.0 each needs log2(N) + frac < 31), exponentials are
    pre-shifted right — a standard hardware wide-accumulator workaround; the
    quotient is shift-invariant.
    """
    x = jnp.asarray(x_raw, jnp.int32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = _exp_neg(x - m, depth, fmt)  # all args <= 0, values in (0, 1]
    n = x.shape[axis]
    headroom = int(math.ceil(math.log2(max(n, 2)))) + fmt.frac + 1
    shift = max(0, headroom - 31)
    e_s = e >> shift
    s = jnp.sum(e_s, axis=axis, keepdims=True)
    # ratio e_s / s <= 1; broadcast div
    return cordic.cordic_div(e_s, jnp.maximum(s, 1), depth, fmt)


def internal_fmt(fmt: FxPFormat) -> FxPFormat:
    """AF-datapath internal format: I/O width + guard bits.

    The silicon AF block carries guard bits past the I/O width (the CORDIC
    atanh tables and gain constant need finer resolution than the I/O grid),
    exactly like the paper's 16-bit-internal SSTp predecessor [4]:
    FxP8 (Q1.6) computes internally at Q3.12, FxP16 (Q3.12) at Q7.16.
    The iteration-depth knob scales onto the internal datapath 1:1 per guard
    bit, so 'full depth' reaches the internal grid and 'approximate depth'
    keeps the paper's cycle saving.
    """
    if fmt.frac >= 16:
        return fmt
    if fmt.frac <= 8:
        return FxPFormat(16, 12)
    return FxPFormat(24, 16)


def multi_af_float(x, mode: str, depth: int, fmt: FxPFormat):
    """Float-in/float-out wrapper: quantize I/O to ``fmt``, compute with the
    guard-bit internal datapath, requantize the result back to ``fmt``."""
    from .fxp import requantize

    xq = quantize(x, fmt)  # I/O quantization at the block boundary
    ifmt = internal_fmt(fmt)
    xi = requantize(xq, fmt, ifmt)
    d = max(depth + (ifmt.frac - fmt.frac), 2)
    if mode == "softmax":
        out = cordic_softmax(xi, d, ifmt)
    else:
        out = multi_af(xi, mode, d, ifmt)
    return dequantize(requantize(out, ifmt, fmt), fmt)


# ---------------------------------------------------------------------------
# Exact float references (the FP32 baseline of the paper's Fig. 3)
# ---------------------------------------------------------------------------


def softmax_ref(x, axis: int = -1):
    return jax.nn.softmax(x, axis=axis)


_REFS: Dict[str, Callable] = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": lambda x: 0.5 * x * (1.0 + jnp.tanh(_GELU_C * (x + 0.044715 * x**3))),
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "swish": lambda x: x * jax.nn.sigmoid(x),
    "selu": lambda x: _SELU_LAMBDA * jnp.where(x > 0, x, _SELU_ALPHA * (jnp.exp(x) - 1.0)),
    "softmax": softmax_ref,
}


def af_ref(x, mode: str):
    return _REFS[mode](jnp.asarray(x, jnp.float32))
