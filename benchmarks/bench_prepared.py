"""Per-call vs prepared weight-bank serving benchmark (JSON output).

Measures the jitted decode step (the serving hot loop) with the seed's
per-call weight path (weights re-rounded / re-scaled every step) against the
prepared path (``prepare_params``: quantize once, serve fast), per engine
mode. Complements the ``benchmarks/run.py`` CSV tables with a JSON record:

    PYTHONPATH=src python -m benchmarks.bench_prepared --arch olmo-1b \
        --modes carmen,int8 --steps 20

writes ``artifacts/bench/bench_prepared.json`` (and prints it).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.core import EngineContext, FXP8, PrecisionPolicy, prepare_params
from repro.models import get_model
from repro.serve.engine import make_decode_sample_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def bench_mode(model, params, mode: str, *, slots: int, max_len: int, steps: int):
    policy = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode=mode, policy=policy, compute_dtype=jnp.float32)
    prepared = prepare_params(params, policy, mode, specs=model.specs())
    rec = {}
    for label, p in (("per_call", params), ("prepared", prepared)):
        decode = jax.jit(make_decode_sample_step(model, ctx))
        cache = model.make_cache(slots, max_len, dtype=jnp.float32)
        toks = jnp.zeros((slots, 1), jnp.int32)
        tok, cache = decode(p, toks, cache)  # compile + first step
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(steps):
            tok, cache = decode(p, tok, cache)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        rec[label] = {
            "step_ms": round(1e3 * dt / steps, 3),
            "tok_s": round(steps * slots / dt, 1),
        }
    rec["speedup"] = round(rec["per_call"]["step_ms"] / rec["prepared"]["step_ms"], 2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--full-size", action="store_true",
                    help="benchmark the unreduced config")
    ap.add_argument("--modes", default="carmen,int8")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default=os.path.join(ARTIFACTS, "bench_prepared.json"))
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduce_cfg(cfg)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    record = {
        "arch": args.arch,
        "reduced": not args.full_size,
        "slots": args.slots,
        "steps": args.steps,
        "backend": jax.default_backend(),
        "modes": {},
    }
    for mode in args.modes.split(","):
        record["modes"][mode] = bench_mode(
            model, params, mode, slots=args.slots, max_len=args.max_len,
            steps=args.steps,
        )

    payload = json.dumps(record, indent=1)
    print(payload)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(payload + "\n")
    return record


if __name__ == "__main__":
    main()
