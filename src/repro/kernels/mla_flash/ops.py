"""jit'd wrapper: MLA model quantities -> the shared-latent flash kernel."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import kernel as _k


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    # cached: see kernels/cordic_mac/ops.py — one probe per process
    return jax.default_backend() == "cpu"


def mla_flash_attention(q_lat, q_rope, c_kv, k_rope, *, scale: float,
                        causal: bool = True, interpret: bool | None = None,
                        **block_kw):
    """MLA attention with VMEM-broadcast shared latent.

    q_lat: (B, S, H, R); q_rope: (B, S, H, r); c_kv: (B, T, R);
    k_rope: (B, T, r). ``scale`` is the model's score scale
    (1/sqrt(nope+rope)). Returns o_lat (B, S, H, R).
    """
    interpret = _interpret_default() if interpret is None else interpret
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)
    # kernel scales by 1/sqrt(Dk); fold the model's scale in via q
    dk = q_cat.shape[-1]
    q_cat = q_cat * (math.sqrt(dk) * scale)
    k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)
    return _k.mla_flash(q_cat, k_cat, c_kv, causal=causal, interpret=interpret, **block_kw)
