"""Decode-attention Pallas kernels: exchangeable with the XLA cache path.

The kernels mirror the reference chains op-for-op (same mask order, same
dtypes), but XLA does not guarantee f32 reduction order across differently
shaped programs (the per-(b,h) kernel blocks vs the whole-batch einsum), so
float outputs are asserted to reduction-order tolerance — a couple of ulps —
while greedy token streams are asserted exactly. Anything beyond ulps means
the kernel stopped computing the serving path's arithmetic.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext
from repro.kernels.decode_attention import (
    gqa_decode_attention,
    mla_decode_attention,
)
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request

EXACT = EngineContext(mode="exact", compute_dtype=jnp.float32)


def _assert_ulp_close(out, ref):
    """Equality up to f32 reduction-order drift (a couple of ulps)."""
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-6, atol=2e-6,
    )


def _gqa_ref(q, ck, cv, pos, scale):
    """models/blocks.attention cache branch, verbatim."""
    g = q.shape[2] // ck.shape[2]
    t = ck.shape[1]
    valid = jnp.arange(t)[None, None, :] <= pos[:, :, None]
    ckr = jnp.repeat(ck, g, axis=2) if g > 1 else ck
    cvr = jnp.repeat(cv, g, axis=2) if g > 1 else cv
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        ckr.astype(jnp.float32))
    scores = jnp.where(valid[:, None], scores * scale, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", probs.astype(cvr.dtype), cvr)


@pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("s", [1, 4], ids=["s1", "s4"])
def test_gqa_decode_kernel_matches_chain(cache_dtype, s):
    """Single-token decode and burst/verify blocks, GQA groups resolved by
    index maps: matches the repeated-KV einsum chain to reduction-order ulps."""
    rng = np.random.default_rng(0)
    b, h, kv, hd, t = 2, 4, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    ck = jnp.asarray(rng.normal(size=(b, t, kv, hd)).astype(np.float32)).astype(cache_dtype)
    cv = jnp.asarray(rng.normal(size=(b, t, kv, hd)).astype(np.float32)).astype(cache_dtype)
    pos = jnp.asarray(rng.integers(s - 1, t - s, size=(b, s)).astype(np.int32))
    scale = 1.0 / math.sqrt(hd)
    out = gqa_decode_attention(q, ck, cv, pos, scale=scale)
    ref = _gqa_ref(q, ck, cv, pos, scale)
    assert out.dtype == ref.dtype == cache_dtype
    _assert_ulp_close(out, ref)


def test_mla_decode_kernel_matches_chain():
    """Absorbed-MLA (two-term scores, latent values): matches models/mla._block
    on the cache path to reduction-order ulps."""
    rng = np.random.default_rng(1)
    b, s, h, r, rd, t = 2, 3, 4, 8, 4, 16
    ql = jnp.asarray(rng.normal(size=(b, s, h, r)).astype(np.float32))
    qr = jnp.asarray(rng.normal(size=(b, s, h, rd)).astype(np.float32))
    ckv = jnp.asarray(rng.normal(size=(b, t, r)).astype(np.float32))
    kr = jnp.asarray(rng.normal(size=(b, t, rd)).astype(np.float32))
    pos = jnp.asarray(rng.integers(2, t - 1, size=(b, s)).astype(np.int32))
    scale = 1.0 / math.sqrt(r + rd)

    valid = jnp.arange(t)[None, None, :] <= pos[:, :, None]
    scores = jnp.einsum("bqhr,btr->bhqt", ql, ckv)
    scores = scores + jnp.einsum("bqhr,btr->bhqt", qr, kr)
    scores = jnp.where(valid[:, None], scores * scale, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhqt,btr->bqhr", probs, ckv)

    out = mla_decode_attention(ql, qr, ckv, kr, pos, scale=scale)
    _assert_ulp_close(out, ref)


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-v3-671b"])
def test_serving_decode_kernel_stream_identical(arch):
    """Greedy serving with attn_impl='decode_kernel' reproduces the XLA
    cache path token for token (GQA and MLA decode dispatch); logit margins
    agree to reduction-order ulps."""
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(ctx):
        rng = np.random.default_rng(0)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab_size, 3 + i).astype(np.int32), 5)
            for i in range(2)
        ]
        out = BatchedServer(model, ctx, params, slots=2, max_len=16,
                            burst=2).run(reqs)
        return out, [r.margins for r in reqs]

    ref, ref_margins = run(EXACT)
    got, got_margins = run(dataclasses.replace(EXACT, attn_impl="decode_kernel"))
    assert got == ref
    for a, b in zip(got_margins, ref_margins):
        _assert_ulp_close(a, b)
