"""Scaled-integer quantization substrate (the production int8 regime).

Complements ``core/fxp.py`` (binary-point FxP — the silicon datapath regime):
here scales are per-tensor/per-channel floats, weights are stored int8 once
(serving), and the CORDIC depth knob maps to effective weight bits.

The weight-bank mechanics now live in the int8 execution backend
(``repro.core.backends.int8``) — ``quantize_params_int8`` and
``QuantizedLinear`` are thin shims over it, kept for calibration tooling and
API stability. New serving code should use ``repro.core.prepare_params``,
which formats whole model trees per the precision policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.backends.int8 import int8_dot, quantize_weight


def fake_quant(x, bits: int = 8, axis: Optional[int] = None):
    """Symmetric fake-quantization with straight-through gradient."""
    qmax = 2.0 ** (bits - 1) - 1
    if axis is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    else:
        scale = jnp.maximum(
            jnp.max(jnp.abs(x), axis=axis, keepdims=True), 1e-8
        ) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax) * scale
    return x + jax.lax.stop_gradient(q - x)  # STE


def quantize_params_int8(params, *, per_channel: bool = True):
    """One-time weight-bank quantization for serving: int8 leaves + scales.

    2D+ float leaves are quantized per output channel (last dim); small/1D
    leaves (norms, biases) stay float (criticality-pinned, like routers).
    Delegates to the int8 backend's ``quantize_weight``.
    """

    def one(p):
        if not hasattr(p, "dtype") or p.dtype.kind != "f" or p.ndim < 2:
            return {"qvalue": p, "qscale": None}
        q, scale = quantize_weight(p, per_channel=per_channel)
        return {"qvalue": q, "qscale": scale}

    return jax.tree.map(one, params)


def dequantize_params(qparams):
    def one(leaf):
        if leaf["qscale"] is None:
            return leaf["qvalue"]
        return leaf["qvalue"].astype(jnp.float32) * leaf["qscale"]

    return jax.tree.map(
        one, qparams, is_leaf=lambda x: isinstance(x, dict) and "qvalue" in x
    )


def calibrate_activation_scales(apply_fn, params, batches, taps) -> Dict[str, float]:
    """Max-abs activation calibration over a few batches (static scales)."""
    scales = {t: 0.0 for t in taps}
    for batch in batches:
        acts = apply_fn(params, batch)  # dict tap -> activation
        for t in taps:
            scales[t] = max(scales[t], float(jnp.max(jnp.abs(acts[t]))))
    return {t: v / 127.0 for t, v in scales.items()}


@dataclasses.dataclass
class QuantizedLinear:
    """Pre-quantized weight bank + int8 dot (single-layer serving fast path).

    The whole-tree form of this is ``prepare_params(..., mode="int8")``.
    """

    w_q: jax.Array  # int8 (in, out)
    scale: jax.Array  # (1, out)

    @staticmethod
    def from_float(w):
        w_q, scale = quantize_weight(w)
        return QuantizedLinear(w_q, scale)

    def __call__(self, x, *, effective_bits: int = 8):
        return int8_dot(x, self.w_q, effective_bits=effective_bits, w_scale=self.scale)
