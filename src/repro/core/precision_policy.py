"""Layer-wise precision / iteration-depth policy (paper §III).

The paper configures each layer's CORDIC depth "using an accuracy-sensitivity
metric [Flex-PE], enabling dynamic selection between approximate and accurate
modes based on layer criticality". We implement that metric concretely:

    sensitivity(l) = E[ || J_l * eps_l || ] / || logits ||

i.e. how much output perturbation one LSB of quantization noise injected at
layer l's output causes. Estimated with a JVP per layer on a calibration
batch — no labels needed. Layers are then greedily assigned the *approximate*
depth (2/3 of full — the 33% cycle saving) starting from the least sensitive,
until the requested cycle-reduction budget is met; everything else (and all
router/normalization layers, which the metric pins) stays at full depth.

The resulting :class:`PrecisionPolicy` is a first-class config object consumed
by the engine, the serving path, and the dry-run configs.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .fxp import FXP8, FXP16, FxPFormat

__all__ = [
    "CRITICAL_KEYWORDS",
    "LayerPrecision",
    "PrecisionPolicy",
    "pin_critical",
    "sensitivity_scan",
    "assign_depths",
]

CRITICAL_KEYWORDS = ("router", "gate_logits", "norm", "embed")
_CRITICAL_KEYWORDS = CRITICAL_KEYWORDS  # backwards-compat alias


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    """Per-layer execution point: FxP format + CORDIC iteration depth."""

    fmt: FxPFormat
    depth: int

    @property
    def mode(self) -> str:
        return "accurate" if self.depth >= cordic.full_depth(self.fmt) else "approximate"

    def to_json(self) -> Dict[str, int]:
        return {"bits": self.fmt.bits, "frac": self.fmt.frac, "depth": int(self.depth)}

    @staticmethod
    def from_json(d: Mapping[str, int]) -> "LayerPrecision":
        return LayerPrecision(FxPFormat(int(d["bits"]), int(d["frac"])), int(d["depth"]))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Maps layer names to execution points; unlisted layers use ``default``."""

    default: LayerPrecision
    overrides: Mapping[str, LayerPrecision] = dataclasses.field(default_factory=dict)

    def for_layer(self, name: str) -> LayerPrecision:
        if name in self.overrides:
            return self.overrides[name]
        for key, lp in self.overrides.items():
            if key and key in name:
                return lp
        return self.default

    @staticmethod
    def uniform(fmt: FxPFormat = FXP8, depth: Optional[int] = None) -> "PrecisionPolicy":
        return PrecisionPolicy(LayerPrecision(fmt, depth or cordic.full_depth(fmt)))

    @staticmethod
    def accurate(fmt: FxPFormat = FXP8) -> "PrecisionPolicy":
        return PrecisionPolicy.uniform(fmt, cordic.full_depth(fmt))

    @staticmethod
    def approximate(fmt: FxPFormat = FXP8) -> "PrecisionPolicy":
        return PrecisionPolicy.uniform(fmt, cordic.approx_depth(fmt))

    # -- JSON round-trip (the ``--policy-file`` serving interchange format) ---
    def to_json(self) -> Dict:
        return {
            "default": self.default.to_json(),
            "overrides": {k: lp.to_json() for k, lp in self.overrides.items()},
        }

    @staticmethod
    def from_json(d: Mapping) -> "PrecisionPolicy":
        return PrecisionPolicy(
            LayerPrecision.from_json(d["default"]),
            {k: LayerPrecision.from_json(v) for k, v in d.get("overrides", {}).items()},
        )

    def save(self, path: str) -> None:
        """Write the policy as JSON (what ``--policy-file`` loads back)."""
        dirname = os.path.dirname(path)
        if dirname:
            os.makedirs(dirname, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")

    @staticmethod
    def load(path: str) -> "PrecisionPolicy":
        with open(path) as f:
            return PrecisionPolicy.from_json(json.load(f))


def pin_critical(
    policy: PrecisionPolicy, *, critical: Sequence[str] = CRITICAL_KEYWORDS
) -> PrecisionPolicy:
    """Hard accuracy floor: critical-keyword layers always run at full depth.

    Used when deriving approximate execution points for the runtime-adaptive
    bank (``repro.runtime``): however aggressively the mode controller demotes,
    routers / norms / embeddings keep the accurate CORDIC depth — the paper
    keeps accuracy-sensitive computations accurate regardless of mode.
    """
    pinned = LayerPrecision(
        policy.default.fmt, cordic.full_depth(policy.default.fmt)
    )
    # keyword floors FIRST: for_layer's substring scan walks insertion order,
    # so a non-critical override key that happens to substring-match a
    # critical layer name (e.g. "final" vs "final_norm") cannot shadow the floor
    overrides: Dict[str, LayerPrecision] = {key: pinned for key in critical}
    for name, lp in policy.overrides.items():
        if any(k in name for k in critical):
            overrides[name] = LayerPrecision(lp.fmt, cordic.full_depth(lp.fmt))
        else:
            overrides[name] = lp
    return PrecisionPolicy(policy.default, overrides)


def sensitivity_scan(
    apply_fn: Callable,
    params,
    batch,
    layer_taps: Sequence[str],
    *,
    fmt: FxPFormat = FXP8,
    rng: Optional[jax.Array] = None,
) -> Dict[str, float]:
    """Estimate per-layer accuracy sensitivity on a calibration batch.

    ``apply_fn(params, batch, noise: Dict[str, scale])`` must inject
    ``noise[name] * eps`` at each tapped layer output (models in this repo
    expose that hook). Returns name -> normalized output perturbation.
    """
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    base = apply_fn(params, batch, {})
    base_norm = jnp.linalg.norm(base.astype(jnp.float32)) + 1e-9
    out: Dict[str, float] = {}
    lsb = fmt.scale
    for i, name in enumerate(layer_taps):
        def tangent_fn(eps_scale, name=name):
            return apply_fn(params, batch, {name: eps_scale})
        _, jvp = jax.jvp(tangent_fn, (0.0,), (lsb,))
        out[name] = float(jnp.linalg.norm(jvp.astype(jnp.float32)) / base_norm)
    return out


def assign_depths(
    sensitivities: Mapping[str, float],
    *,
    fmt: FxPFormat = FXP8,
    cycle_reduction_target: float = 0.33,
    critical: Sequence[str] = _CRITICAL_KEYWORDS,
) -> PrecisionPolicy:
    """Greedy depth assignment meeting a cycle-reduction budget.

    Every layer moved to approximate depth saves ``1 - approx/full`` of its
    cycles; assuming uniform per-layer MAC counts, moving a fraction p of
    layers saves p * 1/3 of all cycles. Critical-keyword layers are never
    demoted (the paper keeps accuracy-sensitive computations accurate).
    """
    full = cordic.full_depth(fmt)
    approx = cordic.approx_depth(fmt)
    per_layer_saving = 1.0 - approx / full
    names = sorted(sensitivities, key=lambda n: sensitivities[n])
    overrides: Dict[str, LayerPrecision] = {}
    saved = 0.0
    n = max(len(names), 1)
    for name in names:
        if any(k in name for k in critical):
            continue
        if saved >= cycle_reduction_target:
            break
        overrides[name] = LayerPrecision(fmt, approx)
        saved += per_layer_saving / n
    return PrecisionPolicy(LayerPrecision(fmt, full), overrides)
