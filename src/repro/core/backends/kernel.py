"""kernel backend: the Pallas ``cordic_mac`` kernel (same math as carmen).

Prepared path: weights are signed-digit-rounded once (the PE weight memory
bank); the kernel is invoked with ``w_prequantized=True`` so its epilogue only
re-grids the already-rounded values (an exact integer cast) instead of
re-running the rounding recurrence per call.
"""
from __future__ import annotations

from .. import cordic
from ..fxp import FxPFormat
from .base import Backend, PreparedWeight, unit_fmt

__all__ = ["KernelBackend"]


class KernelBackend(Backend):
    name = "kernel"

    def prepare(self, w, lp, *, stacked_axes: int = 0, in_axes=None):
        fmt = unit_fmt(lp.fmt)
        data = cordic.signed_digit_round(w, int(lp.depth), fmt)
        # x_fmt: bank-carried activation format (see CarmenBackend.prepare)
        return PreparedWeight(
            data, None, self.name,
            (("depth", int(lp.depth)), ("fmt", (fmt.bits, fmt.frac)),
             ("x_fmt", (lp.fmt.bits, lp.fmt.frac))),
        )

    def dot(self, ctx, x, w, *, name: str = ""):
        from repro.kernels.cordic_mac import ops as mac_ops

        x2 = x.reshape(-1, x.shape[-1])
        if isinstance(w, PreparedWeight):
            bits, frac = w.get("fmt")
            x_fmt = w.get("x_fmt")
            x_fmt = (
                FxPFormat(*x_fmt) if x_fmt else ctx.layer_precision(name).fmt
            )
            out = mac_ops.cordic_mac(
                x2, w.data, depth=w.get("depth"), x_fmt=x_fmt,
                w_fmt=FxPFormat(bits, frac), w_prequantized=True,
            )
        else:
            lp = ctx.layer_precision(name)
            out = mac_ops.cordic_mac(
                x2, w, depth=int(lp.depth), x_fmt=lp.fmt, w_fmt=unit_fmt(lp.fmt)
            )
        return out.reshape(x.shape[:-1] + (w.shape[-1],)).astype(ctx.compute_dtype)
