"""The fused CORDIC dot+AF kernel: bit-parity and zero-recompile guarantees.

Three layers of contract, each gated on exact equality:

* kernel vs pure-XLA reference — the fused Pallas pass (interpret mode here,
  native on TPU) and ``fused_dot_af_ref`` run the identical int32-dot +
  activation-epilogue chain, so they must agree bitwise at every (depth,
  format, AF mode) combination;
* one compiled program serves every execution point — depth/format ride a
  traced params vector (scalar-prefetch operand on TPU), so swapping points
  must not add jit cache entries, while still changing the arithmetic;
* serving through the fused path == serving through the XLA fallback — the
  kernel backend's greedy decode streams are bit-identical with
  ``fused="on"`` and ``fused="off"`` for dense / MoE / MLA, including the
  adaptive controller and the self-speculative decoder.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, PrecisionPolicy
from repro.core.backends import prepare_params
from repro.core.backends.base import PreparedWeight
from repro.core.fxp import FXP8, FXP16
from repro.core import cordic
from repro.kernels.cordic_fused import (
    FUSED_AFS,
    fused_dot_af,
    fused_dot_af_ref,
    make_point,
)
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32) * 0.2)
    return x, w


# ---------------------------------------------------------------------------
# kernel vs XLA reference: bitwise across depths x formats x AF modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", [FXP8, FXP16], ids=["fxp8", "fxp16"])
@pytest.mark.parametrize("depth", [4, 6, None], ids=["d4", "d6", "full"])
def test_fused_kernel_matches_ref_bitwise(operands, fmt, depth):
    x, w = operands
    depth = depth if depth is not None else fmt.frac + 1
    sd = cordic.signed_digit_round(w, depth, fmt)
    point = make_point(depth, fmt, fmt)
    for af in FUSED_AFS:
        for compute_round in (False, True):
            got = fused_dot_af(x, sd, point, af_mode=af, af_depth=8,
                               af_fmt=FXP8, compute_round=compute_round)
            want = fused_dot_af_ref(x, sd, point, af_mode=af, af_depth=8,
                                    af_fmt=FXP8, compute_round=compute_round)
            assert jnp.array_equal(got, want), (af, compute_round)


def test_fused_identity_matches_cordic_mac(operands):
    """Mode 0 (plain dot) reproduces the standalone MAC kernel bitwise —
    the fused kernel is a strict superset of the unfused prepared dot."""
    from repro.kernels.cordic_mac import ops as mac_ops

    x, w = operands
    for fmt in (FXP8, FXP16):
        for depth in (4, fmt.frac + 1):
            sd = cordic.signed_digit_round(w, depth, fmt)
            got = fused_dot_af(x, sd, make_point(depth, fmt, fmt),
                               af_mode="identity")
            want = mac_ops.cordic_mac(x, sd, depth=depth, x_fmt=fmt, w_fmt=fmt,
                                      w_prequantized=True)
            assert jnp.array_equal(got, want), (fmt, depth)


# ---------------------------------------------------------------------------
# depth/format as data: one compiled program serves every execution point
# ---------------------------------------------------------------------------


def test_point_swap_adds_no_compile(operands):
    """Two execution points (different depth AND format) through the same
    call signature: exactly one new jit entry, two different results."""
    x, w = operands
    sd8 = cordic.signed_digit_round(w, 4, FXP8)
    base = fused_dot_af._cache_size()
    a = fused_dot_af(x, sd8, make_point(4, FXP8, FXP8), af_mode="gelu")
    after_first = fused_dot_af._cache_size()
    assert after_first == base + 1
    b = fused_dot_af(x, sd8, make_point(13, FXP16, FXP16), af_mode="gelu")
    assert fused_dot_af._cache_size() == after_first  # same program
    assert not jnp.array_equal(a, b)  # the params vector is live arithmetic


def test_prepared_kernel_trees_share_treedef():
    """prepare_params at two kernel-mode policies yields treedef-identical
    trees (empty meta + traced point), so jitted serving programs are reused
    across a ModeController switch."""
    rng = np.random.default_rng(1)
    # key must be a recognized engine-weight name or prepare_params skips it
    params = {"up": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))}
    approx = prepare_params(params, PrecisionPolicy.approximate(FXP8), "kernel")
    hifi = prepare_params(params, PrecisionPolicy.accurate(FXP16), "kernel")
    assert isinstance(approx["up"], PreparedWeight)
    assert approx["up"].point is not None
    assert jax.tree.structure(approx) == jax.tree.structure(hifi)

    ctx = EngineContext(mode="kernel", compute_dtype=jnp.float32, fused="on")
    f = jax.jit(lambda tree, x: ctx.linear_af(x, tree["up"], af="relu"))
    x = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    f(approx, x)
    f(hifi, x)
    assert f._cache_size() == 1  # one program, both points
    # The params vector is live arithmetic: the raw dot (no AF re-quantization
    # collapsing values onto the FXP8 activation grid) differs between points.
    da = ctx.dot(x, approx["up"], name="up")
    db = ctx.dot(x, hifi["up"], name="up")
    assert not jnp.array_equal(da, db)


def test_prepared_weight_point_survives_scan_slicing():
    """Stacked layer banks are scan xs: each slice must carry its own params
    vector (broadcast at prepare time), not a scalar shred of one."""
    rng = np.random.default_rng(2)
    stacked = jnp.asarray(rng.normal(size=(3, 8, 8)).astype(np.float32))
    from repro.core.backends import get_backend

    pw = get_backend("kernel").prepare(
        stacked, PrecisionPolicy.accurate(FXP8).for_layer("up"), stacked_axes=1
    )
    assert pw.point.shape == (3, 5)

    ctx = EngineContext(mode="kernel", compute_dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))

    def layer(h, w):
        return ctx.dot(h.astype(jnp.float32), w, name="w"), None

    h, _ = jax.lax.scan(layer, x, pw)
    ref = x
    for i in range(3):
        sliced = PreparedWeight(pw.data[i], None, "kernel", (), pw.point[i])
        ref = ctx.dot(ref.astype(jnp.float32), sliced, name="w")
    assert jnp.array_equal(h, ref)


# ---------------------------------------------------------------------------
# backend dispatch: fused == fallback == unfused linear+AF chain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compute_dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_linear_af_fused_matches_unfused_chain(operands, compute_dtype):
    x, w = operands
    lp = PrecisionPolicy.accurate(FXP8)
    tree = prepare_params({"up": w}, lp, "kernel")
    assert isinstance(tree["up"], PreparedWeight)
    base = EngineContext(mode="kernel", policy=lp, compute_dtype=compute_dtype)
    xc = x.astype(compute_dtype)
    outs = {}
    for fused in ("on", "off"):
        ctx = dataclasses.replace(base, fused=fused)
        outs[fused] = ctx.linear_af(xc, tree["up"], af="gelu", name="up")
    unfused = base.activate(base.linear(xc, tree["up"], name="up"), "gelu")
    assert jnp.array_equal(outs["on"], outs["off"])
    assert jnp.array_equal(outs["on"], unfused)


def test_prepared_dot_still_matches_per_call_kernel(operands):
    """The new prepared chain (int32 dot from the params vector) stays bit-
    identical to the per-call cordic_mac path at the same (depth, fmt)."""
    from repro.kernels.cordic_mac import ops as mac_ops

    x, w = operands
    lp = PrecisionPolicy.accurate(FXP8)
    tree = prepare_params({"up": w}, lp, "kernel")
    assert isinstance(tree["up"], PreparedWeight)
    ctx = EngineContext(mode="kernel", policy=lp, compute_dtype=jnp.float32)
    prepared = ctx.dot(x, tree["up"], name="up")
    layer = lp.for_layer("up")
    from repro.core.backends.base import unit_fmt

    per_call = mac_ops.cordic_mac(
        x, w, depth=int(layer.depth), x_fmt=layer.fmt,
        w_fmt=unit_fmt(layer.fmt),
    )
    assert jnp.array_equal(prepared, per_call)


# ---------------------------------------------------------------------------
# serving: fused path == XLA fallback, stream for stream
# ---------------------------------------------------------------------------


def _setup(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, *, max_new=4):
    rng = np.random.default_rng(0)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 3 + i).astype(np.int32),
                max_new)
        for i in range(n)
    ]


def _kernel_ctx(fused):
    return EngineContext(mode="kernel", policy=PrecisionPolicy.accurate(FXP8),
                         compute_dtype=jnp.float32, fused=fused)


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-v3-671b"])
def test_serving_fused_bit_identical_to_fallback(arch):
    """Greedy decode through the fused Pallas path (interpret mode) ==
    the prepared XLA chain, for the dense and MoE+MLA families."""
    cfg, model, params = _setup(arch)
    out, margins = {}, {}
    for fused in ("off", "on"):
        reqs = _requests(cfg, 2)
        out[fused] = BatchedServer(model, _kernel_ctx(fused), params, slots=2,
                                   max_len=16, burst=2).run(reqs)
        margins[fused] = [r.margins for r in reqs]
    assert out["on"] == out["off"]
    for a, b in zip(margins["on"], margins["off"]):
        np.testing.assert_array_equal(a, b)


def test_serving_adaptive_fused_parity_and_zero_recompile():
    """An adaptive kernel-mode bank under forced switching: streams match
    between fused and fallback, the controller actually switches, and the
    burst program compiles ONCE across all execution points."""
    from repro.runtime import (
        ControllerConfig, ModeController, build_bank, default_points,
    )

    cfg, model, params = _setup("olmo-1b")
    outs = {}
    for fused in ("off", "on"):
        bank = build_bank(params, "kernel", default_points(FXP8),
                          specs=model.specs())
        for name in bank.names[1:]:
            assert (jax.tree.structure(bank.tree(name))
                    == jax.tree.structure(bank.tree(bank.names[0])))
        ctrl = ModeController(
            bank, ControllerConfig(margin_demote=0.5, hysteresis=1)
        )
        srv = BatchedServer(model, _kernel_ctx(fused), params, slots=2,
                            max_len=24, burst=2, controller=ctrl)
        outs[fused] = srv.run(_requests(cfg, 2, max_new=8))
        tele = srv.telemetry.summary()
        assert tele["switches"] >= 1  # the ladder was actually walked
        assert len([k for k, v in tele["steps_by_point"].items() if v]) >= 2
        for fn in srv._burst_fns.values():
            assert fn._cache_size() == 1  # one program serves every point
    assert outs["on"] == outs["off"]


def test_serving_speculative_fused_parity():
    """Self-speculative serving (draft approx / verify accurate) through the
    fused path matches the fallback stream for stream."""
    from repro.runtime import build_bank, default_points
    from repro.spec import SpecConfig

    cfg, model, params = _setup("olmo-1b")
    outs = {}
    for fused in ("off", "on"):
        bank = build_bank(params, "kernel", default_points(FXP8),
                          specs=model.specs())
        srv = BatchedServer(model, _kernel_ctx(fused), params, slots=2,
                            max_len=24, speculate=SpecConfig(draft_len=2),
                            bank=bank)
        outs[fused] = srv.run(_requests(cfg, 2, max_new=6))
        assert srv.spec_telemetry.summary()["emitted"] > 0
    assert outs["on"] == outs["off"]
