from .ops import gqa_decode_attention, mla_decode_attention

__all__ = ["gqa_decode_attention", "mla_decode_attention"]
