"""KV-cache index surgery shared by serving and speculative decoding.

Attention/MLA decode caches are (rows, write index) pairs per layer; the
per-query-causal mask (``key_pos <= query_pos``) makes every row at a position
``>= index`` invisible, and the next decode write lands AT the index — so any
rows past it are overwritten right before they could become visible. Two
serving mechanisms lean on that scratch discipline:

* **bucketed prefill** pads a prompt to a power-of-two block, runs one
  multi-token decode, then rewinds the index to the true prompt length —
  the padded tail's rows become invisible garbage, reclaimed by decode;
* **speculative rollback** truncates the cache to the accepted prefix after
  a verify round (``repro.spec.rollback`` re-exports these helpers).

Recurrent-state families (ssm/hybrid/audio mixers) carry no positional index
in their mixer state and cannot be rewound; callers gate on the family.

Index leaves are identified exactly as ``transformer._cache_index`` does:
integer dtype, stacked ``(layers, batch)`` shape; every attention layer
advances in lockstep so one ``(B,)`` vector describes the whole cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bucket_length", "cache_positions", "scatter_rows", "with_cache_positions"]


def _is_index(leaf) -> bool:
    return (
        hasattr(leaf, "dtype")
        and jnp.issubdtype(leaf.dtype, jnp.integer)
        and getattr(leaf, "ndim", 0) >= 2
    )


def cache_positions(cache):
    """Per-slot committed row counts, ``(B,)`` int32 (layer 0 is authoritative)."""
    for leaf in jax.tree.leaves(cache):
        if _is_index(leaf):
            return leaf[0]
    raise ValueError(
        "cache carries no write index — recurrent-state caches cannot be "
        "positioned/rolled back"
    )


def with_cache_positions(cache, positions):
    """Rewrite every layer's write index to ``positions`` ((B,) int32)."""
    positions = jnp.asarray(positions, jnp.int32)

    def put(leaf):
        if _is_index(leaf):
            return jnp.broadcast_to(positions, leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree.map(put, cache)


def bucket_length(plen: int, max_len: int) -> int:
    """Next power-of-two block length for a ``plen``-token prompt.

    Prefill compiles one program per distinct block shape; rounding prompts up
    to buckets caps that at O(log max_len) programs instead of one per
    distinct prompt length. Clamped to ``max_len`` (the cache row budget).
    """
    b = 1
    while b < plen:
        b *= 2
    return min(b, max_len)


def scatter_rows(full, row, slot):
    """Write a single-row cache into slot ``slot`` of a multi-slot cache.

    Shape-driven (works on any cache pytree, traced or eager): the one axis
    where the trees disagree is the slot axis. ``slot`` may be a traced int.
    """

    def put(dst, src):
        src = src.astype(dst.dtype)
        if dst.shape == src.shape:  # slots == 1: whole-cache replacement
            return src
        diff = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape)) if a != b]
        assert len(diff) == 1, (dst.shape, src.shape)
        return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, diff[0])

    return jax.tree.map(put, full, row)
