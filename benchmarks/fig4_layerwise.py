"""Paper Fig. 4 — VGG-16 layer-wise execution under the precision-aware schedule.

Per-layer MACs (from configs/carmen_vgg16.py) x the iterative-PE cycle model
at each layer's assigned depth. The accuracy-sensitivity schedule mirrors the
paper's: first/last layers (feature extraction / classifier head) accurate,
middle layers approximate. Derived: per-layer cycle share and the total cycle
reduction vs an all-accurate schedule.
"""
from __future__ import annotations

from repro.configs.carmen_vgg16 import VGG16_LAYERS
from repro.core import FXP8_UNIT, approx_depth, full_depth

PES = 256  # vector-engine lanes


def schedule():
    """Layer -> depth: first block + fc8 accurate, middle approximate."""
    full, approx = full_depth(FXP8_UNIT), approx_depth(FXP8_UNIT)
    depths = {}
    for spec in VGG16_LAYERS:
        critical = spec.name.startswith("conv1") or spec.name == "fc8"
        depths[spec.name] = full if critical else approx
    return depths


def run():
    full = full_depth(FXP8_UNIT)
    depths = schedule()
    rows = []
    total_mixed = total_full = 0
    for spec in VGG16_LAYERS:
        d = depths[spec.name]
        cycles = spec.macs * (d + 1) / PES
        cycles_full = spec.macs * (full + 1) / PES
        total_mixed += cycles
        total_full += cycles_full
        rows.append(
            (f"fig4.{spec.name}", 0.0,
             f"MACs={spec.macs/1e6:.1f}M;depth={d};cycles={cycles/1e6:.1f}M")
        )
    saving = 1 - total_mixed / total_full
    rows.append(
        ("fig4.total", 0.0,
         f"mixed={total_mixed/1e9:.2f}Gcyc;all_accurate={total_full/1e9:.2f}Gcyc;"
         f"cycle_saving={saving:.2%}")
    )
    return rows
