"""Roofline report: three terms per (arch x shape x mesh) from dry-run artifacts.

    compute term    = flops_dev / peak_FLOPs_per_chip          [s]
    memory term     = hbm_bytes_dev / HBM_bw                   [s]
    collective term = coll_bytes_dev / link_bw                 [s]

flops_dev / hbm_bytes_dev / coll_bytes_dev come from the loop-corrected
analyzer over the post-SPMD (per-device) HLO — see hlo_analysis.py. The
collective term conservatively charges all traffic to ONE ICI link
(~50 GB/s); multi-link overlap is an optimization recorded in §Perf.

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (prefill) or 2*N_active*B
(decode) — the "useful compute" yardstick; HLO/MODEL ratio exposes
remat/redundancy waste.

Usage: python -m repro.launch.roofline [--dir artifacts/dryrun] [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.models import get_model
from repro.models import params as P_

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link
CHIPS = {"single": 256, "multi": 512}


def routed_expert_params(cfg) -> int:
    if not cfg.moe:
        return 0
    m = cfg.moe
    n_moe_layers = (cfg.num_layers - m.first_dense_layers) // m.moe_every
    return n_moe_layers * m.num_experts * 3 * cfg.d_model * m.d_ff_expert


def active_params(cfg) -> int:
    """Params touched per token: total - embedding-table lookups - inactive experts."""
    total = get_model(cfg).count_params()
    embed = cfg.vocab_size * cfg.d_model  # lookup, not matmul
    routed = routed_expert_params(cfg)
    active_routed = routed * (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 0
    return int(total - embed - routed + active_routed)


def model_flops(cfg, shape) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per row


def attn_flops(cfg, shape) -> float:
    """Quadratic attention score+value flops (reported alongside, not in 6ND)."""
    if not cfg.num_heads:
        return 0.0
    d_attn = cfg.num_heads * cfg.head_dim
    b, s = shape.global_batch, shape.seq_len
    layers = cfg.num_layers
    if shape.kind == "train":
        return 3 * 4.0 * b * s * s * d_attn * layers / 2  # causal half, fwd+bwd
    if shape.kind == "prefill":
        return 4.0 * b * s * s * d_attn * layers / 2
    return 4.0 * b * s * d_attn * layers  # decode: 1 x S per layer


def load_records(art_dir: str, mesh: Optional[str] = None, mode: str = "exact", tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("mode", "exact") != mode or r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def terms(rec: Dict) -> Dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = CHIPS[rec["mesh"]]
    t_c = rec["flops_dev"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_dev"] / HBM_BW
    t_x = rec["coll_bytes_dev"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    mf = model_flops(cfg, shape)
    hlo_global = rec["flops_dev"] * chips
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "bottleneck": dom[0],
        "step_s": dom[1],
        "model_flops": mf,
        "attn_flops": attn_flops(cfg, shape),
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_frac": (mf / chips / PEAK_FLOPS) / dom[1] if dom[1] else 0.0,
    }


def render(recs: List[Dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | bottleneck | "
        "MODEL TF | useful (6ND/HLO) | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | SKIP: {r['reason'][:40]} | | | |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | FAIL: {r.get('error','')[:40]} | | | |"
            )
            continue
        t = terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| **{t['bottleneck']}** | {t['model_flops']/1e12:.1f} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} |"
        )
    return hdr + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")
    ap.add_argument("--dir", default=default_dir)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--mode", default="exact")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh, args.mode, args.tag)
    md = render(recs)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)


if __name__ == "__main__":
    main()
