"""Fit the PE-array model's constants against the Tables 2/3/5 measurements.

The analytic cycle model (``mac_cycles``: one CORDIC iteration per cycle)
has shape but no units. Calibration pins both against what this machine
actually measures, using the same measurement protocol as
``benchmarks/table2_mac.py`` / ``table3_af.py`` / ``table5_scaling.py``:

* **sec_per_cycle** — the wall seconds one MAC iteration costs, the slope of
  bit-faithful ``cordic_matmul`` time over depth (Table 2 protocol: the
  bit-faithful path's wall time is genuinely proportional to depth — it
  executes the iteration loop — unlike the fast error-model, whose matmul
  time is depth-independent).
* **mac_overhead** — extra cycles per MAC beyond depth+1, from the fit's
  intercept above the dispatch floor. Clamped to [0, 1]: the +1 in the
  analytic model already covers the accumulate, so anything above one more
  cycle/MAC is dispatch noise, not pipeline structure.
* **af_iter_cycles** — Table 3 protocol: AF-block wall time per element per
  CORDIC iteration over the fitted sec_per_cycle. Fitted *per iteration*
  (not per element) because the AF block is CORDIC-iterative like the PEs:
  keeping AF cost on the same depth ladder preserves per-point cost ratios,
  so calibrating never distorts the savings fractions the gates check.
* **parallel_overhead_exp** — Table 5 protocol: the measured time exponent
  across PE-lane counts (0 = perfect scaling; the paper claims near-linear
  throughput, i.e. exponent ≈ 0).
* **host_sync_cycles** — the dispatch floor (jitted exact-dot wall time) in
  cycles: what the array idles per host round-trip, the term that makes
  burst=1 serving predictably slower than burst=8.

:func:`fit_calibration` is pure (measurements in, calibration out) so tests
fit synthetic measurements with known constants; :func:`run_calibration`
measures then fits. The export round-trips through JSON into
``estimate_point_cycles(calibration=...)`` / ``build_bank(calibration=...)``
so the ModeController and the simulator optimize the same cost.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import time
from typing import Dict, Optional

import numpy as np

CALIBRATION_SCHEMA = "carmen-sim-calibration"
CALIBRATION_VERSION = 1

__all__ = ["CALIBRATION_SCHEMA", "CALIBRATION_VERSION", "fit_calibration",
           "load_calibration", "measure", "run_calibration",
           "save_calibration"]


# -- measurement (Tables 2/3/5 protocol, locally sized) -----------------------

def _timed(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def measure(*, smoke: bool = False) -> Dict:
    """Run the calibration measurements on this machine.

    Mirrors the benchmark protocols at locally-chosen sizes (``smoke``
    shrinks shapes and rep counts for CI). Returns the measurement dict
    :func:`fit_calibration` consumes.
    """
    import jax

    from repro.core import (FXP8, FXP8_UNIT, AF_NAMES, carmen_matmul_fast,
                            cordic_matmul, full_depth, multi_af_float,
                            quantize)

    rng = np.random.default_rng(0)
    reps = 2 if smoke else 5

    # Table 2: bit-faithful MAC time vs depth (the slope is sec/iteration)
    m, k, n = (32, 128, 32) if smoke else (64, 256, 64)
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
    xq, wq = quantize(x, FXP8), quantize(w, FXP8_UNIT)
    depths = (2, full_depth(FXP8_UNIT)) if smoke else (2, 4, full_depth(FXP8_UNIT))
    mac = {}
    for d in depths:
        f = jax.jit(lambda a, b, d=d: cordic_matmul(a, b, d, FXP8_UNIT))
        mac[int(d)] = _timed(lambda: f(xq, wq), reps)

    # dispatch floor: a jitted exact dot on the same shape
    g = jax.jit(lambda a, b: a @ b)
    dispatch_s = _timed(lambda: g(x, w), reps)

    # Table 3: AF-block time per element
    af_shape = (32, 256) if smoke else (64, 512)
    xa = rng.uniform(-1, 1, af_shape).astype(np.float32)
    af_depth = full_depth(FXP8)
    modes = AF_NAMES[:2] if smoke else AF_NAMES
    af = {}
    for mode in modes:
        f = jax.jit(lambda mm=mode: multi_af_float(xa, mm, af_depth, FXP8))
        af[mode] = _timed(f, reps)

    # Table 5: PE-lane scaling (fast model, fixed K and token count)
    lm, lk = (1024, 256) if smoke else (4096, 512)
    xl = rng.uniform(-1, 1, (lm, lk)).astype(np.float32)
    fl = jax.jit(lambda a, b: carmen_matmul_fast(
        a, b, full_depth(FXP8_UNIT), FXP8, FXP8_UNIT))
    lanes = {}
    for nl in (64, 256):
        wl = rng.uniform(-1, 1, (lk, nl)).astype(np.float32)
        lanes[int(nl)] = _timed(lambda: fl(xl, wl), reps)

    return {
        "mac": {"shape": [m, k, n], "times_by_depth": mac},
        "dispatch_s": dispatch_s,
        "af": {"shape": list(af_shape), "depth": af_depth,
               "n_elems": int(np.prod(af_shape)), "times_by_mode": af},
        "lanes": {"shape": [lm, lk], "times_by_n": lanes},
        "smoke": smoke,
    }


# -- fitting ------------------------------------------------------------------

def fit_calibration(measurements: Dict) -> Dict:
    """Fit array constants from a :func:`measure` dict (pure; testable with
    synthetic measurements). Every constant is clamped to its documented
    sane range — a noisy machine degrades toward the analytic model instead
    of producing a pathological one."""
    mac = measurements["mac"]
    m, k, n = mac["shape"]
    macs = float(m) * k * n
    pts = sorted((int(d), float(t)) for d, t in mac["times_by_depth"].items())
    if len(pts) < 2:
        raise ValueError("calibration needs bit-faithful timings at >= 2 depths")
    xs = np.array([d + 1 for d, _ in pts], np.float64)
    ys = np.array([t for _, t in pts], np.float64)
    slope, intercept = np.polyfit(xs, ys, 1)
    fallback = slope <= 0  # depth signal lost in noise: degrade gracefully
    if fallback:
        slope = float(ys.max() / (macs * xs.max()))
        intercept = 0.0
    sec_per_iter = float(slope) / macs  # seconds per MAC iteration
    resid = float(np.max(np.abs(np.polyval([slope, intercept], xs) - ys))
                  / ys.max())

    dispatch_s = float(measurements.get("dispatch_s", 0.0))
    mac_overhead = 0.0
    if not fallback and macs * sec_per_iter > 0:
        mac_overhead = (float(intercept) - dispatch_s) / (macs * sec_per_iter)
    mac_overhead = float(np.clip(mac_overhead, 0.0, 1.0))

    af = measurements.get("af")
    af_iter = 1.0
    if af and af.get("times_by_mode"):
        per_elem = [max(float(t) - dispatch_s, 0.0) / af["n_elems"]
                    for t in af["times_by_mode"].values()]
        iters = float(af.get("depth", 7)) + 1.0
        af_iter = float(np.clip(
            np.mean(per_elem) / (sec_per_iter * iters), 0.25, 8.0))

    lanes = measurements.get("lanes", {}).get("times_by_n", {})
    exp = 0.0
    if len(lanes) >= 2:
        ns = sorted(int(x) for x in lanes)
        lo, hi = ns[0], ns[-1]
        exp = math.log(float(lanes[hi]) / float(lanes[lo])) / math.log(hi / lo)
        exp = float(np.clip(exp, 0.0, 1.5))

    constants = {
        "sec_per_cycle": sec_per_iter,
        "mac_overhead": mac_overhead,
        "af_iter_cycles": af_iter,
        "parallel_overhead_exp": exp,
        "host_sync_cycles": max(dispatch_s, 0.0) / sec_per_iter,
    }
    digest = hashlib.sha256(
        json.dumps({kk: (round(v, 12) if isinstance(v, float) else v)
                    for kk, v in constants.items()},
                   sort_keys=True).encode()).hexdigest()[:8]
    return {
        "schema": CALIBRATION_SCHEMA,
        "version": CALIBRATION_VERSION,
        "id": f"calib-{digest}",
        "constants": constants,
        "fit": {
            "mac_fit_max_rel_resid": resid,
            "mac_slope_fallback": bool(fallback),
            "measured_scaling_exponent": exp,
        },
        "source": measurements,
    }


def run_calibration(*, smoke: bool = False) -> Dict:
    """Measure this machine and fit: the one-call calibration entry point."""
    return fit_calibration(measure(smoke=smoke))


# -- persistence --------------------------------------------------------------

def save_calibration(calibration: Dict, path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(calibration, f, indent=2)
    return path


def load_calibration(path: str) -> Dict:
    with open(path) as f:
        calibration = json.load(f)
    if calibration.get("schema") != CALIBRATION_SCHEMA:
        raise ValueError(
            f"{path}: not a {CALIBRATION_SCHEMA} export "
            f"(schema={calibration.get('schema')!r})")
    if calibration.get("version", 0) > CALIBRATION_VERSION:
        raise ValueError(
            f"{path}: calibration version {calibration['version']} is newer "
            f"than this reader ({CALIBRATION_VERSION})")
    return calibration


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Fit PE-array calibration from local measurements")
    ap.add_argument("--out", default="artifacts/sim/calibration.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few reps (CI)")
    args = ap.parse_args(argv)
    calibration = run_calibration(smoke=args.smoke)
    save_calibration(calibration, args.out)
    print(json.dumps({"id": calibration["id"],
                      "constants": calibration["constants"],
                      "fit": calibration["fit"],
                      "out": args.out}, indent=2))


if __name__ == "__main__":
    main()
