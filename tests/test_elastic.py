"""Elastic restore across mesh shapes (subprocess: needs >1 host device).

Saves a sharded param tree under a (4, 2) mesh, restores it under (2, 4) —
the restart-on-a-different-topology path checkpoints must support at scale.
Runs in a subprocess so the main test process keeps its single-device jax.
"""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.ones((8,), jnp.float32)}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sh_a = {"w": NamedSharding(mesh_a, P("data", "model")), "b": NamedSharding(mesh_a, P("model"))}
placed = jax.tree.map(jax.device_put, tree, sh_a)

with tempfile.TemporaryDirectory() as d:
    checkpoint.save(d, 1, placed)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("model", "data")), "b": NamedSharding(mesh_b, P("data"))}
    restored = checkpoint.restore(d, 1, tree, shardings=sh_b)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(tree[k]))
        assert restored[k].sharding.mesh.shape == {"data": 2, "model": 4}
print("ELASTIC_OK")
"""


def test_elastic_restore_across_mesh_shapes():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/tmp"},
        cwd="/root/repo",
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
