"""AdamW (from scratch) with ZeRO-style state sharding.

Optimizer states inherit the parameter shardings (FSDP axes), which is the
ZeRO-2/3 layout: each device holds only its shard of m/v. ``init`` builds the
states with the same PartitionSpec tree the params use, so under jit the
states never materialize replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_state(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=z, v=jax.tree.map(jnp.copy, z))


def abstract_state(params) -> AdamWState:
    """ShapeDtypeStruct mirror for the dry-run."""
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
