"""Cycle model of the CARMEN PE array (paper §II, Tables 2/3/5).

The array is ``n_pes`` weight-stationary iterative CORDIC PEs, each mapped
to one output channel of the current dot, plus a time-multiplexed AF block
and a weight-stream port. All costs are in PE clock cycles; wall-clock is
``cycles * sec_per_cycle`` once calibrated.

Per-MAC latency: one CORDIC iteration is one cycle, so a K-length dot at
depth d costs ``K * (mac_overhead + d + 1)`` cycles on one PE —
``mac_overhead=0`` recovers the analytic :func:`repro.core.mac.mac_cycles`
model exactly (test-asserted), and a calibration fit can add fractional
pipeline overhead per MAC.

A full dot pass (K, N) for P positions schedules in output-channel *waves*
of ``n_pes`` lanes. Per wave, three resources can bound the cycle count:

* **compute** — ``K * (mac_overhead + depth + 1) * positions`` per lane
  (lanes run in parallel; a partial last wave still pays full compute time).
* **weight stream** — a wave's lanes need ``K * lanes * bits`` weight bits;
  at ``weight_bits_per_cycle`` port bandwidth the wave cannot finish faster
  than the stream. FXP16 points stream twice the bits of FXP8 — the format
  half of the paper's precision/throughput trade.
* **AF block** — ``n * positions`` outputs share ``af_blocks`` AF units at
  ``af_iter_cycles * (depth + 1)`` each (the AF block is CORDIC-iterative
  too, so its cost rides the same depth ladder as the MACs — which is what
  keeps per-point cost *ratios*, and hence savings fractions, faithful under
  calibration). AF work hides under the MAC shadow of the whole pass; only
  the excess stalls.

``parallel_overhead_exp`` models imperfect lane scaling (Table 5's measured
time exponent): total cycles scale by ``n_pes ** exp``, so a 64- vs 256-PE
simulation reproduces the measured exponent by construction (0 = ideal).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

__all__ = ["ArrayConfig", "CostBreakdown", "dot_pass_cost"]


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """One simulated CARMEN array. Defaults are the paper's ideal 256-PE
    array with analytic constants; :meth:`from_calibration` loads fitted
    ones."""

    n_pes: int = 256
    # -- MAC stage ----------------------------------------------------------
    # extra cycles per MAC beyond the depth+1 CORDIC pipeline (fitted;
    # 0 = the analytic model)
    mac_overhead: float = 0.0
    # -- AF block -----------------------------------------------------------
    af_blocks: int = 32  # AF units time-multiplexed over the PE columns
    # the AF block is CORDIC-iterative like the PEs: one evaluation costs
    # af_iter_cycles * (depth + 1). Fitted as cycles-per-AF-iteration so AF
    # cost stays proportional to depth (what keeps per-point cost ratios —
    # and therefore savings fractions — faithful to the depth ladder).
    af_iter_cycles: float = 1.0
    # fixed override: cycles one AF evaluation takes regardless of depth
    # (diagnostic / stress configs; None = the iterative model above)
    af_cycles_per_elem: Optional[float] = None
    # -- weight stream ------------------------------------------------------
    # port bandwidth; default streams one 8-bit weight per PE per cycle, so
    # the stream never stalls FXP8 compute on the ideal array
    weight_bits_per_cycle: Optional[float] = None
    # -- scaling / host -----------------------------------------------------
    # measured parallel-efficiency exponent: cycles *= n_pes ** exp
    parallel_overhead_exp: float = 0.0
    # cycles the array sits idle per host round-trip (dispatch + transfer) —
    # what makes burst=1 serving predictably slower than burst=8
    host_sync_cycles: float = 0.0
    # configuration-register write + pipeline drain on a mode switch
    switch_cycles: float = 256.0
    # wall-clock anchor (seconds per cycle), set by calibration
    sec_per_cycle: Optional[float] = None

    def __post_init__(self):
        if self.n_pes <= 0:
            raise ValueError("n_pes must be positive")
        if self.af_blocks <= 0:
            raise ValueError("af_blocks must be positive")

    @property
    def bandwidth(self) -> float:
        if self.weight_bits_per_cycle is not None:
            return self.weight_bits_per_cycle
        return 8.0 * self.n_pes

    def scaled(self, **overrides) -> "ArrayConfig":
        """A copy with fields replaced (e.g. the 64-PE Table 5 variant)."""
        return dataclasses.replace(self, **overrides)

    @classmethod
    def from_calibration(cls, calibration: Optional[Dict], *,
                         n_pes: int = 256, **overrides) -> "ArrayConfig":
        """Build an array from a ``repro.sim.calibrate`` export (``None`` =
        the ideal analytic array)."""
        if calibration is None:
            return cls(n_pes=n_pes, **overrides)
        c = calibration.get("constants", {})
        fields = dict(
            n_pes=n_pes,
            mac_overhead=float(c.get("mac_overhead", 0.0)),
            af_iter_cycles=float(c.get("af_iter_cycles", 1.0)),
            parallel_overhead_exp=float(c.get("parallel_overhead_exp", 0.0)),
            host_sync_cycles=float(c.get("host_sync_cycles", 0.0)),
            sec_per_cycle=c.get("sec_per_cycle"),
        )
        fields.update(overrides)
        return cls(**fields)


@dataclasses.dataclass
class CostBreakdown:
    """Cycle attribution of one scheduled unit of work. ``total`` is the
    bound resource's time; ``weight_stall`` / ``af_stall`` are the cycles by
    which the stream / AF block exceeded the MAC shadow (already included in
    ``total``). ``ideal_macs`` counts MAC iterations (the numerator of PE
    occupancy)."""

    total: float = 0.0
    compute: float = 0.0
    weight_stall: float = 0.0
    af_stall: float = 0.0
    ideal_macs: float = 0.0

    def __add__(self, other: "CostBreakdown") -> "CostBreakdown":
        return CostBreakdown(
            self.total + other.total,
            self.compute + other.compute,
            self.weight_stall + other.weight_stall,
            self.af_stall + other.af_stall,
            self.ideal_macs + other.ideal_macs,
        )

    def scale(self, k: float) -> "CostBreakdown":
        return CostBreakdown(self.total * k, self.compute * k,
                             self.weight_stall * k, self.af_stall * k,
                             self.ideal_macs * k)


def dot_pass_cost(cfg: ArrayConfig, k: int, n: int, depth: int, *,
                  positions: int = 1, bits: int = 8,
                  reps: int = 1) -> CostBreakdown:
    """Cycles to push ``positions`` activation rows through a (K, N) dot at
    ``depth`` on ``cfg``, repeated ``reps`` times (stacked/scanned layers).

    On the ideal config with one PE and one lane this is exactly
    ``mac_cycles(k, depth) * positions`` — the analytic model the rest of
    the repo charges; everything else (waves, stalls, overheads) refines it.
    """
    if k <= 0 or n <= 0 or positions <= 0:
        return CostBreakdown()
    per_mac = cfg.mac_overhead + depth + 1
    full, rem = divmod(n, cfg.n_pes)
    compute = weight_stall = total = 0.0
    for lanes, waves in ((cfg.n_pes, full), (rem, 1 if rem else 0)):
        if waves == 0:
            continue
        wave_compute = k * per_mac * positions
        wave_stream = k * lanes * bits / cfg.bandwidth
        compute += wave_compute * waves
        weight_stall += max(0.0, wave_stream - wave_compute) * waves
        total += max(wave_compute, wave_stream) * waves
    # AF: n*positions outputs share af_blocks units; excess over the pass's
    # MAC shadow stalls the array
    af_c = cfg.af_cycles_per_elem if cfg.af_cycles_per_elem is not None \
        else cfg.af_iter_cycles * (depth + 1)
    af_serial = math.ceil(n * positions / cfg.af_blocks) * af_c
    af_stall = max(0.0, af_serial - compute)
    total += af_stall
    penalty = cfg.n_pes ** cfg.parallel_overhead_exp
    return CostBreakdown(
        total=total * penalty * reps,
        compute=compute * penalty * reps,
        weight_stall=weight_stall * penalty * reps,
        af_stall=af_stall * penalty * reps,
        ideal_macs=float(k) * n * positions * (depth + 1) * reps,
    )
