"""ServingObserver: the engine-facing bundle of metrics + trace hooks.

One observer instruments one :class:`~repro.serve.engine.BatchedServer`.
Every hook runs host-side at a synchronization point the serving loop
already pays for (burst boundary, prefill return, speculative-round commit),
so observability is OFF the jitted hot paths by construction: token streams
are bit-identical with an observer attached or not, and the measured
overhead is bounded in CI (``bench_serving --smoke``'s ≤5% tok/s gate).

SLO metrics recorded per request (histograms, p50/p90/p99 in the snapshot):

=================== ========================================================
``queue_wait_s``     submission -> leaving the queue, by slot admission OR
                     by shed (a shed request still waited; excluding sheds
                     would bias p99 optimistically under heavy shedding)
``ttft_s``           submission -> first token (time-to-first-token). Batch
                     ``run()`` submits everything at run entry; the
                     streaming frontend stamps true per-request submit times
``prefill_s``        admission -> prefill return (one jitted call, synced)
``prefill_chunk_s``  one chunk of a chunked streaming prefill (these replace
                     the monolithic ``prefill`` span on the frontend path)
``intertoken_s``     burst-amortized inter-token latency: a burst that lands
                     ``n`` tokens ``dt`` after the request's previous
                     emission observes ``dt/n`` with weight ``n``
``decode_burst_s``   wall time of one decode burst / speculative round
``request_s``        admission -> completion
``tokens_per_request`` / ``request_tok_s``  per-request totals at completion
=================== ========================================================

plus counters (requests, tokens, prefill_tokens, prefill_chunks, bursts,
spec_rounds, decode_steps, host_transfers, controller_switches, compiles,
evicted, cancelled, admission_ticks) and
run-level gauges (``run_wall_s``, ``tok_s``, ``acceptance_rate`` under
speculation). ``observer.trace`` (optional) records the structured event
timeline documented in :mod:`repro.obs.trace`.

An observer is single-run: ``run_begin`` resets everything, and the server's
:meth:`~repro.serve.engine.BatchedServer.snapshot` is the symmetric export.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .metrics import MetricsRegistry
from .trace import TraceRecorder

__all__ = ["ServingObserver"]


@dataclasses.dataclass
class _ReqState:
    submit: float
    prompt_len: int
    max_new: int
    slot: Optional[int] = None
    admit: Optional[float] = None
    first_tok: Optional[float] = None
    last_emit: Optional[float] = None
    tokens: int = 0
    done: Optional[float] = None


class ServingObserver:
    """Metrics + trace hooks for one serving run (see module docstring)."""

    def __init__(self, metrics: bool = True, trace: bool = True,
                 clock=time.perf_counter,
                 trace_sink: Optional[str] = None) -> None:
        self._clock = clock
        self._want_trace = trace
        # trace_sink: a JSONL path the trace is flushed to at run_end even
        # when the run aborted (TraceRecorder's crash-safe sink), so traces
        # from crashed runs stay replayable
        self.trace_sink = trace_sink
        self.metrics = MetricsRegistry() if metrics else None
        self.trace: Optional[TraceRecorder] = None
        self.requests: Dict[int, _ReqState] = {}
        self._span_t0: Dict[str, float] = {}
        self.aborted: Optional[bool] = None

    # -- run lifecycle --------------------------------------------------------

    def run_begin(self, meta: Dict, requests) -> None:
        """Reset and open the run: every request is registered as submitted
        now (the batched ``run()`` contract — the whole list arrives at
        entry), which anchors queue-wait and TTFT."""
        if self.metrics is not None:
            self.metrics.reset()
        self.trace = (TraceRecorder(clock=self._clock, sink=self.trace_sink)
                      if self._want_trace else None)
        self.requests = {}
        self._span_t0 = {}
        self.aborted = None
        if self.trace is not None:
            self.trace.attach("run", meta)
            self.trace.begin("run", track="run", **meta)
        for req in requests:
            self.request_submitted(req.rid, len(req.prompt), req.max_new)

    def request_submitted(self, rid: int, prompt_len: int, max_new: int,
                          wall_ts: Optional[float] = None) -> None:
        """Register one arrival. ``run_begin`` calls this for the whole batch
        (the ``run()`` contract: the list arrives at entry); the streaming
        frontend calls it per submission at scheduler intake, passing
        ``wall_ts`` — the raw clock reading stamped on the submitting thread
        — so queue-wait and TTFT anchor at the true submit time, not at the
        tick that first saw the request."""
        now = self._at(wall_ts)
        self.requests[rid] = _ReqState(
            submit=now, prompt_len=prompt_len, max_new=max_new)
        self._count("requests")
        if self.trace is not None:
            self.trace.instant("request_submitted", track="sched",
                               rid=rid, prompt_len=prompt_len,
                               max_new=max_new)

    def run_end(self, aborted: bool, host_transfers: int,
                telemetry: Optional[List[Dict]] = None) -> None:
        """Close the run: settle open spans, evict unfinished requests, and
        derive the run-level gauges. Always called (``finally``), so an
        aborted run still exports a coherent record."""
        now = self._now()
        self.aborted = aborted
        for rid, st in self.requests.items():
            if st.done is None and st.admit is not None:
                self._count("evicted")
                if self.trace is not None:
                    self.trace.instant("request_evicted", track=_slot_track(st),
                                       rid=rid, tokens=st.tokens)
        if self.metrics is not None:
            self.metrics.inc("host_transfers", host_transfers)
            wall = max((now - st.submit for st in self.requests.values()),
                       default=0.0)
            self.metrics.set("run_wall_s", wall)
            tokens = self.metrics.counter("tokens").value
            if wall > 0:
                self.metrics.set("tok_s", tokens / wall)
            for rec in telemetry or []:
                if rec.get("kind") == "speculative":
                    self.metrics.set("acceptance_rate",
                                     rec["detail"]["acceptance_rate"])
                self.metrics.set(f"est_cycle_savings_frac_{rec['kind']}",
                                 rec["est_cycle_savings_frac"])
        if self.trace is not None:
            self.trace.close_open()
            self.trace.header["meta"]["aborted"] = aborted
            self.trace.attach("telemetry", telemetry or [])
            if aborted and self.trace.sink is not None:
                # crashed run: the caller's normal export path never runs, so
                # flush the settled trace to the sink now — it stays
                # replayable (satellite of the aborted-run symmetry fix)
                self.trace.flush()

    # -- admission / prefill --------------------------------------------------

    def request_shed(self, rid: int, reason: str) -> None:
        """The request was rejected at admission (never held a slot):
        bounded-queue overflow, oversized/empty prompt, or a deadline that
        expired while queued. ``reason`` is the structured attribution the
        overload gates assert on."""
        st = self.requests.get(rid)
        if st is not None:
            st.done = self._now()
            # a shed request still waited: its time in the queue contributes
            # to the queue_wait histogram (submission -> leaving the queue,
            # by admission OR by shed). Excluding sheds would bias p99
            # optimistically under heavy shedding — exactly the long-waiting
            # requests a deadline sweep rejects would vanish from the tail.
            self._observe("queue_wait_s", st.done - st.submit)
        self._count("shed")
        self._count(f"shed_{reason}")
        if self.trace is not None:
            self.trace.instant("request_shed", track="sched", rid=rid,
                               reason=reason)

    def request_expired(self, rid: int, tokens: int) -> None:
        """An admitted request missed its deadline mid-decode and was
        evicted at the burst boundary with ``tokens`` partial tokens."""
        now = self._now()
        st = self.requests[rid]
        st.done = now
        self._count("expired")
        self._count("deadline_misses")
        if self.trace is not None:
            self.trace.instant("request_expired", track=_slot_track(st),
                               rid=rid, tokens=tokens)
            if st.admit is not None:
                self.trace.end(f"request:{rid}", track=_slot_track(st),
                               rid=rid, tokens=tokens)

    def request_faulted(self, rid: int, tokens: int,
                        reason: Optional[str] = None) -> None:
        """An admitted request produced non-finite/saturated logits and was
        quarantined; ``tokens`` clean tokens were committed before the
        fault."""
        now = self._now()
        st = self.requests[rid]
        st.done = now
        self._count("faulted")
        if self.trace is not None:
            self.trace.instant("request_faulted", track=_slot_track(st),
                               rid=rid, tokens=tokens, reason=reason)
            if st.admit is not None:
                self.trace.end(f"request:{rid}", track=_slot_track(st),
                               rid=rid, tokens=tokens)

    def request_admitted(self, rid: int, slot: int) -> None:
        st = self.requests[rid]
        st.slot, st.admit = slot, self._now()
        self._observe("queue_wait_s", st.admit - st.submit)
        if self.trace is not None:
            self.trace.instant("request_admitted", track="sched", rid=rid,
                               slot=slot)
            self.trace.begin(f"request:{rid}", track=_slot_track(st), rid=rid,
                             prompt_len=st.prompt_len, max_new=st.max_new)

    def prefill_begin(self, rid: int, bucket: int, point: Optional[str]) -> None:
        self._span_t0["prefill"] = self._now()
        if self.trace is not None:
            self.trace.begin("prefill", track="engine", rid=rid, bucket=bucket,
                             point=point)

    def prefill_end(self, rid: int, prompt_len: int,
                    point: Optional[str]) -> None:
        now = self._now()
        self._observe("prefill_s", now - self._span_t0.pop("prefill", now))
        if self.trace is not None:
            self.trace.end("prefill", track="engine", rid=rid)
        self._prefilled(rid, prompt_len, point, now)

    def _prefilled(self, rid: int, prompt_len: int, point: Optional[str],
                   now: float) -> None:
        """Shared prefill-completion accounting: first token committed."""
        st = self.requests[rid]
        st.first_tok = st.last_emit = now
        st.tokens = 1
        self._observe("ttft_s", now - st.submit)
        self._count("prefill_tokens", prompt_len)
        self._count("tokens")
        if self.trace is not None:
            self.trace.instant("request_prefilled", track=_slot_track(st),
                               rid=rid, prompt_len=prompt_len, point=point)

    def prefill_chunk_begin(self, rid: int, start: int, n: int, bucket: int,
                            point: Optional[str]) -> None:
        """One chunk of a chunked (streaming-frontend) prefill: ``n`` prompt
        rows from offset ``start``, padded to ``bucket``. Chunks appear
        instead of the monolithic ``prefill`` span for chunk-prefilled
        requests; the final chunk's end also fires the ``request_prefilled``
        accounting via :meth:`prefill_chunk_end`."""
        self._span_t0["prefill_chunk"] = self._now()
        if self.trace is not None:
            self.trace.begin("prefill_chunk", track="engine", rid=rid,
                             start=start, n=n, bucket=bucket, point=point)

    def prefill_chunk_end(self, rid: int, final: bool,
                          prompt_len: Optional[int] = None,
                          point: Optional[str] = None) -> None:
        now = self._now()
        self._observe("prefill_chunk_s",
                      now - self._span_t0.pop("prefill_chunk", now))
        self._count("prefill_chunks")
        if self.trace is not None:
            self.trace.end("prefill_chunk", track="engine", rid=rid,
                           final=final)
        if final:
            self._prefilled(rid, prompt_len, point, now)

    def admission_tick(self, queued: int, active: int, free: int) -> None:
        """One streaming-frontend scheduler tick (admission + shed sweeps +
        at most one chunk budget of prefill + one burst)."""
        self._count("admission_ticks")
        if self.trace is not None:
            self.trace.instant("admission_tick", track="sched", queued=queued,
                               active=active, free=free)

    def request_cancelled(self, rid: int, tokens: int) -> None:
        """The client cancelled / disconnected: the request leaves at the
        next tick boundary with ``tokens`` partial tokens (0 if it was still
        queued or mid-prefill)."""
        st = self.requests.get(rid)
        self._count("cancelled")
        if st is None:
            return
        st.done = self._now()
        if self.trace is not None:
            self.trace.instant("request_cancelled", track=_slot_track(st),
                               rid=rid, tokens=tokens)
            if st.admit is not None:
                self.trace.end(f"request:{rid}", track=_slot_track(st),
                               rid=rid, tokens=tokens)

    def compile_event(self, what: str, **args) -> None:
        """A new XLA program is about to be built (first visit to a prefill
        bucket / burst variant) — the next span's wall time includes it."""
        self._count("compiles")
        if self.trace is not None:
            self.trace.instant("compile", track="engine", what=what, **args)

    # -- decode bursts / speculative rounds -----------------------------------

    def burst_begin(self, point: Optional[str], kind: str = "burst") -> None:
        self._span_t0[kind] = self._now()
        if self.trace is not None:
            self.trace.begin(kind, track="engine", point=point)

    def burst_end(self, point: Optional[str], steps: int,
                  emitted: Dict[int, List[int]], kind: str = "burst",
                  **extra) -> None:
        """Commit of one burst / speculative round: ``emitted`` maps rid ->
        tokens landed this round (the single host transfer's payload)."""
        now = self._now()
        wall = now - self._span_t0.pop(kind, now)
        total = sum(len(t) for t in emitted.values())
        self._observe("decode_burst_s", wall)
        self._count("bursts" if kind == "burst" else "spec_rounds")
        self._count("decode_steps", steps)
        self._count("tokens", total)
        for rid, toks in emitted.items():
            st = self.requests[rid]
            if toks and st.last_emit is not None:
                self._observe("intertoken_s", (now - st.last_emit) / len(toks),
                              n=len(toks))
            if toks:
                st.last_emit = now
                st.tokens += len(toks)
                if self.trace is not None:
                    self.trace.instant("tokens", track=_slot_track(st),
                                       rid=rid, n=len(toks))
        if self.trace is not None:
            self.trace.end(kind, track="engine", point=point, steps=steps,
                           tokens=total, **extra)

    def spec_stage_begin(self, stage: str, point: str) -> None:
        """Draft/verify dispatch inside a speculative round (dispatch-only
        span: the round synchronizes once, at its commit)."""
        if self.trace is not None:
            self.trace.begin(f"spec_{stage}", track="engine", point=point)

    def spec_stage_end(self, stage: str, point: str) -> None:
        if self.trace is not None:
            self.trace.end(f"spec_{stage}", track="engine", point=point)

    def spec_commit(self, accepted) -> None:
        """Accepted-draft counts per slot, after the round's host transfer
        (the rollback already happened on device)."""
        if self.trace is not None:
            self.trace.instant("spec_rollback", track="engine",
                               accepted=[int(a) for a in accepted])

    # -- controller -----------------------------------------------------------

    def controller_switch(self, old: str, new: str, signals) -> None:
        self._count("controller_switches")
        if self.trace is not None:
            args = dataclasses.asdict(signals) if dataclasses.is_dataclass(
                signals) else dict(signals or {})
            self.trace.instant("controller_switch", track="engine",
                               old=old, new=new, signals=args)

    # -- completion -----------------------------------------------------------

    def request_completed(self, rid: int) -> None:
        now = self._now()
        st = self.requests[rid]
        st.done = now
        if st.admit is not None:
            wall = now - st.admit
            self._observe("request_s", wall)
            if wall > 0:
                self._observe("request_tok_s", st.tokens / wall)
        self._observe("tokens_per_request", st.tokens)
        if self.trace is not None:
            self.trace.instant("request_completed", track="sched", rid=rid,
                               tokens=st.tokens)
            self.trace.end(f"request:{rid}", track=_slot_track(st), rid=rid,
                           tokens=st.tokens)

    # -- export ---------------------------------------------------------------

    def snapshot(self) -> Dict:
        """JSON-able export of the current run's metrics + per-request rows
        (the trace exports itself: ``observer.trace.write_jsonl`` /
        ``to_chrome``)."""
        reqs = {}
        for rid, st in self.requests.items():
            reqs[rid] = {
                "prompt_len": st.prompt_len,
                "max_new": st.max_new,
                "slot": st.slot,
                "tokens": st.tokens,
                "queue_wait_s": _delta(st.submit, st.admit),
                "ttft_s": _delta(st.submit, st.first_tok),
                "request_s": _delta(st.admit, st.done),
                "completed": st.done is not None,
            }
        return {
            "aborted": self.aborted,
            "metrics": self.metrics.snapshot() if self.metrics else None,
            "requests": reqs,
        }

    # -- internals ------------------------------------------------------------

    def _now(self) -> float:
        return self.trace.now() if self.trace is not None else (
            self._clock())

    def _at(self, wall_ts: Optional[float]) -> float:
        """Map a raw clock reading onto the observer's time base (trace time
        when a trace is attached); ``None`` means "now"."""
        if wall_ts is None:
            return self._now()
        return self.trace.at(wall_ts) if self.trace is not None else wall_ts

    def _observe(self, name: str, v: float, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.observe(name, v, n)

    def _count(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.inc(name, n)


def _slot_track(st: _ReqState) -> str:
    return f"slot{st.slot}" if st.slot is not None else "sched"


def _delta(a: Optional[float], b: Optional[float]) -> Optional[float]:
    return None if a is None or b is None else b - a
