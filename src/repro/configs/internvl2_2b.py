"""internvl2-2b [arXiv:2404.16821; hf] — InternLM2-1.8B backbone + ViT stub.

Per the assignment spec the modality frontend is a STUB: ``input_specs()``
supplies precomputed patch embeddings (frontend_tokens x d_model) which are
prepended to the text-token embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=256,
)
