"""Multi-point weight banks: every execution mode prepared in one pass.

An :class:`ExecutionPoint` names a whole-model precision policy (the paper's
"approximate" / "accurate" configuration-register settings, generalized to a
ladder). :func:`build_bank` runs ``prepare_params`` once per point through a
SHARED memo, so any layer whose per-layer (format, depth) agrees between two
points — criticality-pinned layers, scan-promoted layers — is materialized
exactly once and aliased into every tree. The serving loop then switches
execution points by handing a different (already-resident) tree to the same
jitted decode step: zero weight-side work per switch, the software analogue
of switching modes "without hardware modification".

Kernel-mode banks additionally share one *treedef* across points: the per-point
dot parameters (CORDIC depth, quantization formats) travel as a traced int32
params vector on each :class:`PreparedWeight` (``point`` child) rather than as
static pytree aux data, so a mode switch also costs zero retraces/recompiles of
the jitted burst/draft/verify programs — one compiled program serves every
point (compile-count asserted in ``tests/test_cordic_fused.py``). carmen/int8
points still carry static meta and re-specialize per point.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

from repro.core.backends import PreparedWeight, prepare_params
from repro.core.fxp import FXP8, FXP16, FxPFormat
from repro.core.precision_policy import PrecisionPolicy, pin_critical

from .telemetry import calibration_id, estimate_point_cycles

__all__ = ["ExecutionPoint", "MultiPointBank", "build_bank", "default_points",
           "place_bank"]


@dataclasses.dataclass(frozen=True)
class ExecutionPoint:
    """One runtime-selectable mode: a name plus the policy it executes."""

    name: str
    policy: PrecisionPolicy


def default_points(
    fmt: FxPFormat = FXP8,
    *,
    base_policy: Optional[PrecisionPolicy] = None,
    hifi_fmt: Optional[FxPFormat] = FXP16,
) -> Tuple[ExecutionPoint, ...]:
    """The canonical mode ladder: {approx fmt, full fmt, full hifi_fmt}.

    When ``base_policy`` carries per-layer overrides (a §III sensitivity-scan
    assignment), it becomes the cheapest point — the scan already encodes
    which layers tolerate demotion. Otherwise the cheapest point is uniform
    approximate depth with the critical-layer floor pinned.

    The ``hifi_fmt`` point is meaningful for the carmen/kernel backends
    (wider signed-digit grid + activation format). For int8 the effective
    bits cap at 8 either way — pass ``hifi_fmt=None`` there, or the ladder
    gains a point that costs 1.75x cycles for identical arithmetic.
    """
    if base_policy is not None and base_policy.overrides:
        cheap = ExecutionPoint("mixed", pin_critical(base_policy))
    else:
        cheap = ExecutionPoint("approx", pin_critical(PrecisionPolicy.approximate(fmt)))
    points = [cheap, ExecutionPoint("accurate", PrecisionPolicy.accurate(fmt))]
    if hifi_fmt is not None and hifi_fmt != fmt:
        points.append(ExecutionPoint("hifi", PrecisionPolicy.accurate(hifi_fmt)))
    return tuple(points)


@dataclasses.dataclass
class MultiPointBank:
    """Prepared trees for every execution point, cheapest first.

    ``cycles_per_token`` is the estimated engine MAC cycles one decoded token
    costs at each point (iterative-PE model, see ``runtime.telemetry``);
    ``reference`` names the all-accurate baseline that savings are quoted
    against, and ``cycle_model`` names the calibration (or ``"analytic"``)
    those cycles were computed with. ``shared_leaves`` counts prepared leaves
    aliased between at least two points (the zero-copy pinning guarantee,
    test-asserted).
    """

    mode: str
    points: Tuple[ExecutionPoint, ...]
    trees: Dict[str, Any]
    cycles_per_token: Dict[str, float]
    reference: str
    shared_leaves: int = 0
    unique_leaves: int = 0
    cycle_model: str = "analytic"

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.points)

    def tree(self, name: str):
        return self.trees[name]

    def index(self, name: str) -> int:
        return self.names.index(name)

    def rel_cycles(self, name: str) -> float:
        """Cycle cost of ``name`` relative to the all-accurate reference."""
        return self.cycles_per_token[name] / self.cycles_per_token[self.reference]


def _leaf_ids(tree) -> set:
    return {
        id(l)
        for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, PreparedWeight))
        if isinstance(l, PreparedWeight)
    }


def place_bank(bank: MultiPointBank, mesh, specs=None) -> MultiPointBank:
    """Place every bank tree on ``mesh`` with the logical-axis shardings.

    Leaves are placed ONCE per tensor identity and re-aliased into every
    point's tree — pinned/agreeing layers stay single-copy on device, the
    same zero-copy guarantee ``build_bank``'s shared memo gives on the host.
    Mutates ``bank.trees`` in place (controllers and speculative decoders
    hold references to the bank), returns the bank. Idempotent: re-placing an
    already-placed bank is a no-op device_put.
    """
    from repro.sharding.partition import prepared_shardings

    if specs is None:
        raise ValueError("place_bank needs the model's param specs "
                         "(model.specs()) to derive shardings")
    is_pw = lambda x: isinstance(x, PreparedWeight)
    placed: Dict[int, Any] = {}
    for name in bank.names:
        tree = bank.trees[name]
        sh = prepared_shardings(tree, specs, mesh)

        def put(leaf, sharding):
            key = id(leaf)
            if key not in placed:
                placed[key] = jax.device_put(leaf, sharding)
            return placed[key]

        bank.trees[name] = jax.tree.map(put, tree, sh, is_leaf=is_pw)
    return bank


def build_bank(
    params,
    mode: str,
    points: Optional[Sequence[ExecutionPoint]] = None,
    *,
    specs=None,
    reference: Optional[str] = None,
    mesh=None,
    calibration: Optional[Dict] = None,
) -> MultiPointBank:
    """Materialize the multi-point weight bank (one prepare pass, shared memo).

    Points are re-ordered cheapest -> most expensive by estimated MAC cycles,
    so the controller's demote/promote directions are well-defined. The
    ``reference`` point (default: ``"accurate"`` when present, else the most
    expensive point) anchors relative-cycle and savings reporting.

    ``mesh`` places every prepared tree with the logical-axis shardings
    (:func:`place_bank`) — sharded serving hands the jitted decode step
    device-resident tensor-parallel trees, still zero weight-side work per
    switch.

    ``calibration`` (a ``repro.sim.calibrate`` export) refines the per-point
    cycle estimates, so the ModeController's budget and the PE-array
    simulator optimize the same cost; ``bank.cycle_model`` records which
    model produced the estimates.
    """
    if mode == "exact":
        raise ValueError(
            "adaptive banks need a depth-configurable backend "
            "(carmen | int8 | kernel); 'exact' has no precision knob"
        )
    points = tuple(points if points is not None else default_points())
    if len(points) < 2:
        raise ValueError("a multi-point bank needs at least two execution points")
    if len({p.name for p in points}) != len(points):
        raise ValueError("execution point names must be unique")

    cycles = {
        p.name: estimate_point_cycles(params, p.policy, specs=specs,
                                      calibration=calibration)
        for p in points
    }
    points = tuple(sorted(points, key=lambda p: cycles[p.name]))
    if reference is None:
        reference = "accurate" if "accurate" in cycles else points[-1].name
    if reference not in cycles:
        raise ValueError(f"reference point {reference!r} not in {sorted(cycles)}")

    memo: Dict = {}
    trees = {
        p.name: prepare_params(params, p.policy, mode, specs=specs, memo=memo)
        for p in points
    }

    id_sets = [_leaf_ids(t) for t in trees.values()]
    all_ids = set().union(*id_sets)
    shared = {i for i in all_ids if sum(i in s for s in id_sets) >= 2}
    bank = MultiPointBank(
        mode=mode,
        points=points,
        trees=trees,
        cycles_per_token=cycles,
        reference=reference,
        shared_leaves=len(shared),
        unique_leaves=len(all_ids),
        cycle_model=calibration_id(calibration),
    )
    if mesh is not None:
        place_bank(bank, mesh, specs)
    return bank
