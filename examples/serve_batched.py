"""Continuous-batching inference under the CARMEN quantized engine.

Default run serves a batch of requests through the decode engine three times
— exact (FP32 baseline), carmen (paper-faithful FxP16), int8 (TPU production
path) — and reports tokens/s plus generation agreement vs the baseline: the
end-to-end incarnation of the paper's <2% accuracy-loss claim.

``--adaptive`` instead demonstrates the runtime-adaptive precision subsystem
(``repro.runtime``) on a mixed workload: a multi-point weight bank (approx /
accurate execution points prepared once, pinned layers shared) and a mode
controller that switches the execution point per decode step from live
telemetry — queue pressure while the request backlog exceeds the slot count,
logit-margin confidence, and a MAC-cycle budget. Prints mode occupancy,
switch count, estimated cycle savings vs all-accurate serving, and greedy
token agreement on high-confidence tokens (teacher-forced, so one flipped
token does not cascade into the metric).

Run:  PYTHONPATH=src python examples/serve_batched.py [--adaptive]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP8, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request


def compare_modes(cfg, model, params, requests, *, burst=8):
    results = {}
    for mode, ctx in (
        ("exact", EngineContext(mode="exact", compute_dtype=jnp.float32)),
        ("carmen-fxp16", EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                                       compute_dtype=jnp.float32)),
        ("int8", EngineContext(mode="int8", policy=PrecisionPolicy.accurate(FXP8),
                               compute_dtype=jnp.float32)),
    ):
        server = BatchedServer(model, ctx, params, slots=3, max_len=32, burst=burst)
        t0 = time.time()
        out = server.run([Request(r.rid, r.prompt, r.max_new) for r in requests])
        dt = time.time() - t0
        toks = sum(len(v) for v in out.values())
        results[mode] = out
        print(f"{mode:13s}: {toks} tokens in {dt:5.1f}s ({toks/dt:6.1f} tok/s, "
              f"{server.host_transfers} host round-trips)")

    base = results["exact"]
    for mode in ("carmen-fxp16", "int8"):
        agree = np.mean([
            np.mean(np.array(results[mode][rid]) == np.array(base[rid])) for rid in base
        ])
        print(f"token agreement {mode} vs exact: {agree:.1%}")


def adaptive_demo(cfg, model, params, *, slots=3, requests=12, max_new=16,
                  cycle_budget=0.75, burst=8):
    from repro.runtime import (
        ControllerConfig, ModeController, build_bank, default_points,
        teacher_forced_agreement,
    )

    fmt = FXP16  # approx depth 8 vs full depth 13: ~36% fewer MAC cycles
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(fmt),
                        compute_dtype=jnp.float32)

    def mixed_workload():
        rng = np.random.default_rng(1)  # fresh stream: both runs serve the SAME workload
        reqs = []
        for i in range(requests):
            plen = int(rng.integers(4, 9))
            prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
            # a couple of sampled requests ride along (temperature plumbing);
            # greedy requests carry the matched-output comparison
            temp = 0.8 if i % 6 == 5 else 0.0
            reqs.append(Request(i, prompt, max_new, temperature=temp, seed=i))
        return reqs

    bank = build_bank(params, "carmen", default_points(fmt, hifi_fmt=None),
                      specs=model.specs())

    # all-accurate reference run, served from the bank's own accurate tree
    ref_server = BatchedServer(model, ctx, bank.tree("accurate"), slots=slots,
                               max_len=32, burst=burst, prepare_weights=False)
    ref_reqs = mixed_workload()
    t0 = time.time()
    ref_out = ref_server.run(ref_reqs)
    ref_dt = time.time() - t0
    ref_margins = {r.rid: r.margins for r in ref_reqs}

    # adaptive run: multi-point bank + mode controller
    controller = ModeController(bank, ControllerConfig(cycle_budget=cycle_budget))
    adp_server = BatchedServer(model, ctx, params, slots=slots, max_len=32,
                               burst=burst, controller=controller)
    t0 = time.time()
    adp_server.run(mixed_workload())
    adp_dt = time.time() - t0
    tele = adp_server.telemetry.summary()

    gen_tokens = sum(len(v) for v in ref_out.values())
    print(f"bank: points={bank.names}, shared leaves "
          f"{bank.shared_leaves}/{bank.unique_leaves}, rel cycles "
          f"{ {n: round(bank.rel_cycles(n), 3) for n in bank.names} }")
    print(f"all-accurate: {gen_tokens} generated tokens in {ref_dt:.1f}s; "
          f"adaptive: {adp_dt:.1f}s")
    print(f"mode occupancy (token-weighted): {tele['mode_occupancy']}")
    print(f"controller switches: {tele['switches']} "
          f"(queue pressure while backlog > slots, then margin/budget steering)")
    print(f"estimated MAC-cycle savings vs all-accurate: "
          f"{tele['est_cycle_savings_frac']:.1%}")

    greedy = [r for r in ref_reqs if r.temperature <= 0.0]
    overall, hi, thr, n_hi = teacher_forced_agreement(
        model, ctx, bank.tree(bank.names[0]), greedy, ref_out, ref_margins
    )
    print(f"approx-point greedy agreement: {overall:.1%} overall, "
          f"{hi:.1%} on {n_hi} high-confidence tokens (margin >= {thr:.2f})")
    assert tele["switches"] >= 1, "controller never switched modes"
    assert tele["est_cycle_savings_frac"] >= 0.25, "savings below the 25% bar"
    return tele


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--adaptive", action="store_true",
                    help="runtime-adaptive precision demo (bank + controller)")
    ap.add_argument("--arch", default=None,
                    help="default: olmo-1b (adaptive) / qwen3-8b (mode comparison)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cycle-budget", type=float, default=0.75)
    ap.add_argument("--burst", type=int, default=8,
                    help="decode burst length (1 = per-token loop)")
    args = ap.parse_args(argv)

    arch = args.arch or ("olmo-1b" if args.adaptive else "qwen3-8b")
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.adaptive:
        adaptive_demo(cfg, model, params, slots=args.slots,
                      requests=args.requests, max_new=args.max_new,
                      cycle_budget=args.cycle_budget, burst=args.burst)
    else:
        rng = np.random.default_rng(1)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 12)
            for i in range(6)
        ]
        compare_modes(cfg, model, params, reqs, burst=args.burst)


if __name__ == "__main__":
    main()
