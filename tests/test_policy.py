"""Accuracy-sensitivity metric and depth assignment (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FXP8,
    PrecisionPolicy,
    approx_depth,
    assign_depths,
    full_depth,
    sensitivity_scan,
)


def _toy_apply(params, batch, noise):
    """Two-layer MLP with noise-injection taps after each layer."""
    h = batch @ params["w1"]
    h = h + noise.get("l1", 0.0) * jnp.ones_like(h)
    h = jnp.tanh(h)
    out = h @ params["w2"]
    out = out + noise.get("l2", 0.0) * jnp.ones_like(out)
    return out


def test_sensitivity_orders_layers(rng):
    # w2 large ==> perturbations at l1 are amplified; l2 taps the output directly.
    params = {
        "w1": rng.standard_normal((8, 16)).astype(np.float32) * 0.1,
        "w2": rng.standard_normal((16, 4)).astype(np.float32) * 10.0,
    }
    batch = rng.standard_normal((32, 8)).astype(np.float32)
    sens = sensitivity_scan(_toy_apply, params, batch, ["l1", "l2"], fmt=FXP8)
    assert sens["l1"] > sens["l2"] > 0


def test_assign_depths_meets_budget_and_pins_critical():
    sens = {"mlp.0": 0.01, "mlp.1": 0.02, "attn.router": 0.001, "head": 0.5}
    pol = assign_depths(sens, fmt=FXP8, cycle_reduction_target=0.20)
    # router never demoted despite lowest sensitivity
    assert pol.for_layer("attn.router").depth == full_depth(FXP8)
    # least-sensitive non-critical layers demoted first
    assert pol.for_layer("mlp.0").depth == approx_depth(FXP8)
    # most-sensitive stays accurate
    assert pol.for_layer("head").depth == full_depth(FXP8)


def test_policy_uniform_and_modes():
    acc = PrecisionPolicy.accurate(FXP8).default
    app = PrecisionPolicy.approximate(FXP8).default
    assert acc.mode == "accurate" and app.mode == "approximate"
    assert app.depth < acc.depth
