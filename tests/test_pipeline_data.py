"""Data pipeline: determinism, host sharding, restart/skip-ahead semantics."""
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import ClusterPipeline, TokenPipeline, input_specs
from repro.configs.base import SHAPES


def _pipe():
    cfg = reduced(get_config("olmo-1b"))
    return TokenPipeline(cfg, seq_len=32, global_batch=8), cfg


def test_deterministic_per_step():
    p, _ = _pipe()
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = p.batch(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_restart_skip_ahead_is_free():
    """A restarted worker replays exactly — batch(k) needs no history."""
    p, _ = _pipe()
    seq1 = [np.asarray(p.batch(s)["tokens"]) for s in range(4)]
    fresh, _ = _pipe()
    np.testing.assert_array_equal(seq1[3], np.asarray(fresh.batch(3)["tokens"]))


def test_host_sharding_partitions_batch():
    p, _ = _pipe()
    h0 = np.asarray(p.batch(0, host_index=0, host_count=2)["tokens"])
    h1 = np.asarray(p.batch(0, host_index=1, host_count=2)["tokens"])
    assert h0.shape[0] == 4 and h1.shape[0] == 4
    assert not np.array_equal(h0, h1)  # different shards


def test_targets_are_next_tokens():
    p, _ = _pipe()
    b = p.batch(0)
    # targets/tokens come from one (seq+1)-length stream
    assert b["tokens"].shape == b["targets"].shape


def test_vocab_bounds():
    p, cfg = _pipe()
    t = np.asarray(p.batch(0)["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab_size


def test_cluster_pipeline_fxp_range():
    x, y = ClusterPipeline().dataset(100)
    assert np.abs(x).max() < 2.0  # FxP8 Q1.6-representable
    assert x.shape == (100, 196) and set(np.unique(y)) <= set(range(10))


def test_input_specs_cover_all_kinds():
    cfg = get_config("internvl2-2b")
    for name in ("train_4k", "prefill_32k", "decode_32k"):
        spec = input_specs(cfg, SHAPES[name])
        assert "tokens" in spec
        if name != "decode_32k":
            assert "frontend_embeds" in spec
    audio = get_config("seamless-m4t-large-v2")
    spec = input_specs(audio, SHAPES["train_4k"])
    assert spec["frontend_embeds"].shape[1] == 4096
