"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*; unverified] — interleaved
MoE (128 routed top-1 + 1 shared expert every other layer, dense 16384 between).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=5e5,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
        first_dense_layers=0,
        moe_every=2,
        d_ff_dense=16384,
    ),
)
