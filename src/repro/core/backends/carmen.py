"""carmen backend: paper-faithful CORDIC simulation over the FxP substrate.

Per-call path (QAT / training): activations fake-quantized to the FxP format,
weights rounded to the depth-d signed-digit grid by a traced masked loop
(= linear-CORDIC multiplier), single real matmul, straight-through gradients.

Prepared path (serving): the signed-digit grid is materialized once by
``prepare`` at the policy depth — the forward then only fake-quantizes
activations and runs the matmul, exactly like the silicon engine whose weight
bank is written once. Bit-identical to the per-call forward (the traced and
static rounders agree digit-for-digit; see tests/test_backends.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import cordic
from ..fxp import FXP8, FxPFormat, dequantize, quantize
from .base import Backend, PreparedWeight, unit_fmt

__all__ = ["CarmenBackend", "carmen_dot", "sd_round_traced"]


def sd_round_traced(w, depth, w_fmt: FxPFormat):
    """signed_digit_round with a (possibly traced) depth: full-trip masked loop.

    Runtime-adaptive mode switching: the loop bound is static (full depth) but
    iterations beyond ``depth`` are masked out, so one compiled program serves
    every depth — the software analogue of the paper's "no hardware
    modification" claim.
    """
    z = jnp.round(jnp.asarray(w, jnp.float32) * (1 << w_fmt.frac)).astype(jnp.int32)
    z = jnp.clip(z, w_fmt.qmin, w_fmt.qmax)
    depth = jnp.asarray(depth, jnp.int32)
    full = cordic.full_depth(w_fmt)

    def body(k, carry):
        z, acc = carry
        active = k < depth
        d = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        step = jnp.where(active, (jnp.int32(w_fmt.one) >> k) * d, 0)
        return (z - step, acc + step)

    _, acc = jax.lax.fori_loop(0, full, body, (z, jnp.zeros_like(z)))
    return acc.astype(jnp.float32) * np.float32(w_fmt.scale)


def quantize_activations(x, x_fmt: FxPFormat):
    """Fake-quantize activations into the FxP grid (float32 values out).

    Identity on non-finite inputs: the float->int32 grid cast would otherwise
    launder a NaN/Inf (e.g. from a poisoned KV row) into a plausible finite
    value — silent data corruption that the serving fault flag
    (``serve.engine.make_decode_burst``) could never see at the logits. Real
    FxP silicon cannot hold a NaN either, but there the symptom is a
    saturated accumulator (the ``logit_limit`` probe); the float simulation
    keeps the poison explicit instead. Finite values are untouched, so clean
    streams stay bit-identical.
    """
    xf = jnp.asarray(x, jnp.float32)
    q = dequantize(quantize(xf, x_fmt), x_fmt).astype(jnp.float32)
    return jnp.where(jnp.isfinite(xf), q, xf)


# --- fake-quant forward, straight-through backward ---------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _carmen_matmul_ste(x, w, depth, x_fmt: FxPFormat, w_fmt: FxPFormat):
    xq = quantize_activations(x, x_fmt)
    wq = sd_round_traced(w, depth, w_fmt)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _carmen_fwd(x, w, depth, x_fmt, w_fmt):
    return _carmen_matmul_ste(x, w, depth, x_fmt, w_fmt), (x, w)


def _carmen_bwd(x_fmt, w_fmt, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    dx = jnp.dot(gf, w.astype(jnp.float32).T).astype(x.dtype)
    dw = jnp.dot(x.astype(jnp.float32).reshape(-1, x.shape[-1]).T,
                 gf.reshape(-1, g.shape[-1])).astype(w.dtype)
    return dx, dw, None


_carmen_matmul_ste.defvjp(_carmen_fwd, _carmen_bwd)


def carmen_dot(x, w, depth, x_fmt: FxPFormat = FXP8, w_fmt: Optional[FxPFormat] = None):
    """Functional form of the carmen-mode matmul (used by benchmarks/tests)."""
    return _carmen_matmul_ste(x, w, depth, x_fmt, w_fmt or unit_fmt(x_fmt))


class CarmenBackend(Backend):
    name = "carmen"

    def prepare(self, w, lp, *, stacked_axes: int = 0, in_axes=None):
        fmt = unit_fmt(lp.fmt)
        data = cordic.signed_digit_round(w, int(lp.depth), fmt)
        # x_fmt makes the bank self-describing: the prepared dot quantizes
        # activations at the preparation point's format, so runtime mode
        # switching (multi-point banks, repro.runtime) never consults ctx.policy
        return PreparedWeight(
            data, None, self.name,
            (("depth", int(lp.depth)), ("fmt", (fmt.bits, fmt.frac)),
             ("x_fmt", (lp.fmt.bits, lp.fmt.frac))),
        )

    def dot(self, ctx, x, w, *, name: str = ""):
        shape = x.shape[:-1] + (w.shape[-1],)
        x2 = x.reshape(-1, x.shape[-1])
        if isinstance(w, PreparedWeight):
            x_fmt = w.get("x_fmt")
            x_fmt = (
                FxPFormat(*x_fmt) if x_fmt else ctx.layer_precision(name).fmt
            )
            xq = quantize_activations(x2, x_fmt)
            out = jnp.dot(xq, w.data, preferred_element_type=jnp.float32)
        else:
            lp = ctx.layer_precision(name)
            out = _carmen_matmul_ste(x2, w, lp.depth, lp.fmt, unit_fmt(lp.fmt))
        return out.reshape(shape).astype(ctx.compute_dtype)
