"""Jitted draft / verify steps and the acceptance rule.

Distributions are *temperature-adjusted targets*: ``temp<=0`` slots use the
one-hot argmax (so acceptance degenerates to greedy exact-match and the
emitted stream is bit-identical to accurate-only decoding), ``temp>0`` slots
use ``softmax(logits/temp)`` with the standard speculative-sampling
correction, which preserves the accurate point's output distribution exactly.

PRNG discipline: every slot owns a base key (the server's per-request
stream); each round folds in the round counter, then separate lanes for draft
sampling (0), acceptance uniforms (1), and the correction/bonus sample (2),
with token-index folds inside a lane. A rejected position re-drafted next
round therefore sees fresh randomness — reusing the same uniform across
rounds would bias re-drafts toward re-rejection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.core import EngineContext
from repro.serve.engine import top2_margin

_DRAFT_LANE, _ACCEPT_LANE, _CORRECT_LANE = 0, 1, 2


def _round_keys(base_keys, round_idx):
    """(B, 2) per-request keys -> per-round keys (fresh randomness per round)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, round_idx))(base_keys)


def _lane(keys, lane):
    return jax.vmap(lambda k: jax.random.fold_in(k, lane))(keys)


def _temp_dist(logits, temps):
    """logits (B, V) f32 + temps (B,) -> target/draft distribution (B, V)."""
    v = logits.shape[-1]
    greedy = jax.nn.one_hot(jnp.argmax(logits, axis=-1), v, dtype=jnp.float32)
    soft = jax.nn.softmax(logits / jnp.maximum(temps, 1e-6)[:, None], axis=-1)
    return jnp.where((temps > 0.0)[:, None], soft, greedy)


def make_draft_loop(model: ModelApi, ctx: EngineContext, k: int):
    """k chained decode steps at the draft point, as one jit-able callable.

    ``(tree, tokens (B,1), cache, base_keys (B,2), counts (B,), temps (B,),
    round_idx)`` -> ``(draft_tokens (B,k), draft_probs (B,k,V) f32, cache)``.

    The cache comes back with k approximate KV rows written past each slot's
    committed index (the scratch region) and its index advanced by k — the
    verify step rewinds it before re-deriving those rows accurately.
    """

    def draft_loop(tree, tokens, cache, base_keys, counts, temps, round_idx):
        draft_keys = _lane(_round_keys(base_keys, round_idx), _DRAFT_LANE)

        def step(carry, i):
            tok, cache = carry
            logits, cache = model.decode_step(tree, tok, cache, ctx)
            last = logits[:, -1, :].astype(jnp.float32)
            q = _temp_dist(last, temps)
            keys_i = jax.vmap(jax.random.fold_in)(draft_keys, counts + i)
            sampled = jax.vmap(jax.random.categorical)(
                keys_i, last / jnp.maximum(temps, 1e-6)[:, None]
            )
            nxt = jnp.where(temps > 0.0, sampled, jnp.argmax(last, axis=-1))
            nxt = nxt.astype(jnp.int32)[:, None]
            return (nxt, cache), (nxt[:, 0], q)

        (_, cache), (toks, probs) = jax.lax.scan(
            step, (tokens, cache), jnp.arange(k)
        )
        return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(probs, 0, 1), cache

    return draft_loop


def make_verify_step(model: ModelApi, ctx: EngineContext, k: int):
    """One multi-token accurate forward over the pending token + k drafts.

    ``(tree, tokens (B,1), draft_tokens (B,k), draft_probs (B,k,V), cache,
    start (B,), base_keys, counts, temps, round_idx)`` ->
    ``(emitted (B,k+1), accepted (B,), margins (B,k+1), draft_fault (B,),
    verify_fault (B,), cache)``.

    ``start`` is each slot's committed row count BEFORE drafting; the cache's
    index (advanced by the draft loop) is rewound to it so ``decode_step``
    writes accurate KV over the drafted scratch rows. Position ``i`` of the
    verify logits is the accurate next-token distribution after draft ``i``
    tokens — exactly what sequential accurate decoding would compute, given
    multi-token/token-by-token bit-parity (test-asserted).

    ``emitted[b, :accepted[b]+1]`` is the committed stream extension: the
    accepted draft prefix plus one corrected (first rejection, resampled from
    ``norm(max(p-q,0))``) or bonus (all accepted, sampled from the k-th
    accurate distribution) token. On exit the cache is rolled back to
    ``start + accepted + 1`` committed rows per slot.

    Fault flags (the spec-round abort path): a slot whose *draft*
    distributions went non-finite (``draft_fault``) has its whole draft
    rejected and its correction token drawn from the accurate position-0
    distribution — i.e. the lane degrades to plain accurate decode for this
    round, and because the verify forward just rewrote the drafted scratch
    rows with accurate KV, the slot continues cleanly. A slot whose *verify*
    logits went non-finite (``verify_fault``) is numerically unrecoverable
    here — the caller quarantines it. Both flags ride the round's single
    host transfer; with finite inputs every flag is False and the emitted
    math is bit-identical to the unflagged step.
    """
    from .rollback import with_cache_positions

    def verify(tree, tokens, draft_tokens, draft_probs, cache, start,
               base_keys, counts, temps, round_idx):
        b = tokens.shape[0]
        cache = with_cache_positions(cache, start)
        tok_in = jnp.concatenate([tokens, draft_tokens], axis=1)  # (B, k+1)
        logits, cache = model.decode_step(tree, tok_in, cache, ctx)
        logits = logits.astype(jnp.float32)  # (B, k+1, V)
        p = jax.vmap(_temp_dist, in_axes=(1, None), out_axes=1)(logits, temps)

        # leading-prefix acceptance: accept d_i iff u_i * q(d_i) < p(d_i)
        # (the division-free form of u < p/q; greedy slots have one-hot p, q)
        gather = lambda dist, tok: jnp.take_along_axis(
            dist, tok[..., None], axis=-1
        )[..., 0]
        q_at = gather(draft_probs, draft_tokens)  # (B, k)
        p_at = gather(p[:, :k], draft_tokens)     # (B, k)
        draft_fault = jnp.any(~jnp.isfinite(draft_probs), axis=(1, 2))  # (B,)
        verify_fault = jnp.any(~jnp.isfinite(logits), axis=(1, 2))      # (B,)
        rkeys = _round_keys(base_keys, round_idx)
        u = jax.vmap(
            lambda key: jax.random.uniform(jax.random.fold_in(key, _ACCEPT_LANE), (k,))
        )(rkeys)
        # a faulted draft is rejected wholesale (NaN q_at would compare False
        # anyway, but an Inf could sneak a draft token through)
        accept = (u * q_at < p_at) & ~draft_fault[:, None]
        accepted = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

        # correction token: residual distribution at the first rejection,
        # or the bonus distribution (position k) when every draft survived
        resid = jnp.maximum(p[:, :k] - draft_probs, 0.0)
        at = jnp.minimum(accepted, k - 1)
        resid_at = jnp.take_along_axis(resid, at[:, None, None], axis=1)[:, 0]
        p_reject = jnp.take_along_axis(p[:, :k], at[:, None, None], axis=1)[:, 0]
        rsum = resid_at.sum(-1, keepdims=True)
        # measure-zero guard: q == p makes the residual vanish; fall back to p
        resid_at = jnp.where(rsum > 0.0, resid_at / jnp.maximum(rsum, 1e-30), p_reject)
        dist = jnp.where((accepted == k)[:, None], p[:, k], resid_at)  # (B, V)
        # draft-fault abort: the residual is NaN-contaminated (it subtracts
        # the faulted draft probs), so the lane falls back to the accurate
        # position-0 distribution — exactly what plain accurate decode of the
        # pending token would have sampled from
        dist = jnp.where(draft_fault[:, None], p[:, 0], dist)
        ckeys = jax.vmap(jax.random.fold_in)(_lane(rkeys, _CORRECT_LANE), counts + accepted)
        sampled = jax.vmap(jax.random.categorical)(ckeys, jnp.log(dist + 1e-30))
        correction = jnp.where(
            temps > 0.0, sampled, jnp.argmax(dist, axis=-1)
        ).astype(jnp.int32)

        pos = jnp.arange(k + 1)[None, :]
        drafts_pad = jnp.concatenate(
            [draft_tokens, jnp.zeros((b, 1), jnp.int32)], axis=1
        )
        emitted = jnp.where(
            pos < accepted[:, None],
            drafts_pad,
            jnp.where(pos == accepted[:, None], correction[:, None], 0),
        )
        cache = with_cache_positions(cache, start + accepted + 1)
        return emitted, accepted, top2_margin(logits), draft_fault, verify_fault, cache

    return verify
