"""Batched serving driver (continuous batching over decode steps).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 6 --max-new 16 --mode carmen
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.core import EngineContext, FXP8, PrecisionPolicy
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--mode", choices=["exact", "carmen", "int8", "kernel"], default="exact")
    ap.add_argument("--per-call", action="store_true",
                    help="skip prepare_params: re-quantize weights every step "
                         "(the seed behaviour; for A/B benchmarking)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = get_model(cfg)
    ctx = (
        EngineContext(mode="exact", compute_dtype=jnp.float32)
        if args.mode == "exact"
        else EngineContext(
            mode=args.mode, policy=PrecisionPolicy.accurate(FXP8), compute_dtype=jnp.float32
        )
    )
    params = model.init(jax.random.PRNGKey(0))
    server = BatchedServer(
        model, ctx, params, slots=args.slots,
        max_len=args.prompt_len + args.max_new + 2,
        prepare_weights=not args.per_call,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32), args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = server.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    weights = "per-call" if args.per_call else "prepared"
    print(f"served {len(results)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s, mode={args.mode}, {weights} weights)")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid][:8]}...")
    return results


if __name__ == "__main__":
    main()
