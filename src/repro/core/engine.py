"""The CARMEN vector engine: one entry point for every matmul in the framework.

Model code never calls ``jnp.dot`` directly — it calls ``EngineContext.linear``
so that the CARMEN execution point (precision format x CORDIC depth) is a
runtime configuration, exactly like the silicon engine's configuration
registers (paper §II-C "control engine ... configuration registers for runtime
parameter tuning").

Execution backends (``repro.core.backends`` — registry keyed by mode)
---------------------------------------------------------------------
exact       FP32/bf16 matmul — the paper's FP32 baseline.
carmen      Paper-faithful simulation: activations fake-quantized to the FxP
            format, weights rounded to the depth-d signed-digit grid
            (= linear-CORDIC multiplier), single real matmul. Differentiable
            via straight-through estimator so QAT/finetuning works.
int8        Production TPU path (beyond-paper): real int8 x int8 -> int32
            ``dot_general`` (2x MXU rate on v5e), per-output-channel weight
            scales, dynamic per-tensor activation scale. CORDIC depth maps to
            effective weight bits by zeroing trailing bits of the int8 grid.
kernel      The Pallas ``cordic_mac`` kernel (tests / small shapes; same math
            as ``carmen``).

Every backend has two lifecycles: the **per-call** path (raw float weights —
weight-side quantization re-traced every call; what QAT trains through, with
``depth`` allowed to be a traced scalar for runtime-adaptive switching) and
the **prepared** path (``prepare_params`` formats the weight bank once; the
forward then does zero weight-side rounding or scale computation — the
software analogue of CARMEN's pre-formatted PE array).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .backends import (
    carmen_dot,
    int8_dot,
    prepare_params,
    resolve,
    sd_round_traced,
)
from .backends.base import PreparedWeight
from .fxp import FXP8
from .precision_policy import LayerPrecision, PrecisionPolicy

__all__ = [
    "EngineContext",
    "PreparedWeight",
    "carmen_dot",
    "int8_dot",
    "prepare_params",
    "sd_round_traced",
]


@dataclasses.dataclass(frozen=True)
class EngineContext:
    """Static engine configuration threaded through model code.

    Hashable (usable as a jit static argument). ``mode`` selects the execution
    backend; ``policy`` supplies per-layer (fmt, depth). Prepared weight
    leaves (``prepare_params``) carry their own backend, which takes
    precedence over ``mode`` at dispatch.
    """

    mode: str = "exact"  # exact | carmen | int8 | kernel
    policy: Optional[PrecisionPolicy] = None
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention lowering: "xla" (query-chunked, scores materialize per chunk)
    # or "flash" (KV-chunked online softmax; pure-JAX twin of the Pallas
    # flash kernel — bit-tested against it; scores never exceed tile size)
    attn_impl: str = "xla"
    # emit dots in compute_dtype so TP partial-sums all-reduce in bf16
    # (Megatron-style; halves activation collective volume; MXU still
    # accumulates fp32 internally per tile)
    tp_reduce_bf16: bool = False

    def layer_precision(self, name: str) -> LayerPrecision:
        policy = self.policy or PrecisionPolicy.accurate(FXP8)
        return policy.for_layer(name)

    def dot(self, x, w, *, name: str = ""):
        """Matmul along the last axis of x / first of w, backend-dispatched."""
        return resolve(w, self.mode).dot(self, x, w, name=name)

    def linear(self, x, w, b=None, *, name: str = ""):
        out = self.dot(x, w, name=name)
        if b is not None:
            out = out + b.astype(out.dtype)
        return out
