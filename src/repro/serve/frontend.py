"""Continuous-batching streaming frontend with chunked prefill.

:class:`~repro.serve.engine.BatchedServer.run` consumes a *fixed* request
list: admission happens only while that list drains, and every prefill runs
the whole prompt in one jitted call — a long prompt admitted next to a
decoding slot stalls that slot's token emission for the full prompt's wall
time. This module turns the same server into a streaming service:

* :class:`ContinuousScheduler` owns a live request queue. ``submit()`` is
  thread-safe and returns a :class:`StreamHandle` immediately; every
  ``step()`` (one *admission tick*) drains new arrivals and cancellations,
  re-runs the resilience sweeps (queued-deadline expiry and queue-limit
  shedding fire on EVERY tick, not just at run entry), runs at most one
  chunk budget of prefill, then one decode burst / speculative round over
  the active slots. Admission and eviction happen at every burst boundary —
  continuous batching in the vLLM sense, over the engine's existing slot
  discipline.

* **Chunked prefill** bounds how long any prompt can monopolize the device
  between bursts: instead of one whole-prompt forward, the prompt advances
  through the request's PRIVATE single-row cache at most
  ``chunk_tokens`` rows per tick (:func:`~repro.serve.engine.
  make_prefill_chunk` — the per-query-causal mask plus the write-index
  rewind make a chunk attend exactly the rows the monolithic forward would
  give it; recurrent families chunk their masked scan with the state as the
  carry). Only the final chunk's admit program touches the shared slot cache
  and transfers anything to the host, so a 10-chunk prefill still costs one
  host round-trip. Greedy token streams are identical to the monolithic
  path (asserted per family in ``tests/test_frontend.py``); inter-token
  latency for slots decoding alongside is bounded by one chunk budget
  (asserted structurally: ``stats["max_prefill_rows_between_bursts"]``).

* Deadlines become *submit-relative*: the scheduler resolves each arrival's
  deadline (or the resilience default) against its submit timestamp into
  the server's run-local deadline table, so a request submitted late still
  gets its full allowance — and none of this ever writes to the caller's
  ``Request`` object.

* Cancellation: ``handle.cancel()`` (client disconnect) marks the request;
  the scheduler evicts it at the next tick boundary with outcome
  ``aborted`` / reason ``cancelled`` and its partial tokens. The slot is
  freed and reused with no telemetry leak — the same ``_begin_run`` /
  ``_end_run`` symmetry contract the batch path has.

:class:`AsyncFrontend` is the asyncio facade: the scheduler loops on a
daemon thread, ``await frontend.generate(req)`` / ``async for tok in
frontend.stream(req)`` bridge handles onto the event loop. The HTTP/stdin
drivers in ``launch/serve.py`` sit on top of it.

Sharded serving (``mesh=``) is not streamed yet — the scheduler rejects a
meshed server at construction (ROADMAP: sharded streaming).
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BatchedServer, Request
from .kvcache import bucket_length

__all__ = ["AsyncFrontend", "ContinuousScheduler", "FrontendConfig",
           "StreamHandle"]

_DONE = object()  # stream sentinel


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Streaming-frontend knobs.

    ``chunk_tokens`` is the prefill budget per admission tick: at most this
    many prompt rows run between consecutive decode bursts (each chunk is
    padded to a power-of-two bucket ≤ the budget, so chunked prefill
    compiles O(log chunk_tokens) extra programs). ``monolithic_prefill``
    disables chunking — each admission runs the whole prompt through the
    batch path's one-shot prefill (the contrast arm of the interleaving
    benchmark, and a fallback).
    """

    chunk_tokens: int = 32
    monolithic_prefill: bool = False

    def __post_init__(self):
        if self.chunk_tokens < 1:
            raise ValueError(
                f"chunk_tokens must be >= 1, got {self.chunk_tokens}")


class StreamHandle:
    """The caller's side of one streaming request.

    Tokens arrive incrementally: iterate the handle (blocking) or poll
    ``tokens``. ``result()`` blocks until the request settles and returns
    the full stream; ``outcome`` carries the structured
    :class:`~repro.resilience.RequestOutcome` once settled. ``cancel()``
    requests eviction at the next tick boundary (client disconnect).
    All methods are safe to call from any thread.
    """

    def __init__(self, request: Request) -> None:
        self.request = request
        self.rid = request.rid
        self.tokens: List[int] = []
        self.outcome = None
        self._events: _queue.Queue = _queue.Queue()
        self._done = threading.Event()
        self._cancel = threading.Event()
        self._sent = 0  # tokens already pushed (scheduler-side cursor)

    def cancel(self) -> None:
        """Ask the scheduler to evict this request at the next tick."""
        self._cancel.set()

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def status(self) -> Optional[str]:
        return self.outcome.status if self.outcome is not None else None

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until settled; returns the (possibly partial) stream."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not settled in {timeout}s")
        return list(self.tokens)

    def __iter__(self):
        """Yield tokens as they land; returns when the request settles."""
        while True:
            item = self._events.get()
            if item is _DONE:
                return
            yield item

    # -- scheduler side -------------------------------------------------------

    def _push(self, toks: List[int]) -> None:
        self.tokens.extend(toks)
        for t in toks:
            self._events.put(t)

    def _settle(self, outcome) -> None:
        self.outcome = outcome
        self._done.set()
        self._events.put(_DONE)


@dataclasses.dataclass
class _PrefillJob:
    """One in-flight chunked prefill: the request's private row cache and
    last-logits carry, plus the committed-row cursor."""

    req: Request
    slot: int
    prompt: np.ndarray
    row: object
    last: object
    done: int = 0


class ContinuousScheduler:
    """Continuous batching over one :class:`BatchedServer` (module docstring).

    Single-threaded engine discipline: every engine/observer call happens on
    the thread driving ``step()``; ``submit``/``cancel`` only touch a locked
    inbox and per-handle events, so any number of client threads can feed
    one scheduler. Use as a context manager (opens/closes the server's run
    lifecycle), or call ``open()`` / ``close()`` explicitly.
    """

    def __init__(self, server: BatchedServer,
                 config: Optional[FrontendConfig] = None) -> None:
        if server.mesh is not None:
            raise ValueError(
                "the streaming frontend is single-device for now — serve "
                "mesh= through run() (ROADMAP: sharded streaming)"
            )
        self.server = server
        self.config = config if config is not None else FrontendConfig()
        self._lock = threading.Lock()
        self._inbox: List = []          # (request, handle, wall_ts, reason)
        self._known: set = set()        # every rid ever submitted
        self.handles: Dict[int, StreamHandle] = {}
        self.queue: List[Request] = []
        self.results: Dict[int, List[int]] = {}
        self.slot_of: Dict[int, int] = {}
        self.free: List[int] = list(range(server.slots))
        self.job: Optional[_PrefillJob] = None
        self._open = False
        self._closed = False
        self._shed_since = 0            # sheds since last controller observe
        self._rows_since_burst = 0      # prefill rows stalling active slots
        self._chunk_buckets: set = set()
        self.stats = {
            "ticks": 0, "bursts": 0, "submitted": 0, "prefill_rows": 0,
            "max_prefill_rows_between_bursts": 0,
        }

    # -- lifecycle ------------------------------------------------------------

    def open(self) -> "ContinuousScheduler":
        """Begin the serving session (the server's run lifecycle: telemetry,
        observer, outcome state all reset — same contract as ``run()``)."""
        if self._open:
            return self
        if self._closed:
            raise RuntimeError("scheduler already closed; build a new one")
        cfg = self.config
        self.server._frontend_meta = {
            "chunk_tokens": cfg.chunk_tokens,
            "monolithic_prefill": cfg.monolithic_prefill,
        }
        self.server._begin_run([])
        self._open = True
        return self

    def close(self, aborted: bool = False) -> None:
        """End the session. A clean close resolves anything still in flight
        as ``aborted`` / ``shutdown`` (partial tokens kept) so every
        submitted request ends with exactly one outcome; ``aborted=True``
        lets ``_end_run``'s crashed-run attribution fill them instead."""
        if self._closed:
            return
        self._closed = True
        if not self._open:
            return
        server = self.server
        self._drain_inbox()
        if not aborted:
            for req in self.queue:
                server._finish(req, "aborted", reason="shutdown")
            self.queue = []
            if self.job is not None:
                server._finish(self.job.req, "aborted", reason="shutdown")
                self.free.append(self.job.slot)
                self.job = None
            for rid in list(server.active):
                req = server.active.pop(rid)
                self.results[rid] = req.generated
                server._finish(req, "aborted", reason="shutdown")
                self.free.append(self.slot_of.pop(rid))
        server._end_run(aborted)
        self._flush()
        for rid, handle in list(self.handles.items()):
            handle._settle(server.outcomes.get(rid))
            del self.handles[rid]
        self._open = False

    def __enter__(self) -> "ContinuousScheduler":
        return self.open()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(aborted=exc_type is not None)

    # -- client side ----------------------------------------------------------

    def submit(self, request: Request) -> StreamHandle:
        """Enqueue one request; returns its :class:`StreamHandle`.

        Thread-safe, non-blocking. With ``resilience=None`` invalid requests
        raise here, synchronously (the legacy fail-stop contract); with a
        :class:`ResilienceConfig` they are shed with a structured reason at
        the next tick. Deadlines are relative to this call.
        """
        if self._closed:
            raise RuntimeError("scheduler is closed")
        if not self._open:
            raise RuntimeError("scheduler is not open — use it as a context "
                               "manager or call open() first")
        reason = self.server._admission_error(request)  # raises when legacy
        handle = StreamHandle(request)
        with self._lock:
            if request.rid in self._known:
                raise ValueError(f"duplicate rid {request.rid}: streaming "
                                 "rids must be unique per session")
            self._known.add(request.rid)
            self._inbox.append((request, handle, time.perf_counter(), reason))
            self.stats["submitted"] += 1
        return handle

    @property
    def idle(self) -> bool:
        """No queued, in-prefill, or decoding work (new submissions may
        still arrive)."""
        with self._lock:
            inbox = bool(self._inbox)
        return not (inbox or self.queue or self.job is not None
                    or self.server.active)

    # -- scheduler loop -------------------------------------------------------

    def step(self) -> bool:
        """One admission tick. Returns False when there was nothing to do.

        Order: drain arrivals and cancellations, re-run the resilience
        sweeps over the queue, run at most ``chunk_tokens`` prefill rows,
        then one decode burst / speculative round, then stream the committed
        tokens out to their handles.
        """
        if not self._open or self._closed:
            raise RuntimeError("scheduler is not open")
        server = self.server
        did = self._drain_inbox()
        did = self._apply_cancellations() or did
        did = self._police_queue() or did
        if not (self.queue or self.job is not None or server.active):
            self._flush()
            return did
        obs = server.observer
        if obs is not None:
            obs.admission_tick(len(self.queue), len(server.active),
                               len(self.free))
        self.stats["ticks"] += 1
        active_before = bool(server.active)
        rows = self._prefill_tick()
        self.stats["prefill_rows"] += rows
        if active_before:
            # only rows run while a slot was already decoding can stall its
            # emission — that is what the interleaving bound measures
            self._rows_since_burst += rows
        if server.active:
            queue_depth, free_slots = len(self.queue), len(self.free)
            summary = (server._spec_round(self.slot_of)
                       if server.spec is not None
                       else server._burst_round(self.slot_of))
            misses = server._settle_round(summary, self.results, self.slot_of,
                                          self.free)
            if server.controller is not None:
                server._observe(summary["point"], summary["emitted"],
                                summary["steps"], queue_depth, free_slots,
                                summary["min_margin"],
                                deadline_misses=misses, shed=self._shed_since)
                self._shed_since = 0
            self.stats["bursts"] += 1
            self.stats["max_prefill_rows_between_bursts"] = max(
                self.stats["max_prefill_rows_between_bursts"],
                self._rows_since_burst)
            self._rows_since_burst = 0
        self._flush()
        return True

    def drain(self) -> Dict[int, List[int]]:
        """Tick until idle; returns rid -> tokens for everything resolved so
        far (the streaming analogue of ``run()``'s return value)."""
        while True:
            did = self.step()
            if not did and self.idle:
                return dict(self.results)

    def serve_forever(self, stop: threading.Event,
                      idle_sleep: float = 1e-3) -> None:
        """Drive ticks until ``stop`` is set (the daemon-thread loop
        :class:`AsyncFrontend` runs); sleeps briefly when idle."""
        while not stop.is_set():
            if not self.step():
                time.sleep(idle_sleep)

    # -- tick internals -------------------------------------------------------

    def _drain_inbox(self) -> bool:
        server = self.server
        with self._lock:
            batch, self._inbox = self._inbox, []
        for req, handle, wall, reason in batch:
            self.handles[req.rid] = handle
            server._run_requests.append(req)
            d = server._resolve_deadline(req)
            # submit-relative -> run-relative: the engine's sweeps compare
            # against (perf_counter() - _t0)
            server._deadlines[req.rid] = (
                None if d is None else (wall - server._t0) + d)
            if server.observer is not None:
                server.observer.request_submitted(
                    req.rid, len(np.asarray(req.prompt)), req.max_new,
                    wall_ts=wall)
            if reason is not None:
                server._shed(req, reason)
                self._shed_since += 1
                continue
            self.queue.append(req)
        return bool(batch)

    def _apply_cancellations(self) -> bool:
        server, did = self.server, False
        for rid, handle in list(self.handles.items()):
            if not handle.cancelled or rid in server.outcomes:
                continue
            req = handle.request
            if self.job is not None and self.job.req.rid == rid:
                # mid-prefill: nothing reached the shared cache yet — drop
                # the private row carry and return the slot
                self.free.append(self.job.slot)
                self.job = None
                req.generated, req.margins = [], []
                server._finish(req, "aborted", reason="cancelled")
            elif rid in server.active:
                # mid-decode: evict at this tick boundary, keep the partial
                # stream (it was committed and already pushed to the handle)
                server.active.pop(rid)
                self.results[rid] = req.generated
                server._finish(req, "aborted", reason="cancelled")
                self.free.append(self.slot_of.pop(rid))
            else:
                kept = [r for r in self.queue if r.rid != rid]
                if len(kept) == len(self.queue):
                    continue  # already settling this tick
                self.queue = kept
                server._finish(req, "aborted", reason="cancelled")
            did = True
        return did

    def _police_queue(self) -> bool:
        """The resilience sweeps, every tick: shed queued requests whose
        deadline already passed, then enforce the queue bound."""
        server, res = self.server, self.server.resilience
        if res is None or not self.queue:
            return False
        self.queue, n_shed = server._expire_queue(self.queue)
        if (res.queue_limit is not None
                and len(self.queue) > res.queue_limit):
            from repro.resilience.outcome import shed_overflow

            self.queue, dropped = shed_overflow(
                self.queue, res.queue_limit, res.shed_policy,
                deadline_of=server._deadline)
            for r in dropped:
                server._shed(r, "queue_full")
            n_shed += len(dropped)
        self._shed_since += n_shed
        return n_shed > 0

    def _prefill_tick(self) -> int:
        """Run at most ``chunk_tokens`` prompt rows: continue the in-flight
        job, then admit from the queue while budget and slots remain.
        Returns the rows actually run (monolithic admissions charge their
        whole prompt, which is exactly their stall)."""
        server, cfg = self.server, self.config
        budget = cfg.chunk_tokens
        rows = 0
        while budget > 0:
            if self.job is None:
                if not (self.queue and self.free):
                    break
                req = self.queue.pop(0)
                slot = self.free.pop(0)
                if server.observer is not None:
                    server.observer.request_admitted(req.rid, slot)
                if cfg.monolithic_prefill:
                    server._prefill_slot(slot, req)
                    server._after_prefill(slot, req, self.results,
                                          self.slot_of, self.free)
                    plen = len(np.asarray(req.prompt))
                    rows += plen
                    budget -= plen
                    continue
                row, last = server.fresh_row()
                self.job = _PrefillJob(
                    req=req, slot=slot,
                    prompt=np.asarray(req.prompt, np.int32),
                    row=row, last=last)
            n = min(budget, len(self.job.prompt) - self.job.done)
            self._advance_job(self.job, n)
            rows += n
            budget -= n
            if self.job.done >= len(self.job.prompt):
                self.job = None
        return rows

    def _advance_job(self, job: _PrefillJob, n: int) -> None:
        """One chunk: ``n`` prompt rows through the job's private row cache;
        the final chunk also runs the admit program (sample token 0, scatter
        the row into the slot, admit the slot state) — the chunked prefill's
        single host transfer."""
        server = self.server
        obs = server.observer
        point = server._serving_point()
        bucket = bucket_length(n, server.max_len)
        chunk_fn, admit_fn = server.chunk_fns()
        final = job.done + n >= len(job.prompt)
        if obs is not None:
            if bucket not in self._chunk_buckets:
                obs.compile_event("prefill_chunk", bucket=bucket)
            obs.prefill_chunk_begin(job.req.rid, job.done, n, bucket, point)
        self._chunk_buckets.add(bucket)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :n] = job.prompt[job.done:job.done + n]
        job.row, job.last = chunk_fn(
            server._serving_tree(), job.row, job.last, jnp.asarray(padded),
            jnp.int32(job.done), jnp.int32(n))
        job.done += n
        if not final:
            if obs is not None:
                obs.prefill_chunk_end(job.req.rid, final=False)
            return
        req, slot = job.req, job.slot
        seed = req.seed if req.seed is not None else req.rid
        tok, margin, server.cache, server._state = admit_fn(
            server.cache, server._state, job.row, job.last, jnp.int32(slot),
            jax.random.PRNGKey(seed), jnp.float32(req.temperature),
            jnp.int32(req.max_new))
        tok, margin = jax.device_get((tok, margin))
        server.host_transfers += 1
        server._slot_start[slot] = len(job.prompt)
        req.generated = [int(tok[0, 0])]
        req.margins = [float(margin[0])]
        if obs is not None:
            obs.prefill_chunk_end(req.rid, final=True,
                                  prompt_len=len(job.prompt), point=point)
        if server.telemetry is not None:
            server.telemetry.record_prefill(point, len(job.prompt))
        server._after_prefill(slot, req, self.results, self.slot_of,
                              self.free)

    def _flush(self) -> None:
        """Stream newly committed tokens to their handles and settle the
        ones whose outcome landed this tick."""
        server = self.server
        for rid in list(self.handles):
            handle = self.handles[rid]
            gen = handle.request.generated or []
            if len(gen) > handle._sent:
                handle._push(gen[handle._sent:])
                handle._sent = len(gen)
            if rid in server.outcomes:
                handle._settle(server.outcomes[rid])
                del self.handles[rid]


class AsyncFrontend:
    """asyncio facade over :class:`ContinuousScheduler`: the scheduler loops
    on a daemon thread; ``generate``/``stream`` bridge handles onto the
    event loop. Also usable synchronously via ``start()``/``stop()`` +
    ``submit()`` (the stdin/HTTP drivers in ``launch/serve.py`` do)."""

    def __init__(self, server: BatchedServer,
                 config: Optional[FrontendConfig] = None) -> None:
        self.scheduler = ContinuousScheduler(server, config)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "AsyncFrontend":
        self.scheduler.open()
        self._thread = threading.Thread(
            target=self.scheduler.serve_forever, args=(self._stop,),
            daemon=True, name="carmen-frontend")
        self._thread.start()
        return self

    def stop(self, aborted: bool = False) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.scheduler.close(aborted=aborted)

    async def __aenter__(self) -> "AsyncFrontend":
        return self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        self.stop(aborted=exc_type is not None)

    def submit(self, request: Request) -> StreamHandle:
        return self.scheduler.submit(request)

    async def generate(self, request: Request) -> List[int]:
        """Submit and await the full (possibly partial-on-abort) stream."""
        import asyncio

        handle = self.submit(request)
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, handle._done.wait)
        return list(handle.tokens)

    async def stream(self, request: Request):
        """Submit and yield tokens as they land (async generator)."""
        import asyncio

        handle = self.submit(request)
        loop = asyncio.get_running_loop()
        while True:
            item = await loop.run_in_executor(None, handle._events.get)
            if item is _DONE:
                return
            yield item
