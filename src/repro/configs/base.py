"""Model / shape / engine configuration system.

Every assigned architecture is a :class:`ModelConfig` instance in its own
``configs/<id>.py``; the CARMEN execution point (precision x depth policy) is
orthogonal and supplied per run. ``reduced()`` produces the small-config
variant used by CPU smoke tests; the full configs are exercised only through
the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    first_dense_layers: int = 0  # leading dense layers (deepseek: 3)
    moe_every: int = 1  # a layer is MoE iff (i % moe_every == moe_every-1) past prefix
    d_ff_dense: int = 0  # d_ff of the interleaved/prefix dense layers
    capacity_factor: float = 1.25
    router_dtype: str = "float32"  # criticality-pinned (DESIGN.md §4)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD mixer."""

    state_dim: int = 128
    head_dim: int = 64  # P
    num_heads: int = 0  # derived: d_inner // head_dim if 0
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1  # B/C projection groups


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention blocks."""

    attn_every: int = 9  # one shared-attn application per this many ssm layers
    shared_attn_blocks: int = 1  # distinct shared blocks, used round-robin


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 24
    # decoder layer count = ModelConfig.num_layers
    encoder_seq_factor: float = 1.0  # encoder frames per decoder token (stub frontend)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric
    act: str = "swish"  # MLP activation (multi-AF block mode)
    glu: bool = True  # gated MLP (SwiGLU-style)
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 256  # stub patch/frame positions prepended
    dtype: str = "bfloat16"
    # which attention flavor long-context decoding is allowed with
    subquadratic: bool = False

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def kv_groups(self) -> int:
        return max(1, self.num_heads // max(self.num_kv_heads, 1))

    def validate(self) -> None:
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.moe:
            assert self.family in ("moe",), self.name
        if self.family == "ssm":
            assert self.ssm is not None
        if self.family == "hybrid":
            assert self.ssm is not None and self.hybrid is not None
        if self.family == "audio":
            assert self.encdec is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell shape. ``kind`` selects which step gets lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec'd skip rules: long_500k only for sub-quadratic mixers."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "quadratic full attention at 524k ctx — architecturally inapplicable"
    return True, ""


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128) -> ModelConfig:
    """Family-preserving small config for CPU smoke tests."""
    scale = d_model / cfg.d_model
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    head_dim = max(16, d_model // heads)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads if cfg.num_heads else 0,
        num_kv_heads=kv if cfg.num_heads else 0,
        head_dim=head_dim,
        d_ff=max(32, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab_size=256,
        frontend_tokens=8,
        dtype="float32",
    )
    if cfg.moe:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.num_shared_experts else 0,
            d_ff_dense=64 if cfg.moe.d_ff_dense else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla:
        updates["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16
        )
        updates["head_dim"] = 16
    if cfg.ssm:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=32, num_heads=0
        )
    if cfg.hybrid:
        updates["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=max(1, layers // 2))
    if cfg.encdec:
        updates["encdec"] = dataclasses.replace(cfg.encdec, encoder_layers=layers)
    return dataclasses.replace(cfg, **updates)
