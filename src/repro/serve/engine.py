"""Serving engine: prefill/decode step builders, sampling, batched scheduler.

The decode step is the unit the decode-shape cells lower (one new token against
a seq_len-deep KV cache). The scheduler below implements simple continuous
batching over a fixed slot count — admit/evict per step, per-slot positions —
with three serving fast paths on top:

* **prepared weight banks**: on construction the server runs
  ``prepare_params`` (quantize once), so carmen/int8/kernel decode performs
  zero weight-side rounding or scale computation per step;
* **batched prefill**: an admitted prompt runs through the model in ONE
  multi-token forward (``decode_step`` with S = prompt length), and the
  resulting KV rows are scattered into the slot cache — replacing the seed's
  token-by-token Python loop. Sampling happens on device inside the jitted
  step (per-slot temperature + per-request PRNG streams), so only (B, 1)
  token ids and a (B,) top-2 logit margin cross the host boundary per step;
* **runtime-adaptive precision** (``repro.runtime``): pass a
  :class:`~repro.runtime.controller.ModeController` and each decode step
  executes at the controller's current execution point — a different
  prepared tree from the multi-point weight bank, selected from live
  telemetry (logit margins, queue pressure, cycle budget) with zero
  weight-side work per switch. ``self.telemetry`` accumulates mode
  occupancy, estimated MAC cycles saved, and switch counts;
* **self-speculative decoding** (``repro.spec``): pass
  ``speculate=SpecConfig(...)`` (plus a bank, or a controller that carries
  one) and the decode loop becomes draft-k-then-verify rounds: a jitted scan
  rolls the approximate execution point ``k`` tokens forward into the cache
  region past each slot's committed index, then ONE accurate multi-token
  forward verifies all ``k+1`` positions, accepts a draft prefix
  (greedy exact-match / rejection sampling), and rolls the cache back to the
  accepted length per slot. Greedy output is bit-identical to accurate-only
  serving; ``self.spec_telemetry`` records acceptance and weight-pass cycle
  savings. With a controller attached it picks the draft point each round,
  fed by the verify logits' margins.

SSM/hybrid/audio families keep the sequential prefill path (their recurrent
state is carried step-by-step); the distributed story (cache shardings) lives
in sharding/partition.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, prepare_params
from repro.models import ModelApi

# families whose decode caches are pure attention/MLA KV rows (scatterable);
# recurrent-state families prefill sequentially
_BATCHED_PREFILL_FAMILIES = ("dense", "vlm", "moe")


def make_decode_sample_step(model: ModelApi, ctx: EngineContext, *,
                            temperature: float = 0.0):
    """Decode + on-device sampling: only (B, 1) ids leave the device."""

    def decode_sample(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        return sample(logits, key, temperature=temperature), cache

    return decode_sample


def make_cached_prefill_step(model: ModelApi, ctx: EngineContext):
    """Whole-prompt prefill: tokens (B, P) -> (first sampled token (B, 1), cache)."""

    def prefill_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), cache

    return prefill_step


def sample(logits, key, *, temperature: float = 0.0):
    """logits (B, 1, V) -> tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Serving steps: per-slot sampling + margin telemetry
# ---------------------------------------------------------------------------


def _sample_slots(last, base_keys, counts, temps):
    """Per-slot sampling: last (B, V) logits -> (B, 1) int32 tokens.

    ``base_keys`` (B, 2) per-request PRNG keys, ``counts`` (B,) per-request
    generated-token indices (folded in, so a request's stream is independent
    of batch composition and scheduling), ``temps`` (B,) temperatures —
    ``temp <= 0`` means greedy, bit-identical to plain argmax.
    """
    greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
    keys = jax.vmap(jax.random.fold_in)(base_keys, counts)
    scaled = last / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)[:, None]


def top2_margin(logits):
    """Top-2 logit margin along the last axis — the controller's confidence
    signal (shared with the speculative verify step)."""
    top2 = jax.lax.top_k(logits, 2)[0]
    return top2[..., 0] - top2[..., 1]


def make_serve_decode_step(model: ModelApi, ctx: EngineContext):
    """Decode + per-slot sampling + margin telemetry (the scheduler's step).

    Only (B, 1) token ids and (B,) float margins cross the host boundary.
    """

    def decode_serve(params, tokens, cache, base_keys, counts, temps):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        last = logits[:, -1, :].astype(jnp.float32)
        return _sample_slots(last, base_keys, counts, temps), top2_margin(last), cache

    return decode_serve


def make_serve_prefill_step(model: ModelApi, ctx: EngineContext):
    """Whole-prompt prefill with sampling: tokens (1, P) -> first token + margin."""

    def prefill_serve(params, tokens, cache, base_keys, temps):
        logits, cache = model.decode_step(params, tokens, cache, ctx)
        last = logits[:, -1, :].astype(jnp.float32)
        counts = jnp.zeros((tokens.shape[0],), jnp.int32)  # first generated token
        return _sample_slots(last, base_keys, counts, temps), top2_margin(last), cache

    return prefill_serve


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new: int
    temperature: float = 0.0      # <= 0: greedy
    seed: Optional[int] = None    # PRNG stream seed; defaults to rid
    generated: Optional[List[int]] = None
    margins: Optional[List[float]] = None  # top-2 logit margin per generated token


def _checked_prompt(req: Request) -> np.ndarray:
    prompt = np.asarray(req.prompt, np.int32)
    if prompt.size == 0:
        raise ValueError(
            f"request {req.rid}: empty prompt — prompts must carry at least "
            "one token (seed with BOS)"
        )
    return prompt


@dataclasses.dataclass
class BatchedServer:
    """Continuous batching over ``slots`` concurrent sequences.

    ``prepare_weights=True`` (default) formats the weight bank once through
    the engine's backend registry; pass False to benchmark the per-call path.

    ``controller`` switches the server to runtime-adaptive precision: decode
    executes at the controller's current execution point (a tree from its
    multi-point weight bank), the controller observes margins / queue
    pressure after every step, and ``self.telemetry`` accumulates occupancy,
    switch counts, and estimated MAC-cycle savings. ``params`` may stay the
    raw float tree in that case — the bank carries all serving weights.

    ``speculate`` (a :class:`repro.spec.SpecConfig`) switches the decode loop
    to self-speculative rounds served from a multi-point ``bank`` (defaulting
    to ``controller.bank``): draft ``draft_len`` tokens at the draft point,
    verify all of them plus a bonus position in one accurate multi-token
    forward, commit the accepted prefix, roll the KV cache back. Requires a
    scatterable (attention/MLA) cache family — recurrent state cannot roll
    back. With a controller attached, the controller picks the draft point
    per round; ``self.telemetry``'s cycle fields then describe draft-point
    occupancy only, and ``self.spec_telemetry`` is the cycle-accounting
    authority.
    """

    model: ModelApi
    ctx: EngineContext
    params: object
    slots: int = 4
    max_len: int = 256
    prepare_weights: bool = True
    controller: Optional[object] = None  # repro.runtime.ModeController
    speculate: Optional[object] = None   # repro.spec.SpecConfig
    bank: Optional[object] = None        # repro.runtime.MultiPointBank

    def __post_init__(self):
        self._bank = self.bank
        if self._bank is None and self.controller is not None:
            self._bank = self.controller.bank
        if self.controller is not None:
            from repro.runtime import TelemetryRecorder

            self.telemetry = TelemetryRecorder.for_bank(self.controller.bank)
        else:
            self.telemetry = None
            if self.prepare_weights and self.speculate is None:
                self.params = prepare_params(
                    self.params, self.ctx.policy, self.ctx.mode, specs=self.model.specs()
                )
        self.batched_prefill = self.model.cfg.family in _BATCHED_PREFILL_FAMILIES
        self.spec = None
        self.spec_telemetry = None
        if self.speculate is not None:
            from repro.spec import SpeculativeDecoder

            if self._bank is None:
                raise ValueError(
                    "speculate= needs a multi-point weight bank: pass bank= "
                    "or a controller that carries one"
                )
            if not self.batched_prefill:
                raise ValueError(
                    f"speculative serving needs a scatterable KV cache; the "
                    f"{self.model.cfg.family!r} family carries recurrent "
                    "state that cannot roll back past rejected drafts"
                )
            self.spec = SpeculativeDecoder(
                self.model, self.ctx, self._bank, self.speculate
            )
            self.spec_telemetry = self.spec.telemetry
        self.decode = jax.jit(make_serve_decode_step(self.model, self.ctx))
        self.prefill = jax.jit(make_serve_prefill_step(self.model, self.ctx))
        self.cache = self.model.make_cache(self.slots, self.max_len, dtype=jnp.float32)
        self.active: Dict[int, Request] = {}
        self._slot_keys = jnp.stack(
            [jax.random.PRNGKey(0)] * self.slots
        )  # (slots, 2) per-request PRNG streams
        self._slot_temps = np.zeros((self.slots,), np.float32)
        self._slot_start = np.zeros((self.slots,), np.int32)  # committed KV rows

    def _serving_tree(self):
        """The tree prefill / non-speculative decode executes at.

        Speculative serving prefills at the VERIFY point so the committed
        prompt KV is accurate — the bit-exactness guarantee starts there.
        """
        if self.spec is not None:
            return self._bank.tree(self.spec.verify_point)
        return self.controller.tree() if self.controller is not None else self.params

    def _scatter_slot(self, slot: int, row_cache):
        """Write a freshly prefilled single-row cache into this slot's rows."""

        def put(dst, src):
            src = src.astype(dst.dtype)
            if dst.shape == src.shape:  # slots == 1: whole-cache replacement
                return src
            diff = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape)) if a != b]
            assert len(diff) == 1, (dst.shape, src.shape)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, diff[0])

        self.cache = jax.tree.map(put, self.cache, row_cache)

    def _prefill_slot(self, slot: int, req: Request):
        """Run the prompt into this slot's cache; sets ``req.generated``.

        Both paths prefill a FRESH single-row cache and scatter it into the
        slot, so prefilling never touches other active slots' state: one
        multi-token pass for attention families (compiles once per distinct
        prompt length), a sequential token loop for recurrent state.
        """
        prompt = _checked_prompt(req)
        tree = self._serving_tree()
        seed = req.seed if req.seed is not None else req.rid
        base_key = jax.random.PRNGKey(seed)
        temp = np.float32(req.temperature)
        row = self.model.make_cache(1, self.max_len, dtype=jnp.float32)
        if self.batched_prefill:
            tok, margin, row = self.prefill(
                tree, jnp.asarray(prompt[None, :]), row,
                base_key[None, :], jnp.asarray([temp]),
            )
        else:
            zero = jnp.zeros((1,), jnp.int32)
            for t in prompt:
                tok, margin, row = self.decode(
                    tree, jnp.asarray([[t]], jnp.int32), row,
                    base_key[None, :], zero, jnp.asarray([temp]),
                )
        self._scatter_slot(slot, row)
        self._slot_keys = self._slot_keys.at[slot].set(base_key)
        self._slot_temps[slot] = temp
        self._slot_start[slot] = len(prompt)
        req.generated = [int(np.asarray(tok)[0, 0])]
        req.margins = [float(np.asarray(margin)[0])]
        if self.telemetry is not None:
            point = (self.spec.verify_point if self.spec is not None
                     else self.controller.point)
            self.telemetry.record_prefill(point, len(prompt))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> generated tokens.

        Per-token top-2 margins land on each request's ``.margins``; with a
        controller attached, ``self.telemetry`` holds the adaptive-run record.
        ``run`` is reusable: telemetry, controller state, and speculative
        counters start fresh on every invocation.
        """
        for req in requests:  # reject before any state mutates
            prompt = _checked_prompt(req)
            if self.spec is not None and (
                len(prompt) + req.max_new + self.spec.draft_len > self.max_len
            ):
                raise ValueError(
                    f"request {req.rid}: prompt ({len(prompt)}) + max_new "
                    f"({req.max_new}) + draft_len ({self.spec.draft_len}) "
                    f"exceeds max_len ({self.max_len}) — the verify forward "
                    "needs draft_len rows of scratch headroom"
                )
        if self.telemetry is not None:
            self.telemetry.reset()
        if self.controller is not None:
            self.controller.reset()
        if self.spec is not None:
            self.spec.reset()
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        slot_of: Dict[int, int] = {}
        free = list(range(self.slots))
        while queue or self.active:
            while queue and free:
                req = queue.pop(0)
                slot = free.pop(0)
                self._prefill_slot(slot, req)
                if len(req.generated) >= req.max_new:  # prefill already done
                    results[req.rid] = req.generated
                    free.append(slot)
                    continue
                self.active[req.rid] = req
                slot_of[req.rid] = slot
            if not self.active:
                continue
            if self.spec is not None:
                self._spec_round(slot_of, len(queue), len(free))
            else:
                self._decode_round(slot_of, len(queue), len(free))
            done = [r for r, q in self.active.items() if len(q.generated) >= q.max_new]
            for rid in done:
                req = self.active.pop(rid)
                results[rid] = req.generated
                free.append(slot_of.pop(rid))
        return results

    def _batch_state(self, slot_of):
        """Pending token + generated count per slot for the active set."""
        toks = np.zeros((self.slots, 1), np.int32)
        counts = np.zeros((self.slots,), np.int32)
        for rid, req in self.active.items():
            toks[slot_of[rid], 0] = req.generated[-1]
            counts[slot_of[rid]] = len(req.generated)
        return toks, counts

    def _observe(self, point, tokens, queue_depth, free_slots, min_margin):
        from repro.runtime import StepSignals

        self.telemetry.record_step(point, active=tokens, min_margin=min_margin)
        self.controller.observe(StepSignals(
            active=len(self.active),
            queue_depth=queue_depth,
            free_slots=free_slots,
            min_margin=min_margin,
        ))

    def _decode_round(self, slot_of, queue_depth, free_slots):
        """One classic single-token decode step over the active slots."""
        toks, counts = self._batch_state(slot_of)
        sampled, margins, self.cache = self.decode(
            self._serving_tree(), jnp.asarray(toks), self.cache,
            self._slot_keys, jnp.asarray(counts), jnp.asarray(self._slot_temps),
        )
        sampled = np.asarray(sampled)
        margins = np.asarray(margins)
        if self.controller is not None:
            active_margins = [float(margins[slot_of[r]]) for r in self.active]
            self._observe(self.controller.point, len(self.active),
                          queue_depth, free_slots, min(active_margins))
        for rid, req in self.active.items():
            req.generated.append(int(sampled[slot_of[rid], 0]))
            req.margins.append(float(margins[slot_of[rid]]))
            self._slot_start[slot_of[rid]] += 1

    def _spec_round(self, slot_of, queue_depth, free_slots):
        """One draft-k-then-verify round over the active slots.

        Each active request gains between 1 (first draft rejected) and
        ``draft_len + 1`` (all accepted + bonus) tokens, clipped to its
        ``max_new``; the KV cache comes back rolled back to the committed
        length per slot.
        """
        toks, counts = self._batch_state(slot_of)
        draft_point = self.controller.point if self.controller is not None else None
        emitted, accepted, margins, self.cache, point = self.spec.round(
            jnp.asarray(toks), self.cache, self._slot_keys, counts,
            self._slot_temps, self._slot_start, draft_point=draft_point,
        )
        accs, emits, round_margins = [], [], []
        for rid, req in self.active.items():
            s = slot_of[rid]
            n = min(int(accepted[s]) + 1, req.max_new - len(req.generated))
            req.generated.extend(int(t) for t in emitted[s, :n])
            req.margins.extend(float(m) for m in margins[s, :n])
            self._slot_start[s] += int(accepted[s]) + 1
            accs.append(int(accepted[s]))
            emits.append(n)
            round_margins.append(float(margins[s, :n].min()))
        self.spec.telemetry.record_round(point, self.spec.verify_point, accs, emits)
        if self.controller is not None:
            self._observe(point, sum(emits), queue_depth, free_slots,
                          min(round_margins))
