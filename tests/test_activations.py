"""Multi-AF block: all seven functions vs exact references, both formats."""
import numpy as np
import pytest

from repro.core import (
    AF_NAMES,
    FXP8,
    FXP16,
    af_ref,
    approx_depth,
    full_depth,
    multi_af_float,
)

# max |err| budgets in output LSBs at full depth, inputs inside format range.
# (GELU chains five CORDIC muls; each contributes up to ~depth/2 LSBs of shift
# truncation, so its budget is the largest.)
_LSB_BUDGET = {"relu": 1, "tanh": 4, "sigmoid": 4, "swish": 8, "gelu": 24, "selu": 8}


def _in_range(fmt, rng, n=4096):
    lim = fmt.max_value * 0.97
    return rng.uniform(-lim, lim, n).astype(np.float32)


@pytest.mark.parametrize("fmt", [FXP8, FXP16], ids=["fxp8", "fxp16"])
@pytest.mark.parametrize("mode", [m for m in AF_NAMES if m != "softmax"])
def test_af_accuracy_full_depth(fmt, mode, rng):
    x = _in_range(fmt, rng)
    out = np.asarray(multi_af_float(x, mode, full_depth(fmt), fmt))
    # The unit saturates at the output format's range (SELU's gain pushes
    # lambda*x past Q3.12 max near the edge) — compare against the clipped ref.
    ref = np.clip(np.asarray(af_ref(x, mode)), fmt.min_value, fmt.max_value)
    assert np.max(np.abs(out - ref)) <= _LSB_BUDGET[mode] * fmt.scale + 1e-6


@pytest.mark.parametrize("fmt", [FXP8, FXP16], ids=["fxp8", "fxp16"])
def test_softmax(fmt, rng):
    x = rng.uniform(-fmt.max_value, fmt.max_value, (16, 64)).astype(np.float32)
    out = np.asarray(multi_af_float(x, "softmax", full_depth(fmt), fmt))
    ref = np.asarray(af_ref(x, "softmax"))
    assert np.max(np.abs(out - ref)) <= 3 * fmt.scale
    # distribution-ness (up to output quantization over 64 lanes)
    assert np.allclose(out.sum(-1), 1.0, atol=64 * fmt.scale / 2)
    assert np.all(out >= 0)


def test_softmax_large_lane_count_no_overflow(rng):
    """Renormalization guard: vocab-scale softmax must not overflow int32.

    With vocab-scale near-uniform lanes every probability sits below one output
    LSB (fixed-point softmax zeroes sub-LSB tail mass — inherent and correct),
    so the check uses peaked rows whose answer the output grid can represent:
    tail logits at the format floor, one dominant logit.
    """
    n = 50_000  # > 16k lanes triggers the renormalization shift at Q7.16
    x = np.full((2, n), -8.0, np.float32)
    peak = np.array([123, 45_678])
    x[np.arange(2), peak] = 7.5
    out = np.asarray(multi_af_float(x, "softmax", full_depth(FXP16), FXP16))
    assert np.all(out >= 0) and np.all(np.isfinite(out))
    assert np.array_equal(out.argmax(-1), peak)
    ref = np.asarray(af_ref(x, "softmax"))
    assert np.max(np.abs(out[np.arange(2), peak] - ref[np.arange(2), peak])) <= 0.02


@pytest.mark.parametrize("mode", ["sigmoid", "tanh", "gelu"])
def test_af_depth_degrades_gracefully(mode, rng):
    """Approximate depth costs accuracy but stays usable (<2% of range)."""
    x = _in_range(FXP16, rng)
    ref = np.asarray(af_ref(x, mode))
    err_full = np.max(np.abs(np.asarray(multi_af_float(x, mode, full_depth(FXP16), FXP16)) - ref))
    err_approx = np.max(np.abs(np.asarray(multi_af_float(x, mode, approx_depth(FXP16), FXP16)) - ref))
    assert err_full <= err_approx
    assert err_approx <= 0.02 * (2 * FXP16.max_value)


def test_relu_is_exact_bypass(rng):
    """ReLU is bypass logic: error is pure I/O quantization, independent of depth."""
    x = _in_range(FXP8, rng)
    a = np.asarray(multi_af_float(x, "relu", 2, FXP8))
    b = np.asarray(multi_af_float(x, "relu", full_depth(FXP8), FXP8))
    assert np.array_equal(a, b)
