"""Sharded serving parity: the batched server on a device mesh.

The tensor-parallel serving path (``BatchedServer(mesh=...)``) is a pure
placement change — prepared weight banks, the KV cache, and the per-slot
decode state are committed to the mesh with the logical-axis rules, and the
same jitted hot paths run under GSPMD — so greedy token streams must be
bit-identical between ``mesh=None``, a 1x1 mesh, a 2x2 mesh, and a 4x2 mesh
for every batched-prefill family, with the adaptive (pinned-controller) and
speculative modes included. Sampled streams are asserted identical across
mesh SHAPES (mesh serving samples under partitionable threefry, the
sharding-invariant PRNG mode; the legacy single-device PRNG generates
different bits once the vocab axis is sharded, so ``mesh=None`` keeps its
historical streams).

Meshes larger than 1x1 need forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_serving.py

which is exactly what the ``tests-multidevice`` CI job sets; under plain
tier-1 (one device) the multi-device cases skip and the 1x1 cases still run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request
from repro.sharding import partition

EXACT = EngineContext(mode="exact", compute_dtype=jnp.float32)
NDEV = len(jax.devices())
MESH_SHAPES = [(1, 1), (2, 2), (4, 2)]


def _mesh(shape):
    if NDEV < shape[0] * shape[1]:
        pytest.skip(
            f"{shape[0]}x{shape[1]} mesh needs {shape[0] * shape[1]} host "
            "devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    return jax.make_mesh(shape, ("data", "model"))


def _setup(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=4, *, max_new=6, temperature=0.0):
    rng = np.random.default_rng(0)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 3 + i).astype(np.int32),
                max_new, temperature=temperature, seed=10 + i)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def olmo():
    return _setup("olmo-1b")


# ---------------------------------------------------------------------------
# greedy bit-identity: mesh=None == 1x1 == 2x2 == 4x2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", MESH_SHAPES)
@pytest.mark.parametrize("arch", ["olmo-1b", "llama4-maverick-400b-a17b",
                                  "deepseek-v3-671b"])
def test_greedy_bit_identical_across_meshes(arch, shape):
    """dense / moe / mla: the sharded server's greedy token stream equals
    single-device serving token for token."""
    cfg, model, params = _setup(arch)
    ref = BatchedServer(model, EXACT, params, slots=4, max_len=32,
                        burst=4).run(_requests(cfg))
    mesh = _mesh(shape)
    srv = BatchedServer(model, EXACT, params, slots=4, max_len=32, burst=4,
                        mesh=mesh)
    assert srv.shardings is not None
    assert srv.run(_requests(cfg)) == ref


@pytest.mark.parametrize("arch", ["mamba2-780m"])
def test_recurrent_family_serves_on_mesh(arch):
    """The masked-scan prefill families serve on a mesh too (state shards
    slots over data; no row axis to protect). Token streams are NOT part of
    the bit-parity claim here: the mixer's d_inner contraction reassociates
    under tensor parallelism (partial-sum all-reduce), which moves SSM
    logits by more than the tiny random-init margins — recurrent mesh
    parity is a ROADMAP follow-on. The contract asserted: serving completes,
    budgets are exact, and the run is deterministic for a fixed mesh."""
    cfg, model, params = _setup(arch)
    mesh = _mesh((2, 2))
    out = BatchedServer(model, EXACT, params, slots=4, max_len=32, burst=4,
                        mesh=mesh).run(_requests(cfg))
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 6 for v in out.values())
    again = BatchedServer(model, EXACT, params, slots=4, max_len=32, burst=4,
                          mesh=mesh).run(_requests(cfg))
    assert again == out


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_adaptive_pinned_bit_identical_across_meshes(olmo, shape):
    """A pinned-controller sharded server (multi-point bank placed on the
    mesh, alias-preserving) reproduces static single-device serving."""
    from repro.runtime import (ControllerConfig, ModeController, build_bank,
                               default_points)

    cfg, model, params = olmo
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    want = BatchedServer(model, ctx, bank.tree("accurate"), slots=4,
                         max_len=32, burst=4,
                         prepare_weights=False).run(_requests(cfg))
    mesh = _mesh(shape)
    bank_m = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                        specs=model.specs(), mesh=mesh)
    ctrl = ModeController(bank_m, ControllerConfig(pin="accurate"))
    out = BatchedServer(model, ctx, params, slots=4, max_len=32, burst=4,
                        controller=ctrl, mesh=mesh).run(_requests(cfg))
    assert out == want


@pytest.mark.parametrize("shape", MESH_SHAPES)
def test_speculative_greedy_bit_identical_across_meshes(olmo, shape):
    """Sharded draft-k-then-verify rounds == accurate-only single-device
    serving (the cache donated through both jits at a pinned placement)."""
    from repro.runtime import build_bank, default_points
    from repro.spec import SpecConfig

    cfg, model, params = olmo
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    want = BatchedServer(model, ctx, bank.tree("accurate"), slots=4,
                         max_len=40, burst=4,
                         prepare_weights=False).run(_requests(cfg))
    mesh = _mesh(shape)
    bank_m = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                        specs=model.specs(), mesh=mesh)
    srv = BatchedServer(model, ctx, params, slots=4, max_len=40,
                        bank=bank_m, speculate=SpecConfig(draft_len=3),
                        mesh=mesh)
    assert srv.run(_requests(cfg)) == want
    assert srv.spec_telemetry.summary()["rounds"] > 0


def test_sampled_streams_identical_across_mesh_shapes(olmo):
    """temp > 0: mesh serving samples under partitionable threefry, so the
    stream depends on (seed, token index) — not on the mesh shape."""
    cfg, model, params = olmo
    outs = {}
    for shape in MESH_SHAPES:
        if NDEV < shape[0] * shape[1]:
            continue
        mesh = jax.make_mesh(shape, ("data", "model"))
        outs[shape] = BatchedServer(
            model, EXACT, params, slots=4, max_len=32, burst=4, mesh=mesh,
        ).run(_requests(cfg, max_new=8, temperature=1.3))
    assert len(outs) >= 1
    first = next(iter(outs.values()))
    assert all(o == first for o in outs.values())
    # sanity: the sampled stream actually diverges from greedy
    greedy = BatchedServer(model, EXACT, params, slots=4, max_len=32, burst=4,
                           mesh=jax.make_mesh((1, 1), ("data", "model")),
                           ).run(_requests(cfg, max_new=8))
    assert first != greedy


# ---------------------------------------------------------------------------
# placement + plumbing
# ---------------------------------------------------------------------------


def test_mesh_none_has_no_shardings(olmo):
    cfg, model, params = olmo
    srv = BatchedServer(model, EXACT, params, slots=2, max_len=16)
    assert srv.shardings is None and srv.mesh is None


def test_cache_and_state_placement(olmo):
    """Slots shard over data, the KV heads axis over model, and the S row
    axis is never split (decode's write index stays shard-local)."""
    cfg, model, params = olmo
    mesh = _mesh((2, 2))
    srv = BatchedServer(model, EXACT, params, slots=4, max_len=32, burst=4,
                        mesh=mesh)
    assert srv._state["tok"].sharding.spec[0] == ("data",)
    s_axis_sharded = []
    for leaf in jax.tree.leaves(srv.cache):
        spec = tuple(leaf.sharding.spec)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            if leaf.ndim >= 3 and i >= 2 and leaf.shape[i] == srv.max_len:
                s_axis_sharded.append((leaf.shape, spec))
    assert not s_axis_sharded
    # at least one cache leaf is model-sharded (the KV heads axis)
    assert any(
        "model" in [e for e in tuple(l.sharding.spec) if e is not None]
        for l in jax.tree.leaves(srv.cache)
    )


def test_bank_placement_preserves_aliasing(olmo):
    """place_bank puts each shared tensor once: layers whose (format, depth)
    agree between execution points stay single-copy on device."""
    from repro.core import PrecisionPolicy
    from repro.core.backends import PreparedWeight
    from repro.runtime import ExecutionPoint, build_bank

    cfg, model, params = olmo
    accurate = PrecisionPolicy.accurate(FXP16)
    # two points that agree everywhere except the mlp group: every other
    # prepared leaf must be shared (the memo guarantee build_bank asserts
    # on the host — here we assert it survives device placement)
    points = (
        ExecutionPoint("deep", accurate),
        ExecutionPoint("shallow-mlp", PrecisionPolicy(
            accurate.default,
            {"mlp": PrecisionPolicy.approximate(FXP16).default},
        )),
    )

    def pw_ids(tree):
        return {
            id(l) for l in jax.tree.leaves(
                tree, is_leaf=lambda x: isinstance(x, PreparedWeight))
            if isinstance(l, PreparedWeight)
        }

    host_bank = build_bank(params, "carmen", points, specs=model.specs())
    host_shared = set.intersection(*[pw_ids(host_bank.tree(n))
                                     for n in host_bank.names])
    assert len(host_shared) >= 1

    mesh = _mesh((2, 2))
    bank = build_bank(params, "carmen", points, specs=model.specs(), mesh=mesh)
    placed_shared = set.intersection(*[pw_ids(bank.tree(n))
                                       for n in bank.names])
    assert len(placed_shared) == len(host_shared)
    for name in bank.names:
        for leaf in jax.tree.leaves(bank.tree(name)):
            assert isinstance(leaf.sharding, jax.sharding.NamedSharding)


def test_serving_sharding_report(olmo):
    cfg, model, params = olmo
    mesh = _mesh((2, 2))
    srv = BatchedServer(model, EXACT, params, slots=4, max_len=32, mesh=mesh)
    rep = partition.serving_sharding_report(srv.shardings)
    assert rep["mesh"] == {"data": 2, "model": 2}
    assert rep["params"]["sharded"] >= 1
    assert set(rep) == {"mesh", "dropped", "params", "cache", "state"}
    for d in rep["dropped"]:  # every dropped rule names a non-dividing dim
        assert d["dim"] % d["extent"] != 0
    import json

    json.dumps(rep)  # the report is JSON-able for launch/serve + benchmarks


# ---------------------------------------------------------------------------
# make_host_mesh factoring
# ---------------------------------------------------------------------------


def test_make_host_mesh_factors_devices():
    mesh = make_host_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert mesh.axis_names == ("data", "model")
    assert sizes["data"] * sizes["model"] == NDEV
    # most-square split with model <= data: 1->1x1, 4->2x2, 8->4x2
    assert sizes["model"] ** 2 <= NDEV
    assert sizes["model"] == max(
        d for d in range(1, NDEV + 1) if NDEV % d == 0 and d * d <= NDEV
    )


def test_make_host_mesh_explicit_model():
    mesh = make_host_mesh(model=1)
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
        "data": NDEV, "model": 1,
    }
    if NDEV > 1:
        mesh = make_host_mesh(model=NDEV)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
            "data": 1, "model": NDEV,
        }
    bad = NDEV + 1
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(model=bad)
