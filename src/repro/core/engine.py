"""The CARMEN vector engine: one entry point for every matmul in the framework.

Model code never calls ``jnp.dot`` directly — it calls ``EngineContext.linear``
so that the CARMEN execution point (precision format x CORDIC depth) is a
runtime configuration, exactly like the silicon engine's configuration
registers (paper §II-C "control engine ... configuration registers for runtime
parameter tuning").

Execution modes
---------------
exact       FP32/bf16 matmul — the paper's FP32 baseline.
carmen      Paper-faithful simulation: activations fake-quantized to the FxP
            format, weights rounded to the depth-d signed-digit grid
            (= linear-CORDIC multiplier), single real matmul. Differentiable
            via straight-through estimator so QAT/finetuning works.
int8        Production TPU path (beyond-paper): real int8 x int8 -> int32
            ``dot_general`` (2x MXU rate on v5e), per-output-channel weight
            scales, dynamic per-tensor activation scale. CORDIC depth maps to
            effective weight bits by zeroing trailing bits of the int8 grid.
kernel      The Pallas ``cordic_mac`` kernel (tests / small shapes; same math
            as ``carmen``).

``depth`` may be a static int or a traced scalar (runtime-adaptive switching
between approximate/accurate without recompilation — the paper's key claim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .fxp import FXP8, FXP8_UNIT, FXP16, FXP16_UNIT, FxPFormat, dequantize, quantize
from .precision_policy import LayerPrecision, PrecisionPolicy

__all__ = ["EngineContext", "carmen_dot", "int8_dot", "sd_round_traced"]


def _unit_fmt(fmt: FxPFormat) -> FxPFormat:
    """Weight (multiplier-port) format paired with an activation format."""
    return FXP8_UNIT if fmt.bits <= 8 else FXP16_UNIT


def sd_round_traced(w, depth, w_fmt: FxPFormat):
    """signed_digit_round with a (possibly traced) depth: full-trip masked loop.

    Runtime-adaptive mode switching: the loop bound is static (full depth) but
    iterations beyond ``depth`` are masked out, so one compiled program serves
    every depth — the software analogue of the paper's "no hardware
    modification" claim.
    """
    z = jnp.round(jnp.asarray(w, jnp.float32) * (1 << w_fmt.frac)).astype(jnp.int32)
    z = jnp.clip(z, w_fmt.qmin, w_fmt.qmax)
    depth = jnp.asarray(depth, jnp.int32)
    full = cordic.full_depth(w_fmt)

    def body(k, carry):
        z, acc = carry
        active = k < depth
        d = jnp.where(z >= 0, jnp.int32(1), jnp.int32(-1))
        step = jnp.where(active, (jnp.int32(w_fmt.one) >> k) * d, 0)
        return (z - step, acc + step)

    _, acc = jax.lax.fori_loop(0, full, body, (z, jnp.zeros_like(z)))
    return acc.astype(jnp.float32) * np.float32(w_fmt.scale)


# --- carmen mode: fake-quant forward, straight-through backward -------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _carmen_matmul_ste(x, w, depth, x_fmt: FxPFormat, w_fmt: FxPFormat):
    xq = dequantize(quantize(x, x_fmt), x_fmt).astype(jnp.float32)
    wq = sd_round_traced(w, depth, w_fmt)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _carmen_fwd(x, w, depth, x_fmt, w_fmt):
    return _carmen_matmul_ste(x, w, depth, x_fmt, w_fmt), (x, w)


def _carmen_bwd(x_fmt, w_fmt, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    dx = jnp.dot(gf, w.astype(jnp.float32).T).astype(x.dtype)
    dw = jnp.dot(x.astype(jnp.float32).reshape(-1, x.shape[-1]).T,
                 gf.reshape(-1, g.shape[-1])).astype(w.dtype)
    return dx, dw, None


_carmen_matmul_ste.defvjp(_carmen_fwd, _carmen_bwd)


# --- int8 mode: real integer dot (MXU-rate path) -----------------------------


def int8_dot(x, w, *, effective_bits: int = 8, w_scale=None):
    """int8 x int8 -> int32 dot with per-output-channel weight scales.

    ``effective_bits < 8`` zeroes trailing bits of the weight grid — the int8
    incarnation of reduced CORDIC depth. ``w_scale`` may be precomputed
    (serving: weights stored quantized once).
    """
    xf = x.astype(jnp.float32)
    # per-token (per-row) dynamic activation scale — broadcasts over the N axis
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    x_scale = jnp.maximum(amax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(xf / x_scale), -127, 127).astype(jnp.int8)

    if w_scale is None:
        wf = w.astype(jnp.float32)
        w_scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=0, keepdims=True), 1e-8) / 127.0
        wq = jnp.clip(jnp.round(wf / w_scale), -127, 127).astype(jnp.int8)
    else:
        wq = w  # already int8
    if effective_bits < 8:
        drop = 8 - effective_bits
        wq = ((wq.astype(jnp.int32) >> drop) << drop).astype(jnp.int8)

    acc = jax.lax.dot_general(
        xq, wq, (((xq.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * x_scale * w_scale


# --- dispatch ----------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineContext:
    """Static engine configuration threaded through model code.

    Hashable (usable as a jit static argument). ``mode`` selects the execution
    path; ``policy`` supplies per-layer (fmt, depth).
    """

    mode: str = "exact"  # exact | carmen | int8 | kernel
    policy: Optional[PrecisionPolicy] = None
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention lowering: "xla" (query-chunked, scores materialize per chunk)
    # or "flash" (KV-chunked online softmax; pure-JAX twin of the Pallas
    # flash kernel — bit-tested against it; scores never exceed tile size)
    attn_impl: str = "xla"
    # emit dots in compute_dtype so TP partial-sums all-reduce in bf16
    # (Megatron-style; halves activation collective volume; MXU still
    # accumulates fp32 internally per tile)
    tp_reduce_bf16: bool = False

    def layer_precision(self, name: str) -> LayerPrecision:
        policy = self.policy or PrecisionPolicy.accurate(FXP8)
        return policy.for_layer(name)

    def dot(self, x, w, *, name: str = ""):
        """Matmul along the last axis of x / first of w, CARMEN-dispatched."""
        if self.mode == "exact":
            out_dt = self.compute_dtype if self.tp_reduce_bf16 else jnp.float32
            return jnp.dot(
                x.astype(self.compute_dtype),
                w.astype(self.compute_dtype),
                preferred_element_type=out_dt,
            ).astype(self.compute_dtype)
        if self.mode == "carmen":
            lp = self.layer_precision(name)
            shape = x.shape[:-1] + (w.shape[-1],)
            x2 = x.reshape(-1, x.shape[-1])
            out = _carmen_matmul_ste(x2, w, lp.depth, lp.fmt, _unit_fmt(lp.fmt))
            return out.reshape(shape).astype(self.compute_dtype)
        if self.mode == "int8":
            lp = self.layer_precision(name)
            eff_bits = max(2, min(8, int(np.ceil(lp.depth * 8 / cordic.full_depth(lp.fmt)))))
            return int8_dot(x, w, effective_bits=eff_bits).astype(self.compute_dtype)
        if self.mode == "kernel":
            from repro.kernels.cordic_mac import ops as mac_ops

            lp = self.layer_precision(name)
            x2 = x.reshape(-1, x.shape[-1])
            out = mac_ops.cordic_mac(
                x2, w, depth=int(lp.depth), x_fmt=lp.fmt, w_fmt=_unit_fmt(lp.fmt)
            )
            return out.reshape(x.shape[:-1] + (w.shape[-1],)).astype(self.compute_dtype)
        raise ValueError(f"unknown engine mode {self.mode!r}")

    def linear(self, x, w, b=None, *, name: str = ""):
        out = self.dot(x, w, name=name)
        if b is not None:
            out = out + b.astype(out.dtype)
        return out


def carmen_dot(x, w, depth, x_fmt: FxPFormat = FXP8, w_fmt: Optional[FxPFormat] = None):
    """Functional form of the carmen-mode matmul (used by benchmarks/tests)."""
    return _carmen_matmul_ste(x, w, depth, x_fmt, w_fmt or _unit_fmt(x_fmt))
