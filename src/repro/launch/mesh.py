"""Production mesh construction (spec'd shapes: 16x16 single-pod, 2x16x16 multi-pod).

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: Optional[int] = None):
    """(data, model) mesh over whatever devices exist locally.

    ``model=`` fixes the tensor-parallel extent (it must divide the local
    device count). By default the device count is factored into the most
    square (data, model) split with ``model <= data`` — 1 device -> 1x1,
    4 -> 2x2, 8 -> 4x2 — so local multi-device runs (e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) exercise tensor
    parallelism, not just data parallelism. ``model=1`` recovers the old
    pure-DP (n, 1) shape.
    """
    n = len(jax.devices())
    if model is None:
        model = max(d for d in range(1, n + 1) if n % d == 0 and d * d <= n)
    if model < 1 or n % model:
        raise ValueError(
            f"model={model} does not divide the {n} local devices"
        )
    return jax.make_mesh((n // model, model), ("data", "model"))
