"""CORDIC MAC engine: bit-faithful sim vs fast error model vs exact."""
import numpy as np
import pytest

from repro.core import (
    FXP8,
    FXP8_UNIT,
    FXP16,
    FXP16_UNIT,
    carmen_matmul_fast,
    cordic_dot,
    cordic_matmul,
    dequantize,
    full_depth,
    mac_cycles,
    quantize,
)


@pytest.mark.parametrize("fmt,w_fmt", [(FXP8, FXP8_UNIT), (FXP16, FXP16_UNIT)], ids=["fxp8", "fxp16"])
@pytest.mark.parametrize("k", [16, 64, 256])
def test_dot_error_scaling(fmt, w_fmt, k, rng):
    """K-length dot error <= K * (per-product bound); checks the accumulator is exact."""
    depth = full_depth(w_fmt)
    x = rng.uniform(-0.9, 0.9, (32, k)).astype(np.float32)
    w = rng.uniform(-0.9, 0.9, (32, k)).astype(np.float32)
    xq, wq = quantize(x, fmt), quantize(w, w_fmt)
    y = np.asarray(dequantize(cordic_dot(xq, wq, depth, w_fmt), fmt))
    true = np.sum(np.asarray(dequantize(xq, fmt)) * np.asarray(dequantize(wq, w_fmt)), -1)
    per_product = 0.9 * 2.0 ** (-(depth - 1)) + depth * fmt.scale
    assert np.max(np.abs(y - true)) <= k * per_product


def test_matmul_equals_dot(rng):
    """The scanned matmul is bit-exact to the per-row dot (chained accumulator)."""
    x = rng.uniform(-1, 1, (4, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 8)).astype(np.float32)
    xq, wq = quantize(x, FXP8), quantize(w, FXP8_UNIT)
    mm = np.asarray(cordic_matmul(xq, wq, 5, FXP8_UNIT))
    for j in range(8):
        dot = np.asarray(cordic_dot(xq, np.broadcast_to(np.asarray(wq)[:, j], (4, 32)), 5, FXP8_UNIT))
        assert np.array_equal(mm[:, j], dot)


@pytest.mark.parametrize("depth", [4, 7])
def test_fast_model_matches_bitexact(depth, rng):
    """carmen_matmul_fast deviates from the bit-faithful sim only by shift
    truncation: |dev| <= K * depth * LSB(x) (each iteration floors one shift)."""
    m, k, n = 8, 64, 16
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
    xq, wq = quantize(x, FXP8), quantize(w, FXP8_UNIT)
    bit = np.asarray(dequantize(cordic_matmul(xq, wq, depth, FXP8_UNIT), FXP8))
    fast = np.asarray(carmen_matmul_fast(x, w, depth, FXP8, FXP8_UNIT))
    assert np.max(np.abs(bit - fast)) <= k * depth * FXP8.scale


def test_relative_error_at_full_depth(rng):
    """End-to-end matmul relative error at FxP16 full depth is small (<1%)."""
    m, k, n = 16, 128, 32
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
    fast = np.asarray(carmen_matmul_fast(x, w, full_depth(FXP16_UNIT), FXP16, FXP16_UNIT))
    exact = x @ w
    rel = np.abs(fast - exact) / (np.abs(exact) + 1.0)
    assert np.max(rel) < 0.01


def test_cycles_model():
    assert mac_cycles(64, 7) == 64 * 8
    assert 1 - mac_cycles(64, 10) / mac_cycles(64, 15) == pytest.approx(0.3125)
