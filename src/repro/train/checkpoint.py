"""Checkpoint/restore with atomic manifests and elastic restore.

Layout (one directory per step)::

    ckpt_dir/
      step_000123.tmp/...      (in-flight writes)
      step_000123/
        manifest.json          {step, tree paths, shapes, dtypes, mesh_shape}
        leaf_00000.npy ...     one file per pytree leaf

Fault-tolerance properties:
* **atomic**: leaves are written into a ``.tmp`` dir which is renamed only
  after the manifest is fsync'd — a crash mid-save leaves the previous
  checkpoint intact and the partial dir ignorable.
* **elastic restore**: leaves are loaded host-side and ``device_put`` against
  whatever sharding tree the *current* mesh demands, so restarting on a
  different mesh shape (scale up/down) works without conversion. On a
  multi-host cluster each host materializes only its addressable shards
  (``device_put`` with NamedSharding does this); the save side would write
  per-host shard files — single-process here, API kept identical.
* **async**: ``save(..., background=True)`` snapshots to host memory
  synchronously (cheap) and writes in a thread, overlapping the next step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree, *, background: bool = False):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, treedef = _flatten_with_paths(tree)
    host = [np.asarray(x) for x in flat]  # snapshot (device -> host)
    treedef_str = str(treedef)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, arr in enumerate(host):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest = {
            "step": step,
            "num_leaves": len(host),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "treedef": treedef_str,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if background:
        t = threading.Thread(target=_write, daemon=False)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Load leaves and place them against ``shardings`` (elastic reshard)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree.flatten(like_tree)
    assert manifest["num_leaves"] == len(flat_like), "tree structure changed"
    leaves = [np.load(os.path.join(path, f"leaf_{i:05d}.npy")) for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    return jax.tree.unflatten(treedef, leaves)
