"""Deterministic, stateless synthetic data pipeline.

Batches are a pure function of (seed, step, shape): restart/skip-ahead costs
nothing (fault tolerance), no inter-host coordination is ever needed
(straggler mitigation — every host computes its own shard of the batch from
the step index alone), and elastic rescaling just changes the shard slicing.

Two generators:
* ``TokenPipeline``      — i.i.d.-ish Zipf tokens (markov-mixed so the LM loss
                           actually decreases) for LM train/serve cells;
* ``ClusterPipeline``    — Gaussian-cluster classification sets for the
                           paper's MLP/fig3 accuracy experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

    def batch(self, step: int, *, host_index: int = 0, host_count: int = 1) -> Dict:
        """The full (or this host's shard of the) batch for ``step``."""
        b = self.global_batch // host_count
        key = jax.random.fold_in(self._key(step), host_index)
        k1, k2, k3 = jax.random.split(key, 3)
        v = self.cfg.vocab_size
        # zipf-ish marginal via exponential transform of uniforms
        u = jax.random.uniform(k1, (b, self.seq_len + 1), minval=1e-6)
        toks = jnp.minimum((jnp.exp(-jnp.log(u) * 0.35) - 1) * 50, v - 1).astype(jnp.int32)
        # markov mixing: with p=0.5 copy the previous token (learnable structure)
        copy = jax.random.bernoulli(k2, 0.5, toks.shape)
        toks = jnp.where(copy, jnp.roll(toks, 1, axis=1), toks)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.frontend:
            batch["frontend_embeds"] = (
                0.02 * jax.random.normal(k3, (b, self.cfg.frontend_tokens, self.cfg.d_model))
            ).astype(jnp.float32)
        return batch


@dataclasses.dataclass(frozen=True)
class ClusterPipeline:
    """Gaussian clusters for the paper's 196-64-32-32-10 MLP experiments."""

    n_features: int = 196
    n_classes: int = 10
    seed: int = 0
    spread: float = 2.2

    def dataset(self, n: int):
        rng = np.random.default_rng(self.seed)
        centers = rng.normal(0, self.spread, (self.n_classes, self.n_features))
        y = rng.integers(0, self.n_classes, n)
        x = centers[y] + rng.normal(0, 1.0, (n, self.n_features))
        # normalize into FxP-friendly range [-2, 2)
        x = np.clip(x / (np.abs(x).max() / 1.9), -1.99, 1.99)
        return x.astype(np.float32), y.astype(np.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, abstract: bool = True):
    """ShapeDtypeStruct stand-ins for every model input of a cell (dry-run)."""
    b, s = shape.global_batch, shape.seq_len

    def sds(shp, dt=jnp.int32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s)), "targets": sds((b, s))}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
    else:  # decode: one new token against a seq_len-deep cache
        batch = {"tokens": sds((b, 1))}
    if cfg.frontend and shape.kind != "decode":
        batch["frontend_embeds"] = sds((b, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio" and shape.kind != "decode":
        # encoder frames (stub frontend): (B, T, d_model)
        t = int(s * cfg.encdec.encoder_seq_factor)
        batch["frontend_embeds"] = sds((b, t, cfg.d_model), jnp.float32)
    return batch
