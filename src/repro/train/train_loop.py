"""Training step construction: loss, microbatching, remat, CARMEN modes.

``make_train_step`` returns the pure function the launcher jits (and the
dry-run lowers). Distribution is entirely in the in/out shardings + GSPMD;
the step itself is mesh-agnostic.

Fault-tolerance posture (DESIGN.md §6): the step is deterministic given
(params, opt_state, batch, step) — combined with the stateless data pipeline
(batch derived from the step index) a restarted worker replays identically,
and checkpoint/restore (train/checkpoint.py) carries the rest.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext, PreparedWeight
from repro.models import ModelApi

from . import optimizer as opt


def _check_trainable(params):
    """QAT trains raw float weights through the traced per-call quantization
    path; prepared weight banks (``prepare_params``) are inference-only."""
    leaves = jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, PreparedWeight))
    if any(isinstance(l, PreparedWeight) for l in leaves):
        raise ValueError(
            "train_step received prepared weight banks — training (QAT) "
            "requires raw float params; prepare_params is for inference "
            "(use make_eval_step to evaluate prepared trees)"
        )


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    microbatches: int = 1  # gradient accumulation steps inside one train_step
    remat: bool = True
    lb_loss_weight: float = 0.01  # MoE load-balance aux
    z_loss_weight: float = 1e-4  # logit z-loss (stabilizes large-vocab training)


def cross_entropy(logits, targets, *, z_loss_weight: float = 0.0):
    """Mean CE over all positions; fp32; optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - true_logit).mean()
    if z_loss_weight:
        nll = nll + z_loss_weight * jnp.square(lse).mean()
    return nll


def make_loss_fn(model: ModelApi, ctx: EngineContext, tcfg: TrainConfig):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, ctx, remat=tcfg.remat)
        targets = batch["targets"]
        logits = logits[:, -targets.shape[1] :]  # frontend positions carry no loss
        loss = cross_entropy(logits, targets, z_loss_weight=tcfg.z_loss_weight)
        if cfg.moe:
            loss = loss + tcfg.lb_loss_weight * aux.get("lb_loss", 0.0)
        return loss, {"ce_loss": loss}

    return loss_fn


def make_train_step(model: ModelApi, ctx: EngineContext, tcfg: TrainConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``microbatches > 1`` the global batch is split along axis 0 and
    accumulated with a ``lax.scan`` (per-microbatch grads never coexist).
    """
    loss_fn = make_loss_fn(model, ctx, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        _check_trainable(params)
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            batches = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                (loss, metrics), grads = grad_fn(params, mbatch)
                carry_loss, carry_grads = carry
                new_grads = jax.tree.map(jnp.add, carry_grads, grads)
                return (carry_loss + loss, new_grads), None

            zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zero_grads), batches)
            loss = loss_sum / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = {"ce_loss": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        params, opt_state, om = opt.apply_updates(params, grads, opt_state, tcfg.optimizer)
        metrics = dict(metrics, **om, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: ModelApi, ctx: EngineContext,
                   tcfg: Optional[TrainConfig] = None):
    """(params, batch) -> metrics; gradient-free, so prepared weight banks
    (``prepare_params``) evaluate on their serving fast path."""
    loss_fn = make_loss_fn(model, ctx, tcfg or TrainConfig(remat=False))

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step
