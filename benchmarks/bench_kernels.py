"""Fused vs unfused CORDIC decode path: tok/s, per-layer kernel time, parity.

Two gates ride along with the numbers (exit nonzero on violation):

* **bit-identity** — greedy decode through the fused dot+AF path must equal
  the unfused prepared-XLA chain token for token (and margin for margin);
* **zero recompiles across a mode switch** — an adaptive kernel-mode bank
  under forced switching must serve every execution point from ONE compiled
  burst program (the params vector carries depth/format as data).

Speed numbers are honest for the platform they ran on: on CPU the "fused"
path runs the Pallas kernel in interpret mode, so the XLA fallback usually
wins — the record is the parity/compile-count evidence plus a per-layer
kernel microbenchmark; the tok/s comparison becomes meaningful on TPU.

CI runs ``--smoke`` and uploads ``BENCH_kernels.json``.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    make_requests,
    timed,
)
from repro.core import EngineContext, PrecisionPolicy
from repro.core.fxp import FXP8
from repro.serve.engine import BatchedServer


def _serve(model, ctx, params, reqs, *, slots, max_len, burst):
    # both contenders carry the same metrics-only observer, so the fused/
    # unfused tok/s comparison stays fair and the record gets SLO latency
    server = BatchedServer(model, ctx, params, slots=slots, max_len=max_len,
                           burst=burst)
    attach_observer(server)
    out = server.run(reqs)
    return out, [r.margins for r in reqs], server


def _layer_microbench(d_model: int, d_ff: int, interpret_fused: bool):
    """One MLP gate layer (dot + gelu): fused single pass vs unfused chain."""
    from repro.core import cordic
    from repro.kernels.cordic_af.ops import multi_af_pallas
    from repro.kernels.cordic_fused import fused_dot_af, make_point
    from repro.kernels.cordic_mac import ops as mac_ops

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, d_model)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d_model, d_ff)).astype(np.float32) * 0.1)
    depth = 5
    sd = cordic.signed_digit_round(w, depth, FXP8)
    point = make_point(depth, FXP8, FXP8)

    t_fused, _ = timed(lambda: fused_dot_af(
        x, sd, point, af_mode="gelu", af_depth=8, af_fmt=FXP8,
        interpret=interpret_fused,
    ))
    t_unfused, _ = timed(lambda: multi_af_pallas(
        mac_ops.cordic_mac(x, sd, depth=depth, x_fmt=FXP8, w_fmt=FXP8,
                           w_prequantized=True),
        "gelu", depth=8, fmt=FXP8,
    ))
    return {"fused_us": round(t_fused * 1e6, 1),
            "unfused_us": round(t_unfused * 1e6, 1)}


def _mode_switch_record(model, cfg, params, ctx):
    """Adaptive bank under forced switching: compile-count assertion."""
    from repro.runtime import (
        ControllerConfig, ModeController, build_bank, default_points,
    )

    bank = build_bank(params, "kernel", default_points(FXP8),
                      specs=model.specs())
    ctrl = ModeController(bank, ControllerConfig(margin_demote=0.5,
                                                 hysteresis=1))
    srv = BatchedServer(model, ctx, params, slots=2, max_len=32, burst=2,
                        controller=ctrl)
    srv.run(make_requests(cfg, 2, prompt_len=4, max_new=8))
    tele = srv.telemetry.summary()
    compiles = {k: fn._cache_size() for k, fn in srv._burst_fns.items()}
    return {
        "switches": tele["switches"],
        "steps_by_point": tele["steps_by_point"],
        "burst_compiles": compiles,
    }


def main(argv=None):
    args = bench_parser(
        "fused vs unfused CORDIC decode path",
        default_out="BENCH_kernels.json",
    ).parse_args(argv)
    n, max_new, burst = (2, 4, 2) if args.smoke else (4, 16, 4)
    max_len = 32

    cfg, model, params = load_model(args.arch, full_size=args.full_size)
    base = EngineContext(mode="kernel", policy=PrecisionPolicy.accurate(FXP8),
                         compute_dtype=jnp.float32)

    results = {}
    for fused in ("off", "on"):
        ctx = dataclasses.replace(base, fused=fused)
        reqs = make_requests(cfg, n, prompt_len=4, max_new=max_new)
        secs, (out, margins, srv) = timed(lambda: _serve(
            model, ctx, params, reqs, slots=2, max_len=max_len, burst=burst,
        ))
        tokens = sum(len(v) for v in out.values())
        results[fused] = {
            "out": out,
            "margins": margins,
            "decode_tok_s": round(tokens / secs, 2),
            "latency": latency_block(srv.observer),
        }

    bit_identical = results["on"]["out"] == results["off"]["out"] and all(
        np.array_equal(a, b)
        for a, b in zip(results["on"]["margins"], results["off"]["margins"])
    )

    switch = _mode_switch_record(model, cfg, params, base)

    record = base_record(
        args,
        mode="kernel",
        fmt="fxp8",
        burst=burst,
        max_new=max_new,
        fused_decode_tok_s=results["on"]["decode_tok_s"],
        unfused_decode_tok_s=results["off"]["decode_tok_s"],
        bit_identical=bit_identical,
        latency=results["on"]["latency"],
        layer_kernel=_layer_microbench(cfg.d_model, cfg.d_ff,
                                       interpret_fused=None),
        mode_switch=switch,
    )
    emit_record(record, args.out)

    if not bit_identical:
        print("FAIL: fused decode path diverged from the prepared XLA chain",
              file=sys.stderr)
        return 1
    if any(c != 1 for c in switch["burst_compiles"].values()):
        print(f"FAIL: mode switch recompiled the burst program "
              f"({switch['burst_compiles']})", file=sys.stderr)
        return 1
    if switch["switches"] < 1:
        print("FAIL: controller never switched; compile-count assertion is "
              "vacuous", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
