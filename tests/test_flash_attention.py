"""Flash-attention kernel + pure-JAX twin: sweeps vs the naive oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.blocks import _sdpa_chunked, _sdpa_flash_xla

CASES = [
    # b, sq, sk, h, kv, d, causal
    (2, 128, 128, 4, 2, 32, True),
    (1, 256, 256, 2, 2, 64, True),
    (2, 64, 64, 4, 1, 16, False),
    (1, 96, 96, 3, 3, 32, True),
    (1, 64, 64, 8, 8, 128, True),
]


def _ref(q, k, v, causal):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    kb = np.repeat(k, g, 2) if g > 1 else k
    vb = np.repeat(v, g, 2) if g > 1 else v
    out = attention_ref(
        jnp.asarray(q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)),
        jnp.asarray(kb.transpose(0, 2, 1, 3).reshape(b * h, -1, d)),
        jnp.asarray(vb.transpose(0, 2, 1, 3).reshape(b * h, -1, d)),
        causal=causal,
    )
    return np.asarray(out).reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,sq,sk,h,kv,d,causal", CASES)
def test_kernel_matches_ref(b, sq, sk, h, kv, d, causal, rng):
    q = rng.standard_normal((b, sq, h, d)).astype(np.float32)
    k = rng.standard_normal((b, sk, kv, d)).astype(np.float32)
    v = rng.standard_normal((b, sk, kv, d)).astype(np.float32)
    out = np.asarray(flash_attention(q, k, v, causal=causal, bq=32, bk=32))
    np.testing.assert_allclose(out, _ref(q, k, v, causal), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (64, 32)])
def test_kernel_block_shape_invariance(blocks, rng):
    bq, bk = blocks
    q = rng.standard_normal((1, 128, 2, 32)).astype(np.float32)
    k = rng.standard_normal((1, 128, 2, 32)).astype(np.float32)
    v = rng.standard_normal((1, 128, 2, 32)).astype(np.float32)
    a = np.asarray(flash_attention(q, k, v, bq=bq, bk=bk))
    b_ = np.asarray(flash_attention(q, k, v, bq=128, bk=128))
    np.testing.assert_allclose(a, b_, atol=3e-5, rtol=1e-4)


def test_flash_xla_twin_matches_kernel(rng):
    """The pure-JAX lowering used for dry-run measurement == Pallas kernel.

    All H-layout: sdpa fns take KV pre-repeated to H (see blocks.attention)."""
    b, s, kvh, g, hd = 2, 128, 2, 2, 32
    h = kvh * g
    q = rng.standard_normal((b, s, h, hd)).astype(np.float32)
    k = rng.standard_normal((b, s, kvh, hd)).astype(np.float32)
    v = rng.standard_normal((b, s, kvh, hd)).astype(np.float32)
    kr, vr = np.repeat(k, g, 2), np.repeat(v, g, 2)
    pos = jnp.arange(s)
    twin = np.asarray(_sdpa_flash_xla(jnp.asarray(q), jnp.asarray(kr), jnp.asarray(vr),
                                      pos, pos, True, q_chunk=32, k_chunk=32))
    kern = np.asarray(flash_attention(q, k, v, causal=True, bq=32, bk=32))
    np.testing.assert_allclose(twin, kern, atol=3e-5, rtol=1e-4)
    base = np.asarray(_sdpa_chunked(jnp.asarray(q), jnp.asarray(kr), jnp.asarray(vr),
                                    pos, pos, True))
    np.testing.assert_allclose(twin, base, atol=3e-5, rtol=1e-4)


def test_fully_masked_rows_zero(rng):
    """Non-causal query with zero valid keys can't happen, but causal row 0
    sees exactly one key; degenerate l==0 guard shouldn't produce NaNs."""
    q = rng.standard_normal((1, 32, 1, 16)).astype(np.float32)
    k = rng.standard_normal((1, 32, 1, 16)).astype(np.float32)
    v = rng.standard_normal((1, 32, 1, 16)).astype(np.float32)
    out = np.asarray(flash_attention(q, k, v, causal=True, bq=16, bk=16))
    assert np.isfinite(out).all()
