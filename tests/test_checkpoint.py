"""Checkpoint/restore: roundtrip, atomicity, latest-step discovery, elastic placement."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 7, t)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    r = checkpoint.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_background_save(tmp_path):
    t = _tree()
    th = checkpoint.save(str(tmp_path), 3, t, background=True)
    th.join()
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_partial_save_ignored(tmp_path):
    """A crash mid-save (tmp dir, no manifest) must not corrupt discovery."""
    checkpoint.save(str(tmp_path), 5, _tree())
    os.makedirs(tmp_path / "step_00000009.tmp")
    (tmp_path / "step_00000009.tmp" / "leaf_00000.npy").write_bytes(b"junk")
    os.makedirs(tmp_path / "step_00000010")  # no manifest -> ignored
    assert checkpoint.latest_step(str(tmp_path)) == 5


def test_multiple_steps_latest_wins(tmp_path):
    for s in (1, 2, 30):
        checkpoint.save(str(tmp_path), s, _tree(s))
    assert checkpoint.latest_step(str(tmp_path)) == 30
    r = checkpoint.restore(str(tmp_path), 30, _tree())
    np.testing.assert_array_equal(
        np.asarray(r["a"]), np.asarray(_tree(30)["a"])
    )


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places leaves against a (different) mesh's shardings."""
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    r = checkpoint.restore(str(tmp_path), 1, t, shardings=sh)
    assert all(
        isinstance(x.sharding, NamedSharding) for x in jax.tree.leaves(r)
    )
