"""mamba2-780m [arXiv:2405.21060; unverified] — pure SSD (state-space duality),
attention-free. d_inner = 2*1536 = 3072, 48 SSD heads of dim 64, state 128."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    norm_type="rmsnorm",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    subquadratic=True,
)
