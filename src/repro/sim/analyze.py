"""Attribution reports over a :class:`repro.sim.replay.ReplayResult`.

Two renderings of one replay:

* :func:`report_dict` — the full structured report (JSON-able): totals,
  phase/point/layer/request attribution, predicted-vs-reported savings, and
  the per-point predicted-vs-measured comparison rows.
* :func:`render` — the human-readable table (what
  ``python -m repro.sim.replay trace.jsonl --report`` prints).

Plus the two checks ``bench_sim`` gates on:

* :func:`ordering_inversions` — per-config (or per-point) predicted cycle
  ordering vs measured wall ordering. Only pairs whose *predicted* costs
  differ by more than ``margin`` are comparable — CPU-measured near-ties
  (the fast error-model's wall time barely depends on depth) are excluded
  rather than letting scheduler noise flip a gate.
* :func:`savings_drift` — relative divergence of the simulator's
  ``est_cycle_savings_frac`` from the serving loop's reported value.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .replay import ReplayResult

__all__ = ["ordering_inversions", "render", "report_dict", "savings_drift"]


def report_dict(result: ReplayResult) -> Dict:
    """The full structured replay report (stable JSON shape)."""
    points = {}
    for name, acc in sorted(result.points.items()):
        steps = max(acc["steps"], 1)
        points[name] = dict(
            acc,
            cycles_per_step=acc["cycles"] / steps,
            measured_wall_s_per_step=(acc["wall_s"] / steps
                                      if acc["wall_s"] else None),
        )
    return {
        "meta": result.meta,
        "array": result.config,
        "totals": result.totals,
        "phases": result.phases,
        "points": points,
        "layers": dict(sorted(result.layers.items(),
                              key=lambda kv: -kv[1])),
        "requests": result.requests,
        "counts": result.counts,
        "savings": result.savings,
        "measured": result.measured,
    }


def savings_drift(result: ReplayResult) -> Optional[float]:
    """|simulated - reported| / |reported| savings fraction (None when the
    trace carries no adaptive telemetry record to compare against)."""
    return result.savings.get("rel_diff_vs_reported")


def ordering_inversions(rows: Sequence[Tuple[str, float, Optional[float]]],
                        *, margin: float = 0.10,
                        measured_margin: float = 0.03) -> List[Dict]:
    """Predicted-vs-measured ordering check over ``(name, predicted,
    measured)`` rows (predicted in cycles, measured in seconds — any
    monotone units).

    Returns one record per *inverted comparable pair*: a pair is comparable
    only when both sides show signal — predicted costs differ by more than
    ``margin`` (relative) AND measured costs differ by more than
    ``measured_margin`` (the wall-clock noise floor: the ordering of a
    measured near-tie is scheduler noise, not information). Pairs without a
    measurement are skipped.
    """
    inversions = []
    usable = [(n, p, m) for n, p, m in rows if m is not None and p > 0]
    for i in range(len(usable)):
        for j in range(i + 1, len(usable)):
            (na, pa, ma), (nb, pb, mb) = usable[i], usable[j]
            if abs(pa - pb) / max(pa, pb) <= margin:
                continue  # predicted near-tie: not comparable vs noise
            if abs(ma - mb) / max(ma, mb, 1e-12) <= measured_margin:
                continue  # measured near-tie: ordering is noise
            if (pa < pb) != (ma < mb):
                inversions.append({
                    "pair": [na, nb],
                    "predicted": [pa, pb],
                    "measured": [ma, mb],
                })
    return inversions


def _fmt_cycles(c: float) -> str:
    if c >= 1e9:
        return f"{c / 1e9:.2f}G"
    if c >= 1e6:
        return f"{c / 1e6:.2f}M"
    if c >= 1e3:
        return f"{c / 1e3:.1f}k"
    return f"{c:.0f}"


def render(result: ReplayResult, *, top_layers: int = 10) -> str:
    """The human-readable attribution table."""
    t = result.totals
    lines = []
    meta = result.meta
    lines.append("== PE-array replay "
                 f"({result.config['n_pes']} PEs, "
                 f"mode={meta.get('mode')}, family={meta.get('family')}, "
                 f"slots={meta.get('slots')}, burst={meta.get('burst')}) ==")
    occ = t["pe_occupancy"]
    lines.append(
        f"total {_fmt_cycles(t['total_cycles'])} cycles "
        f"(array {_fmt_cycles(t['array_cycles'])}, "
        f"host idle {_fmt_cycles(t['host_sync_cycles'])}) | "
        f"PE occupancy {occ:.1%} | "
        f"AF stalls {_fmt_cycles(t['af_stall_cycles'])} | "
        f"weight stalls {_fmt_cycles(t['weight_stall_cycles'])}")
    if t.get("predicted_wall_s") is not None:
        m = result.measured
        wall = f"predicted wall {t['predicted_wall_s'] * 1e3:.1f}ms"
        if m.get("wall_s"):
            wall += f" vs measured {m['wall_s'] * 1e3:.1f}ms"
        lines.append(wall)

    lines.append("-- where cycles go (phase) --")
    total = max(t["total_cycles"], 1e-12)
    for phase, cyc in sorted(result.phases.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {phase:<12} {_fmt_cycles(cyc):>10}  "
                     f"{cyc / total:6.1%}")

    lines.append("-- execution points (predicted vs measured per step) --")
    for name, acc in sorted(result.points.items(),
                            key=lambda kv: -kv[1]["cycles"]):
        steps = max(acc["steps"], 1)
        meas = (f"{acc['wall_s'] / steps * 1e3:8.2f}ms/step"
                if acc["wall_s"] else "        --")
        lines.append(
            f"  {name:<10} {_fmt_cycles(acc['cycles']):>10} cycles  "
            f"{_fmt_cycles(acc['cycles'] / steps):>9}/step  {meas}  "
            f"({acc['spans']} spans, {acc['tokens']} tokens)")

    sav = result.savings
    lines.append("-- savings vs reference "
                 f"({sav.get('reference')}) --")

    def _savings_line(label: str, s: Dict) -> str:
        line = (f"  {label}: simulated est_cycle_savings_frac="
                f"{s['est_cycle_savings_frac']:.4f}")
        if s.get("reported") is not None:
            line += (f"  reported="
                     f"{s['reported']['est_cycle_savings_frac']:.4f}")
            if s.get("rel_diff_vs_reported") is not None:
                line += f"  rel_diff={s['rel_diff_vs_reported']:.3f}"
        return line

    lines.append(_savings_line("adaptive", sav))
    if sav.get("speculative"):
        lines.append(_savings_line("speculative", sav["speculative"]))

    lines.append(f"-- top {top_layers} layers --")
    ranked = sorted(result.layers.items(), key=lambda kv: -kv[1])
    array_total = max(t["array_cycles"], 1e-12)
    for name, cyc in ranked[:top_layers]:
        lines.append(f"  {name:<28} {_fmt_cycles(cyc):>10}  "
                     f"{cyc / array_total:6.1%}")

    lines.append("-- requests --")
    for rid, req in sorted(result.requests.items(),
                           key=lambda kv: -kv[1]["cycles"]):
        lines.append(
            f"  rid={rid:<4} tokens={req['tokens']:<5} "
            f"cycles={_fmt_cycles(req['cycles']):>10}")
    return "\n".join(lines)
