"""Execution-backend protocol + the prepared-weight container.

CARMEN's silicon quantizes nothing at runtime: weights sit in the PE array
pre-formatted and only the CORDIC iteration depth changes between modes. Each
software backend mirrors that split with two entry points:

* ``prepare(w, lp)``   — one-time weight-bank formatting (signed-digit grids,
  int8 qvalues + per-channel scales, ...). Returns a :class:`PreparedWeight`
  whose payload replaces the float leaf in the param tree.
* ``dot(ctx, x, w)``   — the per-call matmul. Given a raw float leaf it runs
  the traced per-call path (QAT / training); given a :class:`PreparedWeight`
  it performs **zero** weight-side rounding or scale computation.

:class:`PreparedWeight` is a registered pytree, so prepared param trees flow
through ``jit`` / ``lax.scan`` (stacked layer banks) / sharding unchanged, and
it mimics enough of the array surface (``shape``/``ndim``/``reshape``) that
model code calling ``ctx.linear`` never notices the substitution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..fxp import FXP8_UNIT, FXP16_UNIT, FxPFormat

__all__ = ["Backend", "PreparedWeight", "unit_fmt"]


def unit_fmt(fmt: FxPFormat) -> FxPFormat:
    """Weight (multiplier-port) format paired with an activation format."""
    return FXP8_UNIT if fmt.bits <= 8 else FXP16_UNIT


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedWeight:
    """One prepared weight-bank leaf.

    ``data`` is the backend payload (signed-digit-rounded float32 grid for
    carmen/kernel, int8 qvalues for int8); ``scale`` is the per-output-channel
    dequantization scale (int8 only, keepdims shape ``(..., 1, C)``); ``meta``
    is a hashable tuple of (key, value) pairs recording the preparation point
    (depth / format / effective bits) — it travels as pytree aux data, so a
    prepared tree re-specializes jit programs when the preparation changes.
    ``point`` is the opposite: a small *traced* int32 params vector (kernel
    backend) carrying per-execution-point values (dot depth, quantization
    formats) as data, so switching points swaps arrays instead of programs.
    """

    data: Any
    scale: Any = None
    backend: str = "exact"
    meta: Tuple[Tuple[str, Any], ...] = ()
    point: Any = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        # ``point`` (the kernel backend's runtime params vector) is a CHILD,
        # not aux data: execution points that differ only in depth/format
        # share one treedef, so a ModeController switch swaps arrays without
        # retracing jitted serving programs.
        return (self.data, self.scale, self.point), (self.backend, self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale, point = children
        backend, meta = aux
        return cls(data, scale, backend, meta, point)

    # -- array-ish surface (what model code touches before ctx.dot) ---------
    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def dtype(self):
        return self.data.dtype

    def get(self, key, default=None):
        """meta lookup, e.g. ``w.get("depth")``."""
        return dict(self.meta).get(key, default)

    def reshape(self, *shape):
        """Reshape the payload, carrying the per-channel scale along.

        Model code reshapes weights into 2D matmul form (e.g. ``(D, H, hd) ->
        (D, H*hd)``). The scale keeps its keepdims per-last-channel layout: a
        plain reshape when the channel axis survives, a broadcast-then-reshape
        when trailing axes fold into it (scale stays constant along the
        contraction axis either way, which is what the int8 factoring needs).
        """
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        data = self.data.reshape(shape)
        scale = self.scale
        if scale is not None:
            if data.shape[-1] == self.data.shape[-1]:
                scale = scale.reshape((1,) * (data.ndim - 1) + (scale.shape[-1],))
            elif data.shape[0] == self.data.shape[0]:
                full = jnp.broadcast_to(scale, (1,) + self.data.shape[1:])
                scale = full.reshape((1,) + tuple(data.shape[1:]))
            else:
                raise ValueError(
                    f"cannot reshape per-channel scale {self.scale.shape} for "
                    f"{self.data.shape} -> {data.shape}"
                )
        return PreparedWeight(data, scale, self.backend, self.meta, self.point)

    def placement(self, data_sharding):
        """Sharding container mirroring this leaf, for device_put / jit.

        The payload takes ``data_sharding`` (the raw leaf's rule-derived
        sharding — signed-digit grids and int8 qvalues keep the float
        tensor's shape). The per-channel scale is keepdims-shaped: every axis
        it shares with the payload (stacked-layer leading axes, the output
        channel axis) inherits that axis's entry, size-1 keepdims axes
        replicate — so the scale slices alongside the qvalues inside
        ``lax.scan`` and broadcasts against a model-sharded output channel
        without a gather. Returns a :class:`PreparedWeight` of shardings with
        identical aux data, so it is treedef-compatible with this leaf.
        """
        from jax.sharding import NamedSharding, PartitionSpec

        scale_sh = None
        if self.scale is not None:
            entries = tuple(data_sharding.spec)
            entries = entries + (None,) * (self.data.ndim - len(entries))
            spec = [
                entries[i] if self.scale.shape[i] == self.data.shape[i] else None
                for i in range(self.scale.ndim)
            ]
            while spec and spec[-1] is None:
                spec.pop()
            scale_sh = NamedSharding(data_sharding.mesh, PartitionSpec(*spec))
        point_sh = None
        if self.point is not None:
            # the params vector is tiny and read by every shard: replicate
            point_sh = NamedSharding(data_sharding.mesh, PartitionSpec())
        return PreparedWeight(
            data_sharding, scale_sh, self.backend, self.meta, point_sh
        )

    @property
    def T(self):
        if self.scale is not None:
            raise ValueError(
                "transposing an int8 prepared weight would move the channel "
                "scale onto the contraction axis; prepare the transposed "
                "tensor instead (prepare_params does this for tied lm_head)"
            )
        return PreparedWeight(self.data.T, None, self.backend, self.meta,
                              self.point)


class Backend:
    """One execution mode of the engine. Subclasses register themselves."""

    name: str = "?"

    def prepare(self, w, lp, *, stacked_axes: int = 0, in_axes: Optional[int] = None):
        """Format one weight leaf for serving; default is pass-through.

        ``stacked_axes`` counts leading stacked-layer axes (scan banks);
        ``in_axes`` counts the matmul contraction axes that follow them
        (backends with per-channel scales reduce over exactly those).
        """
        return w

    def dot(self, ctx, x, w, *, name: str = ""):
        """Matmul along the last axis of x / first of w."""
        raise NotImplementedError
