"""Per-architecture smoke tests (reduced configs, CPU) + consistency checks.

The decode-vs-forward check is the strongest invariant here: step-by-step
decoding with KV caches / SSM states must reproduce the teacher-forced forward
logits. For MoE archs the comparison uses a large capacity factor because
capacity drops are a train-time-only effect (decode never drops) — standard
capacity-MoE semantics, verified bit-consistent once drops are removed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.core import EngineContext, FXP8, PrecisionPolicy
from repro.models import get_model

ALL_ARCHS = sorted(ARCHS)
CTX = EngineContext(mode="exact", compute_dtype=jnp.float32)


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend_embeds"] = (
            jax.random.normal(key, (b, cfg.frontend_tokens, cfg.d_model)) * 0.02
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch, key):
    cfg = reduced(get_config(arch))
    m = get_model(cfg)
    prms = m.init(key)
    batch = _batch(cfg, key)
    logits, aux = m.forward(prms, batch, CTX)
    expect_s = 16 + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_grad(arch, key):
    """One backward pass: grads exist, are finite, and are nonzero somewhere."""
    cfg = reduced(get_config(arch))
    m = get_model(cfg)
    prms = m.init(key)
    batch = _batch(cfg, key)
    targets = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = m.forward(p, batch, CTX, remat=True)
        logits = logits[:, -targets.shape[1] :]
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, targets[..., None], axis=-1).mean()
        return nll + 0.01 * aux.get("lb_loss", 0.0)

    loss, grads = jax.value_and_grad(loss_fn)(prms)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    assert any(np.abs(np.asarray(g)).max() > 0 for g in leaves)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = reduced(get_config(arch))
    if cfg.moe:  # remove capacity drops (train-only effect) for exact comparison
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = get_model(cfg)
    prms = m.init(key)
    b, s = 2, 16
    batch = _batch(cfg, key, b, s)
    full_logits, _ = m.forward(prms, batch, CTX)

    cache = m.make_cache(b, s, dtype=jnp.float32)
    if cfg.family == "audio":
        from repro.models import encdec

        enc = encdec.encode(prms, batch["frontend_embeds"], cfg, CTX)
        cache["cross"] = encdec.prefill_cross_kv(prms, enc, cfg, CTX)
    elif cfg.frontend == "vision":
        pytest.skip("vlm decode requires image-prefill path (covered in serve tests)")

    outs = []
    for t in range(s):
        lg, cache = m.decode_step(prms, batch["tokens"][:, t : t + 1], cache, CTX)
        outs.append(np.asarray(lg[:, 0]))
    step_logits = np.stack(outs, 1)
    ref = np.asarray(full_logits[:, -s:])
    np.testing.assert_allclose(step_logits, ref, atol=5e-5, rtol=1e-4)


def test_carmen_mode_forward_close_to_exact(key):
    """The paper's claim C1 at model level: CARMEN FxP16 execution reproduces
    the exact baseline's argmax (FxP8 checked for finiteness only — a
    random-init model's near-uniform logits make FxP8 argmax flaky; the
    trained-model FxP8 claim is benchmarks/fig3)."""
    from repro.core import FXP16

    cfg = reduced(get_config("olmo-1b"))
    m = get_model(cfg)
    prms = m.init(key)
    batch = _batch(cfg, key)
    exact, _ = m.forward(prms, batch, CTX)
    ctx16 = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16), compute_dtype=jnp.float32)
    carmen16, _ = m.forward(prms, batch, ctx16)
    agree = (np.asarray(exact).argmax(-1) == np.asarray(carmen16).argmax(-1)).mean()
    assert agree > 0.9, agree
    ctx8 = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8), compute_dtype=jnp.float32)
    carmen8, _ = m.forward(prms, batch, ctx8)
    assert np.isfinite(np.asarray(carmen8)).all()


def test_moe_load_balance_loss_present(key):
    cfg = reduced(get_config("deepseek-v3-671b"))
    m = get_model(cfg)
    prms = m.init(key)
    _, aux = m.forward(prms, _batch(cfg, key), CTX)
    assert float(aux["lb_loss"]) > 0


def test_moe_dispatch_plan_properties(key):
    """Every expert queue slot is either valid+unique or masked."""
    from repro.models.blocks import _dispatch_indices

    e, s, k, cap = 4, 32, 2, 10
    idx = jax.random.randint(key, (s, k), 0, e)
    gather_idx, valid, rank = _dispatch_indices(idx, e, cap)
    gi, va = np.asarray(gather_idx), np.asarray(valid)
    flat = np.asarray(idx).reshape(-1)
    # valid slots reference choices routed to that expert, no duplicates
    seen = set()
    for ee in range(e):
        for c in range(cap):
            if va[ee, c]:
                choice = gi[ee, c]
                assert flat[choice] == ee
                assert choice not in seen
                seen.add(choice)
    # number of valid slots == number of choices, up to capacity clipping
    counts = np.bincount(flat, minlength=e)
    assert va.sum() == np.minimum(counts, cap).sum()


def test_mamba_state_handoff(key):
    """Prefill then continue decoding == full-sequence forward (conv+ssm state)."""
    cfg = reduced(get_config("mamba2-780m"))
    m = get_model(cfg)
    prms = m.init(key)
    b, s = 1, 32
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _ = m.forward(prms, {"tokens": toks}, CTX)
    # decode all the way (states only, no prefill shortcut for ssm)
    cache = m.make_cache(b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = m.decode_step(prms, toks[:, t : t + 1], cache, CTX)
        outs.append(np.asarray(lg[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full), atol=5e-5, rtol=1e-4)
