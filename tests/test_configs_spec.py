"""Spec conformance: every assigned architecture config matches the assignment
table exactly (guards against silent drift in the dry-run subjects)."""
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable

# (arch, layers, d_model, heads, kv, d_ff, vocab)
ASSIGNED = [
    ("olmo-1b", 16, 2048, 16, 16, 8192, 50304),
    ("qwen3-8b", 36, 4096, 32, 8, 12288, 151936),
    ("qwen2.5-14b", 48, 5120, 40, 8, 13824, 152064),
    ("yi-9b", 48, 4096, 32, 4, 11008, 64000),
    ("deepseek-v3-671b", 61, 7168, 128, 128, 2048, 129280),
    ("llama4-maverick-400b-a17b", 48, 5120, 40, 8, 8192, 202048),
    ("internvl2-2b", 24, 2048, 16, 8, 8192, 92553),
    ("zamba2-7b", 81, 3584, 32, 32, 14336, 32000),
    ("mamba2-780m", 48, 1536, 0, 0, 0, 50280),
    ("seamless-m4t-large-v2", 24, 1024, 16, 16, 8192, 256206),
]


@pytest.mark.parametrize("arch,L,d,h,kv,ff,v", ASSIGNED)
def test_assigned_numbers(arch, L, d, h, kv, ff, v):
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_all_ten_present():
    assert len(ARCHS) == 10


def test_family_features():
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.num_shared_experts == 1 and ds.moe.first_dense_layers == 3
    assert ds.mla.kv_lora_rank == 512 and ds.mla.qk_rope_head_dim == 64
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.moe.num_experts == 128 and l4.moe.top_k == 1
    z = get_config("zamba2-7b")
    assert z.ssm.state_dim == 64 and z.subquadratic
    m = get_config("mamba2-780m")
    assert m.ssm.state_dim == 128 and m.subquadratic
    assert get_config("qwen3-8b").qk_norm
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("olmo-1b").norm_type == "nonparametric"
    assert get_config("seamless-m4t-large-v2").encdec.encoder_layers == 24
    assert get_config("internvl2-2b").frontend == "vision"


def test_long_500k_skip_rules():
    """long_500k runs iff sub-quadratic (per the assignment)."""
    runs = {a for a in ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"zamba2-7b", "mamba2-780m"}
    # every arch runs the other three shapes
    for a in ARCHS:
        for sname in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[sname])[0]


def test_param_budgets():
    """Total parameter counts land on the models' nominal sizes."""
    from repro.models import get_model

    expect = {
        "olmo-1b": (1.0e9, 1.4e9),
        "qwen3-8b": (7.5e9, 9.0e9),
        "qwen2.5-14b": (13.5e9, 15.5e9),
        "yi-9b": (8.0e9, 9.5e9),
        "deepseek-v3-671b": (650e9, 690e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "internvl2-2b": (1.6e9, 2.2e9),
        "zamba2-7b": (6.0e9, 7.6e9),
        "mamba2-780m": (0.7e9, 1.0e9),
        "seamless-m4t-large-v2": (1.3e9, 2.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_model(get_config(arch)).count_params()
        assert lo <= n <= hi, (arch, n)
