"""Production mesh construction (spec'd shapes: 16x16 single-pod, 2x16x16 multi-pod).

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1 device -> 1x1 mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
