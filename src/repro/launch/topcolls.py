"""Print the largest collective ops (trip-weighted) in a dumped cell HLO."""
import gzip
import re
import sys

from repro.launch import hlo_analysis as H


def top_collectives(hlo: str, n: int = 10):
    comps = H.parse_module(hlo)
    entry = next(
        m.group(1) for line in hlo.splitlines()
        if (m := re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip()))
    )
    colls = []

    def walk(name, mult=1.0, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 24:
            return
        for op in comp.ops.values():
            kind = op.op
            if kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                trip = H._while_trip(comp, op, comps)
                if bm:
                    walk(bm.group(1), mult * trip, depth + 1)
            elif kind in ("fusion", "call", "reduce", "custom-call", "scatter", "sort", "map"):
                ref = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line)
                if ref:
                    walk(ref.group(1), mult, depth + 1)
            elif any(kind == k or kind == k + "-start" for k in H.COLLECTIVE_OPS):
                colls.append((op.result_bytes * mult, kind, op.line[:120]))

    walk(entry)
    colls.sort(reverse=True)
    return colls[:n], sum(c[0] for c in colls)


if __name__ == "__main__":
    with gzip.open(sys.argv[1], "rt") as f:
        top, total = top_collectives(f.read(), int(sys.argv[2]) if len(sys.argv) > 2 else 10)
    print(f"total {total/1e9:.0f} GB")
    for b, kind, line in top:
        print(f"  {b/1e9:8.1f} GB {kind:16s} {line[:100]}")
