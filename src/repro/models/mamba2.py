"""Mamba2 / SSD mixer (arXiv:2405.21060, state-space duality).

Chunked SSD forward for train/prefill: within-chunk quadratic ("attention-like")
term + across-chunk linear recurrence carried by ``lax.scan`` — O(L) in sequence
length, which is what qualifies the ssm/hybrid archs for the long_500k cell.
Single-step recurrent form for decode.

Arch-applicability note (DESIGN.md §4): the SSD *recurrence* is elementwise
state decay, not a MAC-array workload, so the CORDIC-MAC technique applies to
the in/out projections (routed through EngineContext) while the recurrence
itself stays in bf16/f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext
from repro.core.normalization import rmsnorm

from .params import ParamSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.num_heads or d_inner // s.head_dim
    return d_inner, n_heads


def mamba2_specs(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    proj_out = 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads  # z, x, B, C, dt
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((n_heads,), ("ssm_heads",), "zeros"),
        "D": ParamSpec((n_heads,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((n_heads,), ("ssm_heads",), "zeros"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    gn = s.n_groups * s.state_dim
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner : 2 * d_inner]
    b_mat = zxbcdt[..., 2 * d_inner : 2 * d_inner + gn]
    c_mat = zxbcdt[..., 2 * d_inner + gn : 2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn :]
    return z, x, b_mat, c_mat, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, L, C), w (W, C). Returns (B, L, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    return out + b[None, None, :]


def _segsum(dA):
    """Lower-triangular pairwise decay sums: out[..., i, j] = sum dA[j+1..i]."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int):
    """SSD scan. x: (B,L,H,P), dt: (B,L,H), a: (H,) (negative),
    b_mat/c_mat: (B,L,G,N) with H a multiple of G. Returns (y, final_state)."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[-2:]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = h // g

    # chunked views
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)  # (B,NC,Q,H,N)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    dA = dtc * a[None, None, None, :]  # (B,NC,Q,H) negative decay increments
    dA_cs = jnp.cumsum(dA, axis=2)
    dA_total = dA_cs[:, :, -1:, :]  # (B,NC,1,H)
    xdt = xc * dtc[..., None]

    # 1) intra-chunk (quadratic within the chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))  # (B,NC,H,Q,Q) causal decay mask
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc) * L
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xdt)

    # 2) per-chunk terminal states
    decay_states = jnp.exp(dA_total - dA_cs)  # (B,NC,Q,H)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", bc * decay_states[..., None], xdt)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_total[:, :, 0, :])  # (B,NC,H)

    def scan_fn(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((bsz, h, n, p), x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,NC,H,N,P)

    # 4) inter-chunk contribution
    state_decay = jnp.exp(dA_cs)  # (B,NC,Q,H)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", cc * state_decay[..., None], prev_states)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def mamba2_forward(p, x, cfg: ModelConfig, ctx: EngineContext, *, name, state=None):
    """Full-sequence (state=None) or single-step decode (state carried).

    state = {"conv": (B, W-1, conv_dim), "ssm": (B, H, N, P)}.
    Returns (out, new_state).
    """
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    bsz, l, _ = x.shape

    zxbcdt = ctx.linear(x, p["in_proj"], name=f"{name}.in_proj")
    z, xs, b_mat, c_mat, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, b_mat, c_mat], axis=-1)

    if state is None:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        window = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B, W, C)
        conv_out = (
            jnp.einsum("bwc,wc->bc", window, p["conv_w"])[:, None, :] + p["conv_b"][None, None, :]
        )
        new_conv = window[:, 1:, :]

    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_inner]
    b_mat = conv_out[..., d_inner : d_inner + s.n_groups * s.state_dim]
    c_mat = conv_out[..., d_inner + s.n_groups * s.state_dim :]

    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])  # (B,L,H)
    xh = xs.reshape(bsz, l, n_heads, s.head_dim)
    bm = b_mat.reshape(bsz, l, s.n_groups, s.state_dim).astype(jnp.float32)
    cm = c_mat.reshape(bsz, l, s.n_groups, s.state_dim).astype(jnp.float32)

    if state is None:
        chunk = min(s.chunk_size, l)
        y, final_state = ssd_chunked(xh.astype(jnp.float32), dt, a, bm, cm, chunk)
        # conv window for a subsequent decode step = last W-1 pre-conv inputs
        tail = conv_in[:, -(s.conv_width - 1) :, :].astype(x.dtype)
        new_state = {"conv": tail, "ssm": final_state}
    else:
        # recurrent step: h' = h * exp(dt A) + dt * B x ; y = C h' + D x
        rep = n_heads // s.n_groups
        bmh = jnp.repeat(bm[:, 0], rep, axis=1)  # (B,H,N)
        cmh = jnp.repeat(cm[:, 0], rep, axis=1)
        dt0 = dt[:, 0]  # (B,H)
        decay = jnp.exp(dt0 * a[None, :])  # (B,H)
        xdt = xh[:, 0].astype(jnp.float32) * dt0[..., None]  # (B,H,P)
        upd = jnp.einsum("bhn,bhp->bhnp", bmh, xdt)
        ssm = state["ssm"].astype(jnp.float32) * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", cmh, ssm)[:, None]  # (B,1,H,P)
        new_state = {"conv": new_conv, "ssm": ssm.astype(state["ssm"].dtype)}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    return ctx.linear(y, p["out_proj"], name=f"{name}.out_proj"), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.state_dim, s.head_dim), dtype),
    }


def mamba_state_specs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, n_heads, s.state_dim, s.head_dim), dtype),
    }
