"""Per-call vs prepared weight-bank serving benchmark (JSON output).

Measures the jitted decode step (the serving hot loop) with the seed's
per-call weight path (weights re-rounded / re-scaled every step) against the
prepared path (``prepare_params``: quantize once, serve fast), per engine
mode. Complements the ``benchmarks/run.py`` CSV tables with a JSON record:

    PYTHONPATH=src python -m benchmarks.bench_prepared --arch olmo-1b \
        --modes carmen,int8 --steps 20

writes ``artifacts/bench/bench_prepared.json`` (and prints it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import EngineContext, FXP8, PrecisionPolicy, prepare_params
from repro.serve.engine import BatchedServer, make_decode_sample_step

from ._common import (
    attach_observer,
    base_record,
    bench_parser,
    emit_record,
    latency_block,
    load_model,
    make_requests,
    timed,
)


def bench_mode(model, params, mode: str, *, slots: int, max_len: int, steps: int):
    policy = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode=mode, policy=policy, compute_dtype=jnp.float32)
    prepared = prepare_params(params, policy, mode, specs=model.specs())
    rec = {}
    for label, p in (("per_call", params), ("prepared", prepared)):
        decode = jax.jit(make_decode_sample_step(model, ctx))

        def run_steps():
            cache = model.make_cache(slots, max_len, dtype=jnp.float32)
            tok = jnp.zeros((slots, 1), jnp.int32)
            for _ in range(steps):
                tok, cache = decode(p, tok, cache)
            return tok

        dt, _ = timed(run_steps)  # warmup run eats compile + first dispatch
        rec[label] = {
            "step_ms": round(1e3 * dt / steps, 3),
            "tok_s": round(steps * slots / dt, 1),
        }
    rec["speedup"] = round(rec["per_call"]["step_ms"] / rec["prepared"]["step_ms"], 2)
    return rec


def main(argv=None):
    ap = bench_parser(__doc__, default_out="bench_prepared.json", smoke=False)
    ap.add_argument("--modes", default="carmen,int8")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args(argv)

    cfg, model, params = load_model(args.arch, full_size=args.full_size)
    record = base_record(args, slots=args.slots, steps=args.steps, modes={})
    for mode in args.modes.split(","):
        record["modes"][mode] = bench_mode(
            model, params, mode, slots=args.slots, max_len=args.max_len,
            steps=args.steps,
        )

    # one small end-to-end served run on the first mode's prepared path, so
    # this record also carries SLO latency percentiles, not just step_ms
    mode = args.modes.split(",")[0]
    ctx = EngineContext(mode=mode, policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    server = BatchedServer(model, ctx, params, slots=args.slots,
                           max_len=args.max_len)
    obs = attach_observer(server)
    timed(lambda: server.run(make_requests(
        cfg, args.slots * 2, prompt_len=6,
        max_new=min(args.steps, args.max_len - 8))))
    record["served"] = {"mode": mode, "latency": latency_block(obs)}
    return emit_record(record, args.out)


if __name__ == "__main__":
    main()
