"""Pure-jnp oracle for the cordic_af kernel: the bit-faithful AF simulation.

The kernel body *is* ``repro.core.activations`` traced into Pallas, so the
oracle is simply the non-Pallas evaluation of the same functions — any
difference between kernel and ref is a Pallas lowering bug, not an arithmetic
disagreement. (The float references used for accuracy budgets live in
``repro.core.activations.af_ref``.)
"""
from __future__ import annotations

from repro.core.activations import multi_af_float
from repro.core.fxp import FxPFormat


def af_ref(x, mode: str, *, depth: int, fmt: FxPFormat):
    return multi_af_float(x, mode, depth, fmt)
