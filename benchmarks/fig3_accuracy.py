"""Paper Fig. 3 — inference accuracy vs precision format x CORDIC depth.

Trains the paper's MLP workload (196-64-32-32-10, the network the compared
accelerators run) in float32 on Gaussian-cluster classification, then
evaluates the SAME weights under each CARMEN execution point. Claims:

  C1: FxP-8 accurate mode stays within ~2% of the FP32 baseline.
  C2: approximate mode (-33% cycles) costs <2% extra.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.carmen_mlp import CONFIG as MLP
from repro.core import (
    FXP8,
    FXP8_UNIT,
    FXP16,
    FXP16_UNIT,
    FxPFormat,
    approx_depth,
    carmen_matmul_fast,
    full_depth,
    int8_dot,
    multi_af_float,
)

# Per-layer binary-point schedule (classic fixed-point NN deployment): the AF
# *input* (pre-activation, fan-in up to 196) needs integer headroom, so its
# 8-bit point is Q3.4; weights/activations stay Q1.6 / Q3.12 as elsewhere.
AF_IN_8 = FxPFormat(8, 4)
AF_IN_16 = FxPFormat(16, 10)
from repro.core.activations import af_ref
from repro.data.pipeline import ClusterPipeline


def _init(rng, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params.append(
            (rng.normal(0, np.sqrt(2.0 / a), (a, b)).astype(np.float32),
             np.zeros(b, np.float32))
        )
    return params


def _forward_f32(params, x):
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i < len(params) - 1:
            h = np.asarray(af_ref(h, MLP.act))
    return h


def _forward_carmen(params, x, fmt, w_fmt, depth):
    af_fmt = AF_IN_8 if fmt.bits <= 8 else AF_IN_16
    h = jnp.asarray(x)
    for i, (w, b) in enumerate(params):
        h = carmen_matmul_fast(h, jnp.asarray(w), depth, fmt, w_fmt) + b
        if i < len(params) - 1:
            h = multi_af_float(h, MLP.act, depth, af_fmt)
    return np.asarray(h)


def _forward_int8(params, x, eff_bits):
    h = jnp.asarray(x)
    for i, (w, b) in enumerate(params):
        h = int8_dot(h, jnp.asarray(w), effective_bits=eff_bits) + b
        if i < len(params) - 1:
            h = jnp.asarray(af_ref(h, MLP.act))
    return np.asarray(h)


def _train(params, x, y, steps=2500, lr=0.1, bs=256):
    params = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]

    def loss_fn(ps, xb, yb):
        h = xb
        for i, (w, b) in enumerate(ps):
            h = h @ w + b
            if i < len(ps) - 1:
                h = af_ref(h, MLP.act)
        ll = jax.nn.log_softmax(h)
        return -jnp.take_along_axis(ll, yb[:, None], 1).mean()

    grad = jax.jit(jax.grad(loss_fn))
    n = x.shape[0]
    for s in range(steps):
        i = (s * bs) % (n - bs)
        g = grad(params, x[i : i + bs], y[i : i + bs])
        params = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, g)]
    return [(np.asarray(w), np.asarray(b)) for w, b in params]


def run():
    pipe = ClusterPipeline(
        n_features=MLP.layer_sizes[0], n_classes=MLP.layer_sizes[-1], spread=2.25
    )
    data_x, data_y = pipe.dataset(10_000)
    x_tr, y_tr = data_x[:8_000], data_y[:8_000]
    x_te, y_te = data_x[8_000:], data_y[8_000:]

    params = _train(_init(np.random.default_rng(0), MLP.layer_sizes), x_tr, y_tr)

    def acc(logits):
        return float((logits.argmax(-1) == y_te).mean())

    base = acc(_forward_f32(params, x_te))
    rows = [("fig3.fp32_baseline", 0.0, f"acc={base:.4f}")]

    points = [
        ("fxp16_accurate", FXP16, FXP16_UNIT, full_depth(FXP16_UNIT)),
        ("fxp16_approx", FXP16, FXP16_UNIT, approx_depth(FXP16_UNIT)),
        ("fxp8_accurate", FXP8, FXP8_UNIT, full_depth(FXP8_UNIT)),
        ("fxp8_approx", FXP8, FXP8_UNIT, approx_depth(FXP8_UNIT)),
        ("fxp8_d4", FXP8, FXP8_UNIT, 4),
        ("fxp8_d3", FXP8, FXP8_UNIT, 3),
        ("fxp8_d2", FXP8, FXP8_UNIT, 2),  # below the useful-depth floor: the cliff
    ]
    for name, fmt, w_fmt, depth in points:
        a = acc(_forward_carmen(params, x_te, fmt, w_fmt, depth))
        rows.append((f"fig3.{name}", 0.0, f"acc={a:.4f};drop={base-a:+.4f};depth={depth}"))

    for bits in (8, 6, 4):
        a = acc(_forward_int8(params, x_te, bits))
        rows.append((f"fig3.int8_eff{bits}", 0.0, f"acc={a:.4f};drop={base-a:+.4f}"))

    # claim checks (printed as derived flags)
    a8 = [r for r in rows if r[0] == "fig3.fxp8_accurate"][0]
    a8a = [r for r in rows if r[0] == "fig3.fxp8_approx"][0]
    d8 = float(a8[2].split("drop=")[1].split(";")[0])
    d8a = float(a8a[2].split("drop=")[1].split(";")[0])
    rows.append(("fig3.claim_C1_fxp8_within_2pct", 0.0, f"drop={-d8:.4f};pass={abs(d8) <= 0.02}"))
    rows.append(("fig3.claim_C2_approx_within_2pct", 0.0, f"extra={d8a - d8:.4f};pass={d8a - d8 <= 0.02}"))
    return rows
