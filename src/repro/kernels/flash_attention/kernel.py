"""Pallas TPU kernel: blocked flash attention (online softmax, VMEM-resident).

Beyond-paper optimization (EXPERIMENTS.md §Perf): the dry-run baselines show
materialized f32 attention scores dominating the memory roofline term for
every *_32k cell. This kernel never writes scores to HBM — the classic
flash-attention restructuring, tiled for the TPU memory hierarchy:

  grid = (BH, nq, nk), k innermost; the (bq, bk) score tile, the online
  softmax statistics m/l and the (bq, D) output accumulator live in VMEM
  scratch across the k sweep; HBM traffic is exactly q + k + v + out.

VMEM at defaults (bq = bk = 512, D = 128, f32 compute):
  q/k/v tiles ~3 x 256 KiB, scores 1 MiB, acc 256 KiB, stats 4 KiB
  ~= 2.1 MiB << 16 MiB (room for double buffering).

GQA is handled by the index maps (kv head = q head // group); causal masking
by position arithmetic inside the tile (blocks entirely above the diagonal
contribute zero and are masked, not skipped — grid shapes stay static).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  n_k: int, bq: int, bk: int, causal: bool, scale: float):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0].astype(jnp.float32)  # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)
    if causal:
        qi = pl.program_id(1)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kk == n_k - 1)
    def _finalize():
        # rows with no valid keys (fully masked) have l == 0 -> emit 0
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention_bhsd(
    q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
    interpret: bool = False,
):
    """q: (BH, Sq, D); k, v: (BH, Sk, D) — pre-broadcast over GQA groups.

    Returns (BH, Sq, D) in q.dtype.
    """
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_k = sk // bk
    grid = (bh, sq // bq, n_k)
    scale = 1.0 / math.sqrt(d)

    from repro.kernels.cordic_mac.kernel import pltpu_vmem

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu_vmem((bq, d), jnp.float32),
            pltpu_vmem((bq, 1), jnp.float32),
            pltpu_vmem((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
