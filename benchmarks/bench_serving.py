"""Decode-burst serving benchmark: tokens/sec + host round-trips per burst size.

The decode hot loop's cost on small models is dominated by what happens
BETWEEN engine steps — Python dispatch, (B, 1) token transfers, numpy
bookkeeping — not by the steps themselves. This benchmark measures exactly
that: the same workload served at burst sizes {1, 4, 8, 16} (``burst=1`` is
the per-token loop the seed shipped), for a dense model, a MoE model, an MLA
latent-cache model, and the adaptive-controller machinery, plus one
speculative run. Each record carries tokens/sec, the server's counted host
round-trips, and a bit-identity flag against the burst=1 greedy output —
bursts are a pure scheduling change, so any token drift is a bug.

    PYTHONPATH=src python -m benchmarks.bench_serving --bursts 1,4,8,16

``--smoke`` shrinks the workload for CI, writes
``artifacts/bench/BENCH_serving.json``, and exits nonzero if burst=8 is
slower than burst=1 (``--min-speedup``) or any config loses bit-identity —
the CI gate that keeps the burst path honest.
"""
from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from repro.core import EngineContext, FXP16, PrecisionPolicy
from repro.serve.engine import BatchedServer, Request

from ._common import (
    base_record,
    bench_parser,
    emit_record,
    load_model,
    timed,
)

CONFIG_ARCHS = {
    "dense": "olmo-1b",
    "moe": "llama4-maverick-400b-a17b",
    "mla": "deepseek-v3-671b",
}


def _workload(cfg, n, *, max_new, seed=1):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, int(rng.integers(3, 9))).astype(np.int32),
                max_new)
        for i in range(n)
    ]


def _gen_tokens(out):
    return sum(len(v) for v in out.values())


def bench_bursts(make_server, cfg, bursts, *, requests, max_new, reps=3):
    """Sweep burst sizes over one server config; burst=1 is the reference.

    Reps are interleaved across burst sizes (A/B/A/B, best-of per burst) so
    machine-load drift hits every burst size equally instead of biasing
    whichever happened to run during a quiet stretch.
    """
    servers = {burst: make_server(burst) for burst in bursts}
    run = lambda srv: srv.run(_workload(cfg, requests, max_new=max_new))
    outs, best = {}, {b: float("inf") for b in bursts}
    for burst, srv in servers.items():  # warmup: compile + first dispatch
        outs[burst] = run(srv)
    for _ in range(reps):
        for burst, srv in servers.items():
            dt, outs[burst] = timed(lambda: run(srv), warmup=0)
            best[burst] = min(best[burst], dt)
    ref = outs[bursts[0]]
    rows = [{
        "burst": burst,
        "tok_s": round(_gen_tokens(outs[burst]) / max(best[burst], 1e-9), 1),
        "host_transfers": servers[burst].host_transfers,
        "bit_identical": outs[burst] == ref,
    } for burst in bursts]
    base = rows[0]["tok_s"]
    for row in rows:
        row["speedup"] = round(row["tok_s"] / max(base, 1e-9), 2)
    return rows


def main(argv=None):
    ap = bench_parser(__doc__, default_out="BENCH_serving.json")
    ap.add_argument("--bursts", default="1,4,8,16",
                    help="comma-separated burst sizes (first is the reference)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--draft-len", type=int, default=3)
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced-model width (smoke shrinks it so the "
                         "per-token loop's dispatch overhead is visible)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="CI gate: burst=8 must reach this speedup over "
                         "burst=1 (checked when 1 and 8 are both swept)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.full_size = False
        args.slots = 2
        args.requests = 8
        args.max_new = 32
        args.d_model = 64

    bursts = [int(x) for x in args.bursts.split(",")]
    max_len = 16 + args.max_new + args.draft_len
    record = base_record(args, slots=args.slots, requests=args.requests,
                         max_new=args.max_new, bursts=bursts, configs={})

    for name, arch in CONFIG_ARCHS.items():
        cfg, model, params = load_model(arch, full_size=args.full_size,
                                        d_model=args.d_model)
        ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
        make = lambda burst: BatchedServer(model, ctx, params, slots=args.slots,
                                           max_len=max_len, burst=burst)
        record["configs"][name] = {
            "arch": arch,
            "sweep": bench_bursts(make, cfg, bursts, requests=args.requests,
                                  max_new=args.max_new),
        }

    # adaptive machinery under bursts: pinned controller (bank tree per burst,
    # telemetry live) so the output stays comparable across burst sizes —
    # free-controller trajectories legitimately differ with observation
    # cadence and are bench_adaptive's subject
    from repro.runtime import ControllerConfig, ModeController, build_bank, default_points

    cfg, model, params = load_model("olmo-1b", full_size=args.full_size,
                                    d_model=args.d_model)
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP16, hifi_fmt=None),
                      specs=model.specs())
    make = lambda burst: BatchedServer(
        model, ctx, params, slots=args.slots, max_len=max_len, burst=burst,
        controller=ModeController(bank, ControllerConfig(pin="accurate")),
    )
    record["configs"]["adaptive"] = {
        "arch": "olmo-1b", "pin": "accurate",
        "sweep": bench_bursts(make, cfg, bursts, requests=args.requests,
                              max_new=args.max_new),
    }

    # speculative serving (its round structure subsumes bursting; one run,
    # identity vs the accurate-only burst=1 output)
    from repro.spec import SpecConfig

    ref_server = BatchedServer(model, ctx, bank.tree(bank.reference),
                               slots=args.slots, max_len=max_len, burst=1,
                               prepare_weights=False)
    _, ref_out = timed(lambda: ref_server.run(
        _workload(cfg, args.requests, max_new=args.max_new)))
    spec_server = BatchedServer(model, ctx, params, slots=args.slots,
                                max_len=max_len, bank=bank,
                                speculate=SpecConfig(draft_len=args.draft_len))
    dt, out = timed(lambda: spec_server.run(
        _workload(cfg, args.requests, max_new=args.max_new)))
    record["configs"]["speculative"] = {
        "arch": "olmo-1b", "draft_len": args.draft_len,
        "tok_s": round(_gen_tokens(out) / max(dt, 1e-9), 1),
        "host_transfers": spec_server.host_transfers,
        "bit_identical": out == ref_out,
        "acceptance_rate": spec_server.spec_telemetry.summary()["acceptance_rate"],
    }

    emit_record(record, args.out)

    # CI gate: bursts must never lose tokens/sec or bit-identity
    failures = []
    for name, rec in record["configs"].items():
        if "sweep" not in rec:
            if not rec["bit_identical"]:
                failures.append(f"{name}: speculative output drifted")
            continue
        by_burst = {row["burst"]: row for row in rec["sweep"]}
        for row in rec["sweep"]:
            if not row["bit_identical"]:
                failures.append(f"{name}: burst={row['burst']} output drifted")
        if 1 in by_burst and 8 in by_burst:
            speedup = by_burst[8]["tok_s"] / max(by_burst[1]["tok_s"], 1e-9)
            if speedup < args.min_speedup:
                failures.append(
                    f"{name}: burst=8 speedup {speedup:.2f}x < {args.min_speedup}x"
                )
    if failures:
        print("FAIL:", "; ".join(failures))
        sys.exit(1)
    return record


if __name__ == "__main__":
    main()
