"""Runtime-adaptive precision: execution mode as a per-step serving decision.

Paper mapping (§II-C / §III): CARMEN's control engine exposes the CORDIC
iteration depth through configuration registers, "enabling dynamic switching
between approximate and accurate execution modes without hardware
modification". The weight bank in the PE array never changes between modes —
only the iteration count does. This package is the software incarnation of
that split for the serving loop:

* :mod:`repro.runtime.bank` — **multi-point weight banks**. One prepare pass
  materializes every execution point (e.g. approx-depth FxP8, full-depth
  FxP8, full-depth FxP16) per layer, sharing prepared leaves wherever the
  per-layer execution point agrees (criticality-pinned layers are stored
  once). Switching modes at serve time then costs zero weight-side work —
  "no hardware modification".
* :mod:`repro.runtime.controller` — the **mode controller** feedback loop.
  Each decode step it reads cheap telemetry (top-2 logit margin per slot,
  queue depth / admission pressure, a cycle-budget target) and selects the
  execution point for the next step, with hysteresis against thrashing. The
  §III accuracy floor is structural: approximate points are derived through
  :func:`repro.core.precision_policy.pin_critical`, so critical layers run
  accurate in every mode the controller can reach.
* :mod:`repro.runtime.telemetry` — mode occupancy, estimated MAC cycles
  saved (the paper's K*(depth+1) iterative-PE cycle model), and switch
  counts, exported by ``BatchedServer`` and surfaced by ``launch/serve.py``.
* :mod:`repro.runtime.calibrate` — the serving-side §III sensitivity scan:
  a calibration batch measures per-layer-group logit perturbation under
  depth demotion, feeding ``assign_depths`` at server startup.
"""
from .bank import ExecutionPoint, MultiPointBank, build_bank, default_points
from .calibrate import calibration_scan
from .controller import ControllerConfig, ModeController, StepSignals
from .telemetry import TelemetryRecorder, estimate_point_cycles, teacher_forced_agreement

__all__ = [
    "ExecutionPoint",
    "MultiPointBank",
    "build_bank",
    "default_points",
    "calibration_scan",
    "ControllerConfig",
    "ModeController",
    "StepSignals",
    "TelemetryRecorder",
    "estimate_point_cycles",
    "teacher_forced_agreement",
]
