"""Direct unit tests for the KV-cache index helpers (serve/kvcache.py).

These contracts were only covered transitively through the burst / spec e2e
suites; here each helper is exercised on its own:

* ``cache_positions`` / ``with_cache_positions`` — the write-index rewind
  that bucketed prefill and speculative rollback share;
* ``scatter_rows`` — slot insertion of a single-row cache, eager and traced;
* scratch-region invisibility — rows at positions >= the write index are
  dead: poisoning them cannot change the next decode's logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import EngineContext
from repro.models import get_model
from repro.serve.kvcache import (
    bucket_length,
    cache_positions,
    scatter_rows,
    with_cache_positions,
)

EXACT = EngineContext(mode="exact", compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def olmo():
    cfg = reduced(get_config("olmo-1b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _decode_n(model, params, cache, tokens):
    """Feed ``tokens`` one at a time; returns (last_logits, cache)."""
    logits = None
    for t in tokens:
        logits, cache = model.decode_step(
            params, jnp.array([[t]], jnp.int32), cache, EXACT
        )
    return logits, cache


# ---------------------------------------------------------------------------
# write-index read / rewind
# ---------------------------------------------------------------------------


def test_cache_positions_roundtrip(olmo):
    cfg, model, params = olmo
    cache = model.make_cache(2, 16, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(cache_positions(cache)), [0, 0])
    cache = with_cache_positions(cache, jnp.array([3, 7], jnp.int32))
    np.testing.assert_array_equal(np.asarray(cache_positions(cache)), [3, 7])
    # every layer's index row rewrote, not just layer 0
    for leaf in jax.tree.leaves(cache):
        if jnp.issubdtype(leaf.dtype, jnp.integer) and leaf.ndim >= 2:
            np.testing.assert_array_equal(
                np.asarray(leaf), np.broadcast_to([3, 7], leaf.shape)
            )


def test_cache_positions_advance_with_decode(olmo):
    cfg, model, params = olmo
    cache = model.make_cache(1, 16, dtype=jnp.float32)
    _, cache = _decode_n(model, params, cache, [5, 17, 3])
    np.testing.assert_array_equal(np.asarray(cache_positions(cache)), [3])


def test_cache_positions_raises_on_recurrent():
    cfg = reduced(get_config("mamba2-780m"))
    model = get_model(cfg)
    cache = model.make_cache(1, 16, dtype=jnp.float32)
    with pytest.raises(ValueError, match="no write index"):
        cache_positions(cache)


def test_rewind_replays_identically(olmo):
    """Rewinding the index to k and re-decoding the same suffix reproduces
    the original logits — the rewound rows are overwritten before they can
    become visible."""
    cfg, model, params = olmo
    cache = model.make_cache(1, 16, dtype=jnp.float32)
    _, cache = _decode_n(model, params, cache, [5, 17])
    want, full = _decode_n(model, params, cache, [3, 9])
    rewound = with_cache_positions(full, jnp.array([2], jnp.int32))
    got, _ = _decode_n(model, params, rewound, [3, 9])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# scratch-region invisibility
# ---------------------------------------------------------------------------


def test_scratch_rows_invisible(olmo):
    """Poisoning every row at positions >= the write index does not change
    the next decode step — the per-query-causal mask plus the
    write-at-index discipline make that region pure scratch."""
    cfg, model, params = olmo
    cache = model.make_cache(1, 16, dtype=jnp.float32)
    _, cache = _decode_n(model, params, cache, [5, 17, 3])
    idx = int(np.asarray(cache_positions(cache))[0])

    def poison(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return leaf
        # row leaves are (L, B, S, ...): blast positions >= idx along S
        mask = (jnp.arange(leaf.shape[2]) >= idx).reshape(
            (1, 1, -1) + (1,) * (leaf.ndim - 3)
        )
        return jnp.where(mask, jnp.float32(1e9), leaf)

    poisoned = jax.tree.map(poison, cache)
    want, _ = _decode_n(model, params, cache, [9])
    got, _ = _decode_n(model, params, poisoned, [9])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# slot scatter
# ---------------------------------------------------------------------------


def test_scatter_rows_writes_one_slot(olmo):
    cfg, model, params = olmo
    full = model.make_cache(3, 8, dtype=jnp.float32)
    row = model.make_cache(1, 8, dtype=jnp.float32)
    row = jax.tree.map(lambda l: l + 1, row)
    out = scatter_rows(full, row, jnp.int32(1))
    for dst, src, new in zip(
        jax.tree.leaves(full), jax.tree.leaves(row), jax.tree.leaves(out)
    ):
        new = np.asarray(new)
        np.testing.assert_array_equal(new[:, 1], np.asarray(src)[:, 0])
        np.testing.assert_array_equal(new[:, 0], np.asarray(dst)[:, 0])
        np.testing.assert_array_equal(new[:, 2], np.asarray(dst)[:, 2])


def test_scatter_rows_whole_cache_when_single_slot(olmo):
    cfg, model, params = olmo
    full = model.make_cache(1, 8, dtype=jnp.float32)
    row = jax.tree.map(lambda l: l + 2, model.make_cache(1, 8, dtype=jnp.float32))
    out = scatter_rows(full, row, jnp.int32(0))
    for src, new in zip(jax.tree.leaves(row), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(new), np.asarray(src))


def test_scatter_rows_under_jit_with_traced_slot(olmo):
    cfg, model, params = olmo
    full = model.make_cache(4, 8, dtype=jnp.float32)
    row = jax.tree.map(lambda l: l + 3, model.make_cache(1, 8, dtype=jnp.float32))
    eager = scatter_rows(full, row, jnp.int32(2))
    jitted = jax.jit(scatter_rows)(full, row, jnp.int32(2))
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucket_length_properties():
    for plen in range(1, 70):
        b = bucket_length(plen, 64)
        assert b >= min(plen, 64) and b <= 64
        assert b & (b - 1) == 0 or b == 64  # pow2 unless clamped
