"""Pure-jnp oracle: naive softmax attention with materialized scores."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (BH, Sq, D); k, v: (BH, Sk, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
