"""Fused CORDIC dot + activation epilogue — one VMEM-resident Pallas pass.

The unfused kernel path materializes the prepared-dot output to HBM, then
re-reads it through ``multi_af_pallas``.  This kernel performs the whole
per-layer chain in one pass over the output tile:

    quantize(x) -> int32 dot against the signed-digit weight grid
                -> descale -> (optional compute-dtype round)
                -> time-multiplexed CORDIC activation -> f32 out

Everything that varies across :class:`~repro.runtime.bank.ExecutionPoint`\\ s —
CORDIC dot depth, activation-format parameters, and the AF mode selector —
rides in a small int32 *params* vector delivered as a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``).  The compiled program is therefore
identical for every point: a ModeController switch swaps the vector, not the
kernel.

Bit-parity strategy: the matmul is an exact int32 x int32 dot.  Activations
are quantized in-kernel (round-half-even, saturate) and the signed-digit grid
values are multiples of ``2**-w_frac``, so ``round(w * 2**w_frac)`` recovers
the weight integers exactly.  Integer accumulation is order-independent, so
the pure-XLA reference (:func:`repro.kernels.cordic_fused.ref`) running the
identical chain is bitwise equal — for FXP8 *and* FXP16 — regardless of tile
order.  The activation epilogue reuses the same fixed-point `multi_af` library
as the standalone ``cordic_af`` kernel.

The params vector layout (``make_point`` builds the first five entries; the
op appends the AF mode index):

    [0] dot CORDIC depth (informational — baked into the prepared grid)
    [1] activation fraction bits  (x_frac)
    [2] activation qmin
    [3] activation qmax
    [4] weight fraction bits      (w_frac)
    [5] AF mode index into FUSED_AFS
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import activations as afs
from repro.core import fxp

from ..cordic_af.kernel import ELEMENTWISE_AFS

# Mode 0 is a plain (no-activation) prepared dot so attention/output
# projections share the same compiled kernel as MLP gate/up projections.
FUSED_AFS = ("identity",) + ELEMENTWISE_AFS

# params-vector indices
P_DEPTH = 0
P_XFRAC = 1
P_XQMIN = 2
P_XQMAX = 3
P_WFRAC = 4
P_MODE = 5
POINT_LEN = 5  # entries owned by make_point; P_MODE is appended per call
PARAM_LEN = 6


def make_point(depth: int, x_fmt: fxp.FxPFormat, w_fmt: fxp.FxPFormat):
    """Pack an execution point's dot parameters into the int32 params vector.

    The result is a *traced-compatible* array: swapping it between calls does
    not retrace, which is the whole trick behind zero-cost mode switches.
    """
    return jnp.asarray(
        [int(depth), x_fmt.frac, x_fmt.qmin, x_fmt.qmax, w_fmt.frac],
        jnp.int32,
    )


def af_epilogue(h, mode, af_depth, af_fmt, compute_round):
    """The shared activation chain applied to the f32 dot output ``h``.

    ``mode`` may be a static string (XLA reference path) or a traced int32
    scalar indexing :data:`FUSED_AFS` (kernel path, via ``lax.switch``).  Both
    run the exact same ops so the two paths stay bitwise identical.
    """
    ifmt = afs.internal_fmt(af_fmt)
    d = max(int(af_depth) + (ifmt.frac - af_fmt.frac), 2)

    def _apply(v, name):
        if name == "identity":
            return v
        if compute_round:
            # the unfused path hands the dot output to apply_af in the
            # compute dtype; reproduce that single rounding here
            v = v.astype(jnp.bfloat16).astype(jnp.float32)
        xq = fxp.requantize(fxp.quantize(v, af_fmt), af_fmt, ifmt)
        raw = afs.multi_af(xq, name, d, ifmt)
        return fxp.dequantize(fxp.requantize(raw, ifmt, af_fmt), af_fmt)

    if isinstance(mode, str):
        return _apply(h, mode)
    branches = [functools.partial(_apply, name=name) for name in FUSED_AFS]
    return jax.lax.switch(mode, branches, h)


def fused_kernel(params_ref, x_ref, w_ref, out_ref, *, af_depth, af_fmt,
                 compute_round):
    """grid = (M // bm, N // bn); x tile (bm, K), w tile (K, bn)."""
    x_frac = params_ref[P_XFRAC]
    qmin = params_ref[P_XQMIN].astype(jnp.float32)
    qmax = params_ref[P_XQMAX].astype(jnp.float32)
    w_frac = params_ref[P_WFRAC]

    x_scale = jnp.exp2(x_frac.astype(jnp.float32))
    w_scale = jnp.exp2(w_frac.astype(jnp.float32))

    xq = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) * x_scale),
                  qmin, qmax).astype(jnp.int32)
    # signed-digit grid values are exact multiples of 2**-w_frac, so this
    # recovers the weight integers exactly
    wq = jnp.round(w_ref[...].astype(jnp.float32) * w_scale).astype(jnp.int32)

    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    h = (acc.astype(jnp.float32) * jnp.exp2(-x_frac.astype(jnp.float32))
         ) * jnp.exp2(-w_frac.astype(jnp.float32))

    out_ref[...] = af_epilogue(h, params_ref[P_MODE], af_depth, af_fmt,
                               compute_round)
