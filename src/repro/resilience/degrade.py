"""Graceful precision degradation: a cap layered on the controller's ladder.

CARMEN's runtime knob — CORDIC iteration depth — trades accuracy for cycles
with zero weight-side work per switch. :class:`DegradationPolicy` uses that
knob for *survival*: under sustained overload (deadline misses, shed
requests, a full queue with nothing free) it caps the whole batch's
execution point further and further down the bank's cheap->accurate ladder,
so the engine emits approximate tokens fast instead of accurate tokens
late; when the pressure clears it lifts the cap back one rung at a time
with its own (longer) hysteresis, so a transient lull does not bounce the
batch straight back into overload.

The policy *wraps* a :class:`~repro.runtime.controller.ModeController` and
is duck-type compatible with it (``point`` / ``tree()`` / ``observe()`` /
``reset()`` / ``bank`` / ``switches`` / ``on_switch``), so
``BatchedServer(controller=DegradationPolicy(inner))`` needs no engine
changes: the effective point is ``min(inner's choice, cap)`` on the ladder
index, which composes with both adaptive controllers (the margin/budget
logic keeps voting underneath the cap) and pinned ones (``pin="accurate"``
under a cap degrades the whole batch — the benchmark's comparison case).
Only *effective*-point changes fire ``on_switch``, so the serving trace and
switch counters describe what actually executed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["DegradationConfig", "DegradationPolicy"]


@dataclasses.dataclass(frozen=True)
class DegradationConfig:
    floor: Optional[str] = None     # cheapest point the cap may reach (default: rung 0)
    demote_hysteresis: int = 1      # consecutive pressured observations per cap drop
    promote_hysteresis: int = 4     # consecutive calm observations per cap lift

    def __post_init__(self):
        if self.demote_hysteresis < 1 or self.promote_hysteresis < 1:
            raise ValueError("hysteresis values must be >= 1")


class DegradationPolicy:
    """Overload-driven cap over a ModeController's execution-point ladder."""

    def __init__(self, inner, config: Optional[DegradationConfig] = None):
        self.inner = inner
        self.cfg = config or DegradationConfig()
        self.bank = inner.bank
        if self.cfg.floor is not None and self.cfg.floor not in self.bank.names:
            raise ValueError(
                f"unknown floor point {self.cfg.floor!r}; bank has "
                f"{self.bank.names}"
            )
        self._floor_idx = (self.bank.index(self.cfg.floor)
                           if self.cfg.floor is not None else 0)
        self._top_idx = len(self.bank.points) - 1
        self.on_switch = None  # wired per run by the server (observer hook)
        self.reset()

    def reset(self) -> None:
        self.inner.reset()
        self._cap = self._top_idx
        self._pressure_streak = 0
        self._calm_streak = 0
        self.switches = 0
        self.demotions = 0
        self.promotions = 0

    # -- ModeController duck-type ---------------------------------------------

    @property
    def point(self) -> str:
        """The capped effective point the next step executes at."""
        idx = min(self.bank.index(self.inner.point), self._cap)
        return self.bank.points[idx].name

    @property
    def cap(self) -> str:
        return self.bank.points[self._cap].name

    def tree(self):
        return self.bank.tree(self.point)

    @property
    def rel_cycles_ema(self) -> float:
        return self.inner.rel_cycles_ema

    def observe(self, signals) -> str:
        """Feed the inner controller, then move the cap on overload signals.

        Pressure is any of: a deadline missed this observation, a request
        shed this observation, or a non-empty queue with zero free slots.
        The inner controller's ``on_switch`` stays unwired — only effective-
        point changes (cap moves or uncapped inner moves) fire ours.
        """
        old = self.point
        self.inner.observe(signals)
        pressure = (
            getattr(signals, "deadline_misses", 0) > 0
            or getattr(signals, "shed", 0) > 0
            or (signals.queue_depth > 0 and signals.free_slots == 0)
        )
        if pressure:
            self._calm_streak = 0
            self._pressure_streak += 1
            if (self._pressure_streak >= self.cfg.demote_hysteresis
                    and self._cap > self._floor_idx):
                self._cap -= 1
                self.demotions += 1
                self._pressure_streak = 0
        else:
            self._pressure_streak = 0
            self._calm_streak += 1
            if (self._calm_streak >= self.cfg.promote_hysteresis
                    and self._cap < self._top_idx):
                self._cap += 1
                self.promotions += 1
                self._calm_streak = 0
        new = self.point
        if new != old:
            self.switches += 1
            if self.on_switch is not None:
                self.on_switch(old, new, signals)
        return new
