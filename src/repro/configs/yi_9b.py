"""yi-9b [arXiv:2403.04652; hf] — llama-arch GQA (kv=4)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    norm_type="rmsnorm",
    act="swish",
    glu=True,
    rope_theta=1e4,
)
