"""Shared transformer building blocks: RoPE, norms, GQA attention, MLP, MoE.

All matmuls route through ``EngineContext`` (the CARMEN vector engine) and all
activation functions through the multi-AF block mapping, so the paper's
technique is a first-class execution mode for every architecture.

Attention is computed in query chunks (flash-style, pure JAX ``lax.scan``) so
that 32k-sequence cells never materialize an (S, S) score tensor — scores per
step stay (B, H, Qc, S).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import EngineContext
from repro.core.normalization import layernorm, nonparametric_ln, rmsnorm
from repro.configs.base import ModelConfig
from repro.sharding.partition import constrain

from .params import ParamSpec

Q_CHUNK = 1024  # flash-style query block


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm_type == "nonparametric":
        return {}
    if cfg.norm_type == "layernorm":
        return {
            "scale": ParamSpec((d,), ("embed",), "ones"),
            "bias": ParamSpec((d,), ("embed",), "zeros"),
        }
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm_type == "nonparametric":
        return nonparametric_ln(x)
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def apply_af(x, mode: str, ctx: EngineContext):
    """Activation through the CARMEN multi-AF block (or the exact ref)."""
    return ctx.activate(x, mode)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D) with positions (..., S). Rotates pairs (D/2)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked-causal; decode path with KV cache)
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
    return specs


def _proj(ctx, x, w, b, name):
    """(B,S,D) x (D,H,hd) -> (B,S,H,hd) through the engine (2D matmul form)."""
    d = w.shape[0]
    out = ctx.linear(x, w.reshape(d, -1), b.reshape(-1) if b is not None else None, name=name)
    return out.reshape(x.shape[:-1] + w.shape[1:])


def _sdpa_chunked(q, k, v, q_positions, k_positions, causal: bool):
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd) (KV pre-repeated to H so the head dim
    shards over the model axis for EVERY kv_heads count — the 5-D (KV,G)
    layout forced head replication whenever kv_heads %% TP != 0, §Perf A)."""
    b, sq, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    n_chunks = max(1, sq // Q_CHUNK) if sq % Q_CHUNK == 0 else 1
    qc = q.reshape(b, n_chunks, sq // n_chunks, h, hd)
    qp = q_positions.reshape(n_chunks, sq // n_chunks)

    def chunk_fn(_, qq):
        q_i, qp_i = qq  # (B, Qc, H, hd), (Qc,)
        scores = jnp.einsum("bqhd,bshd->bhqs", q_i.astype(jnp.float32), k.astype(jnp.float32))
        scores = scores * scale
        if causal:
            mask = qp_i[:, None] >= k_positions[None, :]  # (Qc, Sk)
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
        return None, out

    _, outs = jax.lax.scan(chunk_fn, None, (jnp.moveaxis(qc, 1, 0), qp))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def _sdpa_flash_xla(q, k, v, q_positions, k_positions, causal: bool,
                    q_chunk: int = 512, k_chunk: int = 512):
    """KV-chunked online-softmax attention (pure-JAX flash twin).

    q, k, v: (B,S,H,hd) (KV pre-repeated to H — see _sdpa_chunked). Never
    materializes more than a (Qc, Kc) score tile per (q-chunk, k-chunk) pair —
    the HBM-traffic shape the Pallas kernel (kernels/flash_attention) realizes
    on TPU. Tested equal to both the naive reference and the kernel.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hd_v = v.shape[-1]  # may differ from hd (MLA: scores over R+r, values R)
    scale = 1.0 / math.sqrt(hd)
    qc = q_chunk if sq % q_chunk == 0 else sq
    kc = k_chunk if sk % k_chunk == 0 else sk
    nq, nk = sq // qc, sk // kc
    q_r = jnp.moveaxis(q.reshape(b, nq, qc, h, hd), 1, 0)
    qp_r = q_positions.reshape(nq, qc)
    k_r = jnp.moveaxis(k.reshape(b, nk, kc, h, hd), 1, 0)
    v_r = jnp.moveaxis(v.reshape(b, nk, kc, h, hd_v), 1, 0)
    kp_r = k_positions.reshape(nk, kc)

    def q_step(_, qq):
        q_i, qp_i = qq  # (B,Qc,H,hd), (Qc,)
        q_f = q_i.astype(jnp.float32)

        def k_step(carry, kk):
            m, l, acc = carry
            k_j, v_j, kp_j = kk
            s = jnp.einsum("bqhd,bshd->bhqs", q_f, k_j.astype(jnp.float32)) * scale
            if causal:
                mask = qp_i[:, None] >= kp_j[None, :]
                s = jnp.where(mask[None, None], s, -1e30)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p, v_j.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (k_r, v_r, kp_r))
        out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
        return None, jnp.moveaxis(out, 2, 1).astype(v.dtype)  # (B,Qc,H,hd)

    _, outs = jax.lax.scan(q_step, None, (q_r, qp_r))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd_v)


def cache_row_write(c, x, i):
    """Write block ``x`` (B, S, ...) into rows [i, i+S) of cache ``c``
    (B, Smax, ...), ``i`` (B,) int32 — the decode/prefill KV write.

    Two lowerings with identical values:

    * single device: a vmapped ``dynamic_update_slice`` — O(S) rows touched,
      in-place on the donated cache buffer;
    * under a mesh: a gather + select over the row axis. The vmapped DUS
      lowers to a scatter that XLA's SPMD partitioner cannot lower inside
      the nested burst/layer scans whenever an MoE dispatch shares the
      program (hlo_verifier RET_CHECK on the scatter index broadcast,
      jax 0.4.37) — the gather form is partitioner-friendly on every family.
      Start indices are clamped exactly like DUS clamps them.
    """
    from repro.sharding.partition import current_mesh_axes

    s = x.shape[1]
    if not current_mesh_axes():
        start = (lambda b_i: (b_i,) + (0,) * (x.ndim - 2))
        upd = jax.vmap(lambda cb, xb, ib: jax.lax.dynamic_update_slice(cb, xb, start(ib)))
        return upd(c, x.astype(c.dtype), i)
    i = jnp.clip(i, 0, c.shape[1] - s)  # DUS start-clamping semantics
    j = jnp.arange(c.shape[1], dtype=jnp.int32)[None, :] - i[:, None]  # (B, Smax)
    valid = (j >= 0) & (j < s)
    idx = jnp.clip(j, 0, s - 1).reshape(j.shape + (1,) * (x.ndim - 2))
    gathered = jnp.take_along_axis(x.astype(c.dtype), idx, axis=1)
    return jnp.where(valid.reshape(idx.shape), gathered, c)


def attention(p, x, cfg: ModelConfig, ctx: EngineContext, *, positions, name, cache=None,
              causal: bool = True):
    """Returns (out, new_cache). cache = dict(k, v, index) for decode."""
    b, s, _ = x.shape
    kvh, g, hd = cfg.num_kv_heads, cfg.kv_groups, cfg.head_dim

    q = _proj(ctx, x, p["wq"], p.get("bq"), f"{name}.q")  # (B,S,H,hd)
    k = _proj(ctx, x, p["wk"], p.get("bk"), f"{name}.k")
    v = _proj(ctx, x, p["wv"], p.get("bv"), f"{name}.v")

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # H-layout with KV repeated over groups: the head dim then shards over the
    # model axis for every kv_heads count (the (KV, G) split replicated
    # attention whenever kv_heads %% TP != 0 — §Perf A). The repeat is a
    # broadcast on TPU, not a copy.
    q = constrain(q, "batch", None, "model", None)

    if cache is None:
        kr = jnp.repeat(k, g, axis=2) if g > 1 else k
        vr = jnp.repeat(v, g, axis=2) if g > 1 else v
        kr = constrain(kr, "batch", None, "model", None)
        vr = constrain(vr, "batch", None, "model", None)
        k_pos = positions
        if ctx.attn_impl == "flash":
            out = _sdpa_flash_xla(q, kr, vr, positions, k_pos, causal=causal)
        else:
            out = _sdpa_chunked(q, kr, vr, positions, k_pos, causal=causal)
        new_cache = None
    else:
        idx = cache["index"]  # (B,) int32: per-row next write slot
        ck = cache_row_write(cache["k"], k, idx)
        cv = cache_row_write(cache["v"], v, idx)
        s_max = ck.shape[1]
        scale = 1.0 / math.sqrt(hd)
        from repro.sharding.partition import current_mesh_axes

        if ctx.attn_impl == "decode_kernel" and not current_mesh_axes():
            # Pallas cache-decode kernel: GQA resolved by index maps (no
            # repeated-KV materialization), (S, Smax) score tile stays in
            # VMEM. Mesh-sharded caches keep the XLA chain below.
            from repro.kernels.decode_attention import gqa_decode_attention

            out = gqa_decode_attention(q, ck, cv, positions, scale=scale)
        else:
            k_pos = jnp.arange(s_max)
            # per-query causal validity: query at position p sees keys <= p.
            # With s == 1 this is the classic decode mask; with s > 1 (batched
            # prefill writing a whole prompt at once) it is causal within the
            # new block.
            valid = k_pos[None, None, :] <= positions[:, :, None]  # (B, Sq, Smax)
            ckr = jnp.repeat(ck, g, axis=2) if g > 1 else ck
            cvr = jnp.repeat(cv, g, axis=2) if g > 1 else cv
            scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), ckr.astype(jnp.float32))
            scores = jnp.where(valid[:, None], scores * scale, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(cvr.dtype), cvr)
        new_cache = {"k": ck, "v": cv, "index": idx + s}

    out = out.reshape(b, s, cfg.num_heads * hd)
    wo = p["wo"].reshape(cfg.num_heads * hd, cfg.d_model)
    return ctx.linear(out, wo, name=f"{name}.o"), new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "index": jnp.zeros((batch,), jnp.int32),
    }


def attn_cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
        "index": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP (gated / plain) through the multi-AF block
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    specs = {
        "up": ParamSpec((d, f), ("embed", "mlp")),
        "down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.glu:
        specs["gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs


def mlp(p, x, cfg: ModelConfig, ctx: EngineContext, *, name):
    # linear_af fuses the dot and the activation epilogue into one Pallas
    # pass on the kernel backend; every other backend unfuses to the same
    # linear -> multi-AF chain as before
    if cfg.glu:
        up = ctx.linear(x, p["up"], name=f"{name}.up")
        h = ctx.linear_af(x, p["gate"], af=cfg.act, name=f"{name}.gate") * up
    else:
        h = ctx.linear_af(x, p["up"], af=cfg.act, name=f"{name}.up")
    return ctx.linear(h, p["down"], name=f"{name}.down")


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-based, sort/gather dispatch)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.02),
        "up": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "gate": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "down": ParamSpec((e, f, d), ("experts", "mlp", "embed")),
    }
    if m.num_shared_experts:
        fs = m.d_ff_shared * m.num_shared_experts
        specs["shared"] = {
            "up": ParamSpec((d, fs), ("embed", "mlp")),
            "gate": ParamSpec((d, fs), ("embed", "mlp")),
            "down": ParamSpec((fs, d), ("mlp", "embed")),
        }
    return specs


def _dispatch_indices(expert_idx, num_experts: int, capacity: int):
    """Per-row sort/gather dispatch plan.

    expert_idx: (S, K) int32 chosen experts for each of S tokens.
    Returns (gather_idx (E, C) into S*K flat choices, valid (E, C) mask,
             rank (S, K) position of each choice in its expert queue).
    """
    s, k = expert_idx.shape
    flat = expert_idx.reshape(-1)  # (S*K,)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    pos = jnp.arange(s * k, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, pos, -1))
    rank_sorted = pos - seg_start  # position within the expert's queue
    rank = jnp.zeros((s * k,), jnp.int32).at[order].set(rank_sorted)
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(capacity, dtype=jnp.int32)
    gather_pos = starts[:, None] + slot[None, :]  # (E, C) index into sorted order
    valid = slot[None, :] < jnp.minimum(counts[:, None], capacity)
    gather_idx = order[jnp.clip(gather_pos, 0, s * k - 1)]  # (E, C) -> flat choice id
    return gather_idx, valid, rank.reshape(s, k)


def _get_shard_map():
    """(shard_map, relax-kwargs, physical mesh) across jax versions."""
    try:
        from jax import shard_map as sm

        relax = {"check_vma": False}
    except ImportError:  # pragma: no cover — older jax
        from jax.experimental.shard_map import shard_map as sm

        relax = {"check_rep": False}
    from jax._src import mesh as mesh_lib

    return sm, relax, mesh_lib.thread_resources.env.physical_mesh


def _combine_scatter(yw, token_of_choice, s: int, d: int):
    """Combine expert-slot outputs into per-token sums.

    Under a mesh, each model shard scatter-adds its LOCAL experts' slots into
    a (B, S, D) partial and psums over the model axis (shard_map) — the
    minimum-communication combine (~1 GB/dev/layer). A plain GSPMD scatter
    here replicated the batch and moved 1.7 TB/dev (§Perf B); shard_map makes
    the partial-sum structure explicit. Backward of psum+local-scatter is a
    broadcast+gather — no K-replicated cotangents.
    """
    b, e, capacity, _ = yw.shape

    def local(yw_l, tok_l):
        bb = yw_l.shape[0]
        out = (
            jnp.zeros((bb, s, d), yw_l.dtype)
            .at[jnp.arange(bb)[:, None], tok_l.reshape(bb, -1)]
            .add(yw_l.reshape(bb, -1, d))
        )
        return jax.lax.psum(out, "model")

    from repro.sharding.partition import BATCH_AXES, current_mesh_axes, mesh_axis_sizes

    axes = current_mesh_axes()
    sizes = mesh_axis_sizes()
    if "model" in axes and e % max(sizes.get("model", 1), 1) == 0:
        from jax.sharding import PartitionSpec as _P

        _shard_map, _relax, phys = _get_shard_map()
        batch_axes = tuple(a for a in BATCH_AXES if a in axes)
        import numpy as _np

        bext = int(_np.prod([sizes.get(a, 1) for a in batch_axes])) if batch_axes else 1
        bspec = batch_axes if (batch_axes and b % bext == 0) else None
        return _shard_map(
            local,
            mesh=phys,
            in_specs=(
                _P(bspec, "model", None, None),
                _P(bspec, "model", None),
            ),
            out_specs=_P(bspec, None, None),
            **_relax,
        )(yw, token_of_choice)
    bb = yw.shape[0]
    return (
        jnp.zeros((bb, s, d), yw.dtype)
        .at[jnp.arange(bb)[:, None], token_of_choice.reshape(bb, -1)]
        .add(yw.reshape(bb, -1, d))
    )


def moe_ffn(p, x, cfg: ModelConfig, ctx: EngineContext, *, name,
            dropless: bool = False):
    """Batched-per-row MoE: dispatch stays local to each batch row; the E-axis
    reshard of the (B, E, C, D) buffer is the all-to-all (DESIGN.md §6).

    ``dropless`` (the cached-decode path) widens short blocks' capacity so no
    routed token is ever dropped. Returns (out, aux) where aux carries the
    load-balancing loss terms.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    capacity = max(k, int(math.ceil(s * k / e * m.capacity_factor)))
    if dropless and s <= 64:
        # short cached-decode blocks (speculative verify, short batched
        # prefills): a token's top-k experts are distinct, so per-expert load
        # is at most s — this capacity is dropless, making S>1 decode match
        # token-by-token decode (whose s=1 capacity never drops either). The
        # multi-token verifier leans on that parity. Training/eval forwards
        # (dropless=False) and long prefills keep capacity-factor economics.
        capacity = max(capacity, s)

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    router_logits = constrain(router_logits, "batch", None, None)
    probs = constrain(jax.nn.softmax(router_logits, axis=-1), "batch", None, None)
    top_p, top_i = jax.lax.top_k(probs, k)  # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    plan_fn = jax.vmap(lambda ti: _dispatch_indices(ti, e, capacity))
    from repro.sharding.partition import current_mesh_axes

    if current_mesh_axes():
        # manual-mode island: the plan is a sort/scan/gather chain over a few
        # hundred int32s, and XLA's SPMD partitioner SILENTLY miscomputes it
        # when the downstream dispatch constraint propagates a sharding onto
        # it (observed: gather_idx off by whole tokens on a 2x2 mesh, jax
        # 0.4.37). Replicated in/out shard_map makes every device compute
        # the full plan with the unpartitioned lowering — bit-identical to
        # single-device by construction, and O(S*K) int work is free.
        from jax.sharding import PartitionSpec as _P

        sm, relax, phys = _get_shard_map()
        plan = sm(plan_fn, mesh=phys, in_specs=_P(), out_specs=_P(), **relax)(top_i)
    else:
        plan = plan_fn(top_i)
    gather_idx, valid, rank = plan  # (B,E,C), (B,E,C), (B,S,K)

    token_of_choice = gather_idx // k  # (B, E, C) -> source token position
    x_disp = jnp.take_along_axis(
        x, token_of_choice.reshape(b, e * capacity, 1), axis=1
    ).reshape(b, e, capacity, d) * valid[..., None].astype(x.dtype)
    # dispatch reshard: this boundary is where the EP all-to-all belongs;
    # without the constraint GSPMD replicated the batch and all-reduced
    # expert outputs (§Perf B). 2D EP (experts over data x model, weights
    # fully local) when expert count allows; else batch x model.
    x_disp = constrain(x_disp, "batch", "model", None, None)

    # expert FFN (einsum over stacked expert weights; E is the EP axis)
    def expert_mm(h, w):
        return jnp.einsum("becd,edf->becf", h.astype(cfg.compute_dtype), w.astype(cfg.compute_dtype))

    up = expert_mm(x_disp, p["up"])
    gate = expert_mm(x_disp, p["gate"])
    h = apply_af(gate, cfg.act, ctx) * up
    y = jnp.einsum("becf,efd->becd", h.astype(cfg.compute_dtype), p["down"].astype(cfg.compute_dtype))
    y = constrain(y, "batch", "model", None, None)

    # combine: scatter-add each expert slot's weighted output back to its
    # token. Combine-as-scatter (not gather+einsum) is deliberate: the
    # einsum-combine's BACKWARD materializes a K-replicated (B, S*K, D)
    # full-D f32 cotangent (872 GB/dev all-gather + 872 GB all-reduce
    # measured); scatter-add's backward is a plain gather (§Perf B).
    kept = (rank < capacity).astype(jnp.float32) * top_p  # (B,S,K); drops -> 0
    w_slot = jnp.take_along_axis(
        kept.reshape(b, s * k), gather_idx.reshape(b, e * capacity), axis=1
    ) * valid.reshape(b, e * capacity)  # (B, E*C) weight of the choice per slot
    yw = y.astype(cfg.compute_dtype) * w_slot.reshape(b, e, capacity, 1).astype(
        cfg.compute_dtype
    )
    out = _combine_scatter(yw, token_of_choice, s, d).astype(x.dtype)
    out = constrain(out, "batch", None, None)

    if m.num_shared_experts:
        out = out + mlp(p["shared"], x, cfg, ctx, name=f"{name}.shared")

    # aux: load-balance loss. Scatter-counts instead of a one_hot (B,S,E)
    # materialization — the one_hot form all-gathered 62 GB/dev of f32 router
    # probs per pass (§Perf B iteration 4). Cached decode (dropless=True)
    # skips it entirely: the loss is a training quantity the serving loop
    # discards, and its flat scatter-add is the same scatter class the SPMD
    # partitioner mis-lowers inside nested decode scans (see
    # ``cache_row_write``) — no reason to carry it through the burst.
    if dropless:
        aux = {"lb_loss": jnp.zeros((), jnp.float32)}
    else:
        me = jnp.mean(probs, axis=(0, 1))  # (E,)
        counts = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
        ce = counts / (b * s * k)
        aux = {"lb_loss": e * jnp.sum(me * ce)}
    return out, aux
