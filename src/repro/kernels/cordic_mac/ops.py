"""jit'd wrapper around the cordic_mac Pallas kernel.

Maps CARMEN semantics onto the kernel:

* activations -> binary-point quantization into ``x_fmt`` (saturating), stored
  int8/int16 — the PE's activation memory bank;
* weights -> depth-d signed-digit rounding in ``w_fmt`` (the full arithmetic
  effect of a depth-d linear-CORDIC multiplier), stored int8/int16 — the PE's
  weight memory bank;
* kernel -> MXU integer matmul + requant epilogue.

On CPU (this container) the kernel runs in interpret mode; on TPU it compiles
natively. ``cordic_mac(x, w, depth, ...)`` equals ``carmen_matmul_fast``
bit-for-bit in the FxP8 path; in the FxP16 path the kernel's integer
accumulator is *more* exact than the oracle's f32 matmul (products on the
2^-26 grid), so tests compare at f32-ulp tolerance.

Accumulator envelope (as in silicon — the register is finite): the int32
accumulator is exact while K * max|x| * max|w| * 2^(frac_x + frac_w) < 2^31.
FxP8 (frac 6+6): K*|x||w| < 2^19 — never binds. FxP16 (frac 12+14): bounded by
normalized operands; the production MXU path is int8/FxP8 regardless (v5e has
no native int16 matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cordic
from repro.core.fxp import FXP8, FXP8_UNIT, FxPFormat, quantize

from . import kernel as _k


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    # cached: jax.default_backend() walks the backend registry on every call,
    # and this probe sits on the per-layer hot path
    return jax.default_backend() == "cpu"


def _pad_to(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def quantize_weights(w, depth: int, w_fmt: FxPFormat = FXP8_UNIT):
    """Weight memory bank: signed-digit ints + the (scalar) bank scale."""
    sd = cordic.signed_digit_round(w, depth, w_fmt)
    w_q = jnp.round(sd * (1 << w_fmt.frac)).astype(jnp.int32)
    dtype = jnp.int8 if w_fmt.bits <= 8 else jnp.int16
    return w_q.astype(dtype), np.float32(w_fmt.scale)


def quantize_activations(x, x_fmt: FxPFormat = FXP8):
    xq = quantize(x, x_fmt)
    dtype = jnp.int8 if x_fmt.bits <= 8 else jnp.int16
    return xq.astype(dtype), np.float32(x_fmt.scale)


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth", "x_fmt", "w_fmt", "fuse_relu", "interpret", "bm", "bn", "bk",
        "w_prequantized",
    ),
)
def cordic_mac(
    x,
    w,
    *,
    depth: int,
    x_fmt: FxPFormat = FXP8,
    w_fmt: FxPFormat = FXP8_UNIT,
    fuse_relu: bool = False,
    interpret: bool | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    w_prequantized: bool = False,
):
    """CARMEN MAC-array matmul: float (M, K) x (K, N) -> float32 (M, N).

    ``w_prequantized=True`` declares that ``w`` already carries depth-``depth``
    signed-digit values (a prepared weight bank): the rounding recurrence is
    skipped and the values are cast straight onto the integer grid (exact —
    signed-digit values are integer multiples of the format LSB).
    """
    interpret = _interpret_default() if interpret is None else interpret
    m, k = x.shape
    k2, n = w.shape
    assert k == k2

    x_q, xs = quantize_activations(x, x_fmt)
    if w_prequantized:
        dtype = jnp.int8 if w_fmt.bits <= 8 else jnp.int16
        w_q = jnp.round(jnp.asarray(w, jnp.float32) * (1 << w_fmt.frac)).astype(jnp.int32)
        w_q, ws = w_q.astype(dtype), np.float32(w_fmt.scale)
    else:
        w_q, ws = quantize_weights(w, depth, w_fmt)

    bm = bm or min(_k.DEFAULT_BM, _round_up(m, 8))
    bn = bn or min(_k.DEFAULT_BN, _round_up(n, 128))
    bk = bk or min(_k.DEFAULT_BK, _round_up(k, 128))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    x_q = _pad_to(x_q, mp, kp)
    w_q = _pad_to(w_q, kp, np_)
    x_scale = jnp.full((mp, 1), xs, jnp.float32)
    w_scale = jnp.full((1, np_), ws, jnp.float32)

    out = _k.mac_matmul(
        x_q, w_q, x_scale, w_scale, bm=bm, bn=bn, bk=bk, fuse_relu=fuse_relu, interpret=interpret
    )
    return out[:m, :n]
