"""Paper Table II — CORDIC MAC unit comparison.

Silicon columns (LUTs, um^2, mW) have no software analogue; the algorithmic
content of Table II is (a) error vs compute budget per MAC flavour and (b) the
iterative unit's cycle cost. Rows: exact f32 dot, CARMEN fast model, CARMEN
bit-faithful, Pallas kernel — at accurate and approximate depth.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    FXP8,
    FXP8_UNIT,
    approx_depth,
    carmen_matmul_fast,
    cordic_matmul,
    dequantize,
    full_depth,
    mac_cycles,
    quantize,
)
from repro.kernels.cordic_mac import ops as mac_ops

M, K, N = 64, 256, 64


def _time(fn, *args, reps=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (M, K)).astype(np.float32)
    w = rng.uniform(-1, 1, (K, N)).astype(np.float32)
    exact = x @ w
    rows = []

    us = _time(lambda: jax.jit(lambda a, b: a @ b)(x, w))
    rows.append(("table2.exact_f32_dot", us, "err=0"))

    for mode, depth in (("accurate", full_depth(FXP8_UNIT)), ("approx", approx_depth(FXP8_UNIT))):
        f = jax.jit(lambda a, b, d=depth: carmen_matmul_fast(a, b, d, FXP8, FXP8_UNIT))
        us = _time(f, x, w)
        err = float(np.max(np.abs(np.asarray(f(x, w)) - exact))) / (np.abs(exact).max())
        cyc = mac_cycles(K, depth)
        rows.append((f"table2.carmen_fast_{mode}_d{depth}", us,
                     f"rel_err={err:.4f};cycles/MAC={cyc}"))

    xq, wq = quantize(x, FXP8), quantize(w, FXP8_UNIT)
    for mode, depth in (("accurate", full_depth(FXP8_UNIT)), ("approx", approx_depth(FXP8_UNIT))):
        f = jax.jit(lambda a, b, d=depth: cordic_matmul(a, b, d, FXP8_UNIT))
        us = _time(f, xq, wq)
        out = np.asarray(dequantize(f(xq, wq), FXP8))
        err = float(np.max(np.abs(out - exact))) / (np.abs(exact).max())
        rows.append((f"table2.bit_faithful_{mode}_d{depth}", us, f"rel_err={err:.4f}"))

    us = _time(lambda: mac_ops.cordic_mac(x, w, depth=full_depth(FXP8_UNIT)))
    rows.append(("table2.pallas_kernel_interpret", us, "bit-eq-to-fast"))

    # paper C2: cycle saving approximate vs accurate
    saving = 1 - mac_cycles(K, approx_depth(FXP8_UNIT)) / mac_cycles(K, full_depth(FXP8_UNIT))
    rows.append(("table2.cycle_reduction_claim", 0.0, f"saving={saving:.2%} (paper: 33%)"))
    return rows
