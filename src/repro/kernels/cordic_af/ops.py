"""jit'd wrapper for the multi-AF Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.activations import AF_INDEX
from repro.core.fxp import FXP8, FxPFormat

from . import kernel as _k


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    # cached: see kernels/cordic_mac/ops.py — one probe per process
    return jax.default_backend() == "cpu"


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def multi_af_pallas(
    x,
    mode: str | int,
    *,
    depth: int,
    fmt: FxPFormat = FXP8,
    interpret: bool | None = None,
):
    """Apply one of the seven AFs to an arbitrarily-shaped float array.

    ``mode`` may be a name or a runtime int index into
    ``kernel.ELEMENTWISE_AFS`` (softmax must be named — it routes to the
    row-reduction kernel and reduces over the last axis).
    """
    interpret = _interpret_default() if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape

    if isinstance(mode, str) and mode == "softmax":
        x2 = x.reshape(-1, shape[-1])
        m, n = x2.shape
        bm = 8 if m % 8 == 0 else 1
        out = _k.af_softmax(x2, depth=depth, fmt=fmt, bm=bm, interpret=interpret)
        return out.reshape(shape)

    if isinstance(mode, str):
        mode_idx = _k.ELEMENTWISE_AFS.index(mode)
    else:
        mode_idx = mode
    flat = x.reshape(1, -1) if x.ndim == 1 else x.reshape(-1, shape[-1])
    m, n = flat.shape
    bm = min(_k.DEFAULT_BM, _round_up(m, 8))
    bn = min(_k.DEFAULT_BN, _round_up(n, 128))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    if (mp, np_) != (m, n):
        flat = jnp.pad(flat, ((0, mp - m), (0, np_ - n)))
    out = _k.af_elementwise(flat, mode_idx, depth=depth, fmt=fmt, bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n].reshape(shape)


def af_index(mode: str) -> int:
    """Runtime mode index for a named AF (elementwise set)."""
    if mode == "softmax":
        raise ValueError("softmax routes to the reduction kernel; pass mode='softmax'")
    return _k.ELEMENTWISE_AFS.index(mode)


__all__ = ["multi_af_pallas", "af_index", "AF_INDEX"]
