"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  table2_mac      Table II   CORDIC MAC units
  table3_af       Table III  multi-AF block
  fig3_accuracy   Fig. 3     accuracy vs precision x depth (claims C1/C2)
  table4_system   Table IV   engine throughput per execution mode
  table5_scaling  Table V    PE-lane scaling (claim C4)
  fig4_layerwise  Fig. 4     VGG-16 precision-aware schedule
"""
import sys


def main() -> None:
    from . import (
        fig3_accuracy,
        fig4_layerwise,
        table2_mac,
        table3_af,
        table4_system,
        table5_scaling,
    )

    modules = [table2_mac, table3_af, fig3_accuracy, table4_system, table5_scaling, fig4_layerwise]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in modules:
        if only and only not in mod.__name__:
            continue
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
