"""Continuous-batching inference under the CARMEN quantized engine.

Serves a batch of requests through the decode engine three times — exact
(FP32 baseline), carmen (paper-faithful FxP8), int8 (TPU production path) —
and reports tokens/s plus generation agreement vs the baseline: the
end-to-end incarnation of the paper's <2% accuracy-loss claim.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineContext, FXP8, FXP16, PrecisionPolicy
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request

cfg = reduced(get_config("qwen3-8b"))
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
requests = [
    Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 12) for i in range(6)
]

results = {}
for mode, ctx in (
    ("exact", EngineContext(mode="exact", compute_dtype=jnp.float32)),
    ("carmen-fxp16", EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                                   compute_dtype=jnp.float32)),
    ("int8", EngineContext(mode="int8", policy=PrecisionPolicy.accurate(FXP8),
                           compute_dtype=jnp.float32)),
):
    server = BatchedServer(model, ctx, params, slots=3, max_len=32)
    t0 = time.time()
    out = server.run([Request(r.rid, r.prompt, r.max_new) for r in requests])
    dt = time.time() - t0
    toks = sum(len(v) for v in out.values())
    results[mode] = out
    print(f"{mode:13s}: {toks} tokens in {dt:5.1f}s ({toks/dt:6.1f} tok/s)")

base = results["exact"]
for mode in ("carmen-fxp16", "int8"):
    agree = np.mean([
        np.mean(np.array(results[mode][rid]) == np.array(base[rid])) for rid in base
    ])
    print(f"token agreement {mode} vs exact: {agree:.1%}")
