"""jit'd wrapper: model-layout (B, S, H/KV, D) GQA -> flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    # cached: see kernels/cordic_mac/ops.py — one probe per process
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, interpret: bool | None = None,
                    bq: int | None = None, bk: int | None = None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.

    Returns (B, Sq, H, D). Scores never materialize in HBM (see kernel.py).
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    # broadcast kv heads over groups and fold (B, H) into one grid axis
    kb = jnp.repeat(k, g, axis=2) if g > 1 else k
    vb = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = kb.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = vb.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    kwargs = {}
    if bq:
        kwargs["bq"] = bq
    if bk:
        kwargs["bk"] = bk
    out = _k.flash_attention_bhsd(qf, kf, vf, causal=causal, interpret=interpret, **kwargs)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
