import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

# ^ MUST precede any jax import (jax locks the device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles abstract (ShapeDtypeStruct) params / optimizer state / caches
     with their NamedShardings,
  3. ``jit(step).lower(...).compile()`` — proving the distribution config is
     coherent (shardings consistent, collectives legal, memory bounded),
  4. records memory_analysis / cost_analysis / per-collective bytes into
     ``artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json`` for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both      (full 40-cell table)
"""
import argparse
import gzip
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import hlo_analysis

from repro.configs import ALL_SHAPES, ARCHS, SHAPES, get_config, shape_applicable
from repro.core import EngineContext, FXP8, PrecisionPolicy
from repro.data.pipeline import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.sharding import partition
from repro.train import optimizer as opt
from repro.train.train_loop import TrainConfig, make_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

def engine_ctx(mode: str, attn: str = "xla", tp_bf16: bool = False) -> EngineContext:
    if mode == "exact":
        return EngineContext(mode="exact", attn_impl=attn, tp_reduce_bf16=tp_bf16)
    return EngineContext(mode=mode, policy=PrecisionPolicy.accurate(FXP8), attn_impl=attn,
                         tp_reduce_bf16=tp_bf16)


def _batch_sharding(mesh, shape_tuple):
    """Shard dim 0 over (pod, data) when divisible; replicate otherwise."""
    axes = tuple(a for a in partition.BATCH_AXES if a in mesh.axis_names)
    import numpy as np

    extent = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if shape_tuple and shape_tuple[0] % max(extent, 1) == 0 and extent > 1:
        return NamedSharding(mesh, P(axes))
    return NamedSharding(mesh, P())


def build_cell(arch: str, shape_name: str, mesh, mode: str = "exact", attn: str = "xla",
               pad_heads_to: int = 0, tp_bf16: bool = False, microbatches: int = 1,
               prepared: bool = False):
    """Returns (step_fn, example_args, in_shardings, out_shardings)."""
    cfg = get_config(arch)
    if pad_heads_to:
        import dataclasses as _dc

        # Megatron-style head padding: allocate ceil(H/TP)*TP heads so the TP
        # axis divides them; extra heads carry zero weights (beyond-paper).
        new_h = ((cfg.num_heads + pad_heads_to - 1) // pad_heads_to) * pad_heads_to
        cfg = _dc.replace(cfg, num_heads=new_h)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    ctx = engine_ctx(mode, attn, tp_bf16)
    specs = model.specs()
    param_sh, _ = partition.param_shardings(specs, mesh)
    aparams = model.abstract_params(jnp.bfloat16)
    if prepared and mode != "exact" and shape.kind != "train":
        # lower the serving fast path: weight banks pre-formatted by the
        # backend registry (inference cells only — QAT trains raw weights)
        from repro.core import prepare_params

        aprep = jax.eval_shape(
            lambda p: prepare_params(p, ctx.policy, mode, specs=specs), aparams
        )
        # shared serving placement rules (sharding/partition.py): payloads
        # inherit the raw leaf's sharding, per-channel scales ride the axes
        # they share with the payload, tied lm_head uses the transposed
        # embedding rule
        param_sh = partition.prepared_shardings(aprep, specs, mesh)
        aparams = aprep
    batch = input_specs(cfg, shape)
    batch_sh = {k: _batch_sharding(mesh, v.shape) for k, v in batch.items()}
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        tcfg = TrainConfig(remat=True, microbatches=microbatches)
        step = make_train_step(model, ctx, tcfg)
        aopt = opt.abstract_state(aparams)
        opt_sh = opt.AdamWState(step=repl, m=param_sh, v=param_sh)
        metrics_sh = {k: repl for k in ("ce_loss", "grad_norm", "lr", "loss")}
        return (
            step,
            (aparams, aopt, batch),
            (param_sh, opt_sh, batch_sh),
            (param_sh, opt_sh, metrics_sh),
        )

    if shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = model.forward(params, batch, ctx)
            return logits
        logits_sh = _batch_sharding(mesh, (shape.global_batch,))
        return prefill, (aparams, batch), (param_sh, batch_sh), logits_sh

    # decode: one token against a seq_len cache
    cache = model.make_cache(shape.global_batch, shape.seq_len, jnp.bfloat16, abstract=True)
    cache_sh = partition.cache_shardings(cache, mesh, cfg, row_axis_len=shape.seq_len)

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache, ctx)

    toks = batch["tokens"]
    toks_sh = _batch_sharding(mesh, toks.shape)
    logits_sh = _batch_sharding(mesh, (shape.global_batch,))
    return (
        decode,
        (aparams, toks, cache),
        (param_sh, toks_sh, cache_sh),
        (logits_sh, cache_sh),
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str = "exact",
             out_dir: Optional[str] = None, tag: str = "", attn: str = "xla",
             pad_heads_to: int = 0, tp_bf16: bool = False, microbatches: int = 1,
             prepared: bool = False) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if prepared and not tag:
        tag = "prepared"
    rec: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode, "tag": tag,
        "prepared": prepared,
    }
    if not ok:
        rec.update(status="skip", reason=why)
        return _emit(rec, out_dir)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        with mesh:
            step, args, in_sh, out_sh = build_cell(
                arch, shape_name, mesh, mode, attn=attn, pad_heads_to=pad_heads_to,
                tp_bf16=tp_bf16, microbatches=microbatches, prepared=prepared,
            )
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: one dict per device
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            costs = hlo_analysis.analyze(hlo)  # per-DEVICE program costs
        # persist the optimized HLO so perf iterations re-analyze offline
        hlo_dir = os.path.join(out_dir or ARTIFACTS, "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        tagpart = f"__{tag}" if tag else ""
        modepart = f"__{mode}" if mode != "exact" else ""
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}__{shape_name}__{mesh_kind}{modepart}{tagpart}.hlo.gz"),
                "wt") as f:
            f.write(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                k: int(getattr(mem, k, 0) or 0)
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
            },
            # loop-corrected per-device terms (launch/hlo_analysis.py)
            flops_dev=costs.dot_flops,
            hbm_bytes_dev=costs.hbm_bytes,
            hbm_bytes_upper_dev=costs.hbm_bytes_upper,
            coll_bytes_dev=costs.collective_bytes,
            coll_by_kind={k: float(v) for k, v in costs.collective_by_kind.items()},
            while_trips=costs.while_trips[:64],
            # raw XLA numbers for reference (scan bodies counted once)
            xla_flops=float(cost.get("flops", 0.0)),
            xla_bytes=float(cost.get("bytes accessed", 0.0)),
            hlo_size=len(hlo),
        )
        print(f"[ok] {arch} x {shape_name} x {mesh_kind} ({mode}{'/' + tag if tag else ''}): "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"flops/dev {costs.dot_flops:.3e} coll/dev {costs.collective_bytes/1e9:.2f} GB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep the sweep going
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {type(e).__name__}: {e}")
    return _emit(rec, out_dir)


def _emit(rec: Dict, out_dir: Optional[str]) -> Dict:
    out_dir = out_dir or ARTIFACTS
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    mode = f"__{rec['mode']}" if rec.get("mode", "exact") != "exact" else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{mode}{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES], default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--mode", choices=["exact", "carmen", "int8"], default="exact")
    ap.add_argument("--tag", default="", help="artifact suffix for perf experiments")
    ap.add_argument("--attn", choices=["xla", "flash"], default="xla")
    ap.add_argument("--pad-heads-to", type=int, default=0,
                    help="pad attention heads up to a multiple (TP divisibility)")
    ap.add_argument("--tp-bf16", action="store_true",
                    help="bf16 dot outputs (TP partial-sums all-reduce in bf16)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches inside train_step")
    ap.add_argument("--prepared", action="store_true",
                    help="lower inference cells with prepared weight banks "
                         "(prepare_params; ignored for train shapes / exact mode)")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if args.arch is None else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.shape is None else [args.shape]
    if not args.all and args.arch is None and args.shape is None:
        ap.error("pass --arch/--shape or --all")

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, args.mode, args.out, args.tag,
                               attn=args.attn, pad_heads_to=args.pad_heads_to,
                               tp_bf16=args.tp_bf16, microbatches=args.microbatches,
                               prepared=args.prepared)
                failures += rec["status"] == "fail"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
