"""Regression tests for the HLO cost analyzer — the roofline's load-bearing wall.

Every rule the §Roofline methodology claims is pinned here against real
compiled HLO: loop-trip correction, slice-aware byte charging, in-place DUS
aliasing, the VMEM-tile residency rule, and the dual (fused vs literal) models.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import VMEM_TILE_BYTES, analyze


def _hlo(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_trip_count_exact():
    def body(h, w):
        return jnp.tanh(h @ w), None

    h = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    c = analyze(_hlo(lambda h, ws: jax.lax.scan(body, h, ws)[0], h, ws))
    assert c.dot_flops == 7 * 2 * 32 * 64 * 64
    assert 7 in c.while_trips


def test_nested_scan_multiplies():
    def inner(h, w):
        return jnp.tanh(h @ w), None

    def outer(h, wg):
        return jax.lax.scan(inner, h, wg)[0], None

    h = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 32, 32), jnp.float32)
    c = analyze(_hlo(lambda h, ws: jax.lax.scan(outer, h, ws)[0], h, ws))
    assert c.dot_flops == 12 * 2 * 16 * 32 * 32


def test_grad_counts_both_passes():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    fwd = analyze(_hlo(loss, w, x)).dot_flops
    both = analyze(_hlo(jax.grad(loss), w, x)).dot_flops
    assert both >= 2 * fwd


def test_scan_does_not_charge_full_stacked_params_per_trip():
    """dynamic-slice of stacked weights must charge slice bytes, not L x full."""
    n_layers, d = 8, 256
    full_bytes = n_layers * d * d * 4

    def body(h, w):
        return jnp.tanh(h @ w), None

    h = jax.ShapeDtypeStruct((32, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((n_layers, d, d), jnp.float32)
    c = analyze(_hlo(lambda h, ws: jax.lax.scan(body, h, ws)[0], h, ws))
    # literal worst case would be trips x full stack = 8 x full; the slice-aware
    # model must stay well under 2 x full (weights read once each + h traffic)
    assert c.hbm_bytes < 3 * full_bytes, (c.hbm_bytes, full_bytes)


def test_vmem_tile_rule_small_local_tiles_free():
    """A small dot tile consumed locally inside a loop adds ~no HBM bytes;
    a large materialized score tensor is charged."""

    def flashish(q, k):
        def step(acc, kk):
            s = q @ kk.T  # (64, 64) tile = 16 KB << threshold
            return acc + jnp.sum(jnp.exp(s), -1), None

        acc0 = jnp.zeros((q.shape[0],), jnp.float32)
        return jax.lax.scan(step, acc0, k)[0]

    q = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    ks = jax.ShapeDtypeStruct((16, 64, 32), jnp.float32)
    c = analyze(_hlo(flashish, q, ks))
    # traffic should be ~ k reads (16*64*32*4 = 128 KB x2) + q, NOT 16 tiles x2
    assert c.hbm_bytes < 3e6, c.hbm_bytes


def test_large_scores_are_charged():
    def naive(q, k):
        s = q @ k.T  # (2048, 2048) f32 = 16 MB > threshold
        return jnp.sum(jax.nn.softmax(s, -1), -1)

    q = jax.ShapeDtypeStruct((2048, 64), jnp.float32)
    k = jax.ShapeDtypeStruct((2048, 64), jnp.float32)
    c = analyze(_hlo(naive, q, k))
    assert 2048 * 2048 * 4 <= VMEM_TILE_BYTES * 8  # sanity: it IS above threshold
    assert c.hbm_bytes >= 2 * 2048 * 2048 * 4  # write + read of the scores


def test_dual_models_ordering():
    """fused model <= literal model, always."""

    def f(x, w):
        h = jnp.tanh(x @ w)
        return jnp.sum(h * 2.0 + 1.0)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = analyze(_hlo(f, x, w))
    assert c.hbm_bytes <= c.hbm_bytes_upper


def test_collectives_counted_with_trips():
    """psum inside a scanned shard_map body counts once per trip."""
    if len(jax.devices()) != 1:
        pytest.skip("host-device test")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P

    def body(c, x):
        def local(xl):
            return jax.lax.psum(xl, "model")

        try:
            from jax import shard_map

            y = shard_map(local, mesh=mesh, in_specs=P(None, None), out_specs=P(None, None),
                          check_vma=False)(x)
        except (ImportError, TypeError):
            from jax.experimental.shard_map import shard_map as sm

            y = sm(local, mesh=mesh, in_specs=P(None, None), out_specs=P(None, None),
                   check_rep=False)(x)
        return c + jnp.sum(y), None

    xs = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    with mesh:
        hlo = jax.jit(
            lambda xs: jax.lax.scan(body, jnp.zeros(()), xs)[0]
        ).lower(xs).compile().as_text()
    c = analyze(hlo)
    # 5 trips x 16*16*4B each (if the psum survives SPMD on a 1-element axis,
    # it may be elided; accept either zero or the per-trip value)
    assert c.collective_bytes in (0.0,) or c.collective_bytes >= 5 * 16 * 16 * 4
