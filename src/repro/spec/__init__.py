"""Self-speculative serving: draft on a shallow CORDIC point, verify deep.

CARMEN's configuration registers trade CORDIC iteration depth for accuracy on
the *same* weights and hardware (paper §II-C) — which is exactly the split
speculative decoding needs, with zero extra model. This package turns the
precision ladder of a :class:`repro.runtime.MultiPointBank` into wall-clock
(and weight-pass) speedup per accepted token:

* **draft** (:func:`make_draft_loop`): a jitted ``lax.scan`` rolls the
  *approximate* execution point forward ``k`` tokens, one classic decode step
  per token. Drafted KV rows land in the cache region PAST each slot's
  committed index — the per-query-causal mask makes that region invisible to
  committed positions, so it doubles as the scratch KV view; no copies.
* **verify** (:func:`make_verify_step`): all ``k+1`` positions (the pending
  token plus the k drafts) run through the *accurate* point in ONE multi-token
  ``decode_step`` (the S>1 per-query-causal path), overwriting the drafted
  rows with accurate KV before attention reads them. Acceptance is greedy
  exact-match for ``temperature<=0`` slots and standard rejection sampling
  (accept ``d`` with prob ``min(1, p(d)/q(d))``, resample the first rejection
  from ``norm(max(p - q, 0))``) for sampled slots — the output distribution
  is exactly the accurate point's.
* **rollback** (:mod:`repro.spec.rollback`): committing ``a`` accepted drafts
  plus one corrected/bonus token truncates each slot's cache to
  ``start + a + 1`` rows by rewriting the per-slot write index — rows past the
  accepted prefix become invisible and are overwritten next round.
* **telemetry** (:class:`SpecTelemetry`): acceptance rate, emitted tokens per
  verify step, and estimated cycle cost under the ``K*(depth+1)`` iterative-PE
  model, where a multi-token verify streams the weight bank ONCE for all
  ``k+1`` positions (weight-stationary PE array) — the quantity in which
  speculation beats accurate-only serving.

``BatchedServer(speculate=SpecConfig(...))`` is the serving integration; with
a :class:`repro.runtime.ModeController` attached the controller picks the
draft point per round and its margin/pressure signals are fed from the verify
logits.
"""
from .config import SpecConfig
from .decoding import make_draft_loop, make_verify_step
from .engine import SpeculativeDecoder
from .rollback import cache_positions, rollback, with_cache_positions
from .telemetry import SpecTelemetry

__all__ = [
    "SpecConfig",
    "SpecTelemetry",
    "SpeculativeDecoder",
    "cache_positions",
    "make_draft_loop",
    "make_verify_step",
    "rollback",
    "with_cache_positions",
]
