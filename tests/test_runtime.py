"""Runtime-adaptive precision subsystem: banks, controller, telemetry, serving.

The contracts under test mirror the paper's §II-C/§III claims:
* mode switching costs zero weight-side work (multi-point banks share pinned
  leaves, switching = handing a different resident tree to the decode step);
* a controller pinned to one execution point is bit-identical to the static
  prepared backend (the adaptive machinery adds no arithmetic);
* the controller demotes under pressure / budget and promotes on low margins,
  with hysteresis, and the telemetry cycle accounting matches the iterative-PE
  model exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (
    EngineContext,
    FXP8,
    FXP16,
    LayerPrecision,
    PrecisionPolicy,
    approx_depth,
    full_depth,
)
from repro.models import get_model
from repro.runtime import (
    ControllerConfig,
    ExecutionPoint,
    ModeController,
    StepSignals,
    TelemetryRecorder,
    build_bank,
    calibration_scan,
    default_points,
    estimate_point_cycles,
)
from repro.serve.engine import BatchedServer, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("olmo-1b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# multi-point banks
# ---------------------------------------------------------------------------


def test_bank_orders_points_and_rel_cycles(small_model):
    _, model, params = small_model
    bank = build_bank(params, "carmen", specs=model.specs())
    assert bank.names == ("approx", "accurate", "hifi")
    assert bank.reference == "accurate"
    # exact iterative-PE ratios: (depth+1)/(full+1), uniform over engine dots
    assert bank.rel_cycles("approx") == pytest.approx(
        (approx_depth(FXP8) + 1) / (full_depth(FXP8) + 1)
    )
    assert bank.rel_cycles("accurate") == 1.0
    assert bank.rel_cycles("hifi") == pytest.approx(
        (full_depth(FXP16) + 1) / (full_depth(FXP8) + 1)
    )


def test_bank_shares_leaves_where_points_agree(small_model):
    """Pinned layers are materialized once and aliased into every tree."""
    _, model, params = small_model
    base = PrecisionPolicy(
        LayerPrecision(FXP8, full_depth(FXP8)),
        {"layer.attn": LayerPrecision(FXP8, approx_depth(FXP8))},
    )
    bank = build_bank(
        params, "carmen", default_points(FXP8, base_policy=base), specs=model.specs()
    )
    mixed, acc = bank.tree("mixed"), bank.tree("accurate")
    # attn demoted in the mixed point: distinct prepared leaves
    assert mixed["seg0_dense"]["attn"]["wq"] is not acc["seg0_dense"]["attn"]["wq"]
    # mlp + tied lm_head agree between the points: the SAME object
    assert mixed["seg0_dense"]["mlp"]["up"] is acc["seg0_dense"]["mlp"]["up"]
    assert mixed["lm_head"] is acc["lm_head"]
    assert bank.shared_leaves > 0


def test_bank_rejects_exact_mode(small_model):
    _, model, params = small_model
    with pytest.raises(ValueError, match="precision knob"):
        build_bank(params, "exact", specs=model.specs())


def test_bank_carries_activation_format(small_model):
    """Prepared leaves are self-describing: the dot quantizes activations at
    the bank point's format, not the context policy's (bank-aware dot)."""
    _, model, params = small_model
    bank = build_bank(params, "carmen", specs=model.specs())
    assert bank.tree("hifi")["seg0_dense"]["mlp"]["up"].get("x_fmt") == (
        FXP16.bits, FXP16.frac
    )
    # a ctx pinned to FXP8 must not change a hifi leaf's arithmetic
    head = bank.tree("hifi")["lm_head"]  # unstacked 2D leaf
    ctx8 = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                         compute_dtype=jnp.float32)
    ctx16 = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP16),
                          compute_dtype=jnp.float32)
    x = np.linspace(-1, 1, head.shape[0], dtype=np.float32)[None, :]
    out8 = np.asarray(ctx8.dot(x, head, name="lm_head"))
    out16 = np.asarray(ctx16.dot(x, head, name="lm_head"))
    np.testing.assert_array_equal(out8, out16)


def test_estimate_point_cycles_counts_tied_head(small_model):
    cfg, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    total = estimate_point_cycles(params, pol, specs=model.specs())
    head = np.prod(params["embed"].shape) * (full_depth(FXP8) + 1)
    assert total > head  # the tied lm_head contributes
    body = sum(
        np.prod(l.shape) * (full_depth(FXP8) + 1)
        for l in jax.tree.leaves(params)
        if getattr(l, "ndim", 0) >= 2
    )
    assert total < body + head  # but norms/embeds are not engine dots


def test_estimate_point_cycles_on_prepared_tree(small_model):
    """Prepared trees cost the same as the raw tree they were built from
    (PreparedWeight nodes are walked as leaves, incl. the materialized head)."""
    _, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs())
    raw = estimate_point_cycles(params, pol, specs=model.specs())
    prepared = estimate_point_cycles(bank.tree("accurate"), pol, specs=model.specs())
    assert prepared == raw > 0


# ---------------------------------------------------------------------------
# mode controller
# ---------------------------------------------------------------------------


def _toy_bank():
    """A bank stub: three points, relative cycles 0.5 / 1.0 / 2.0."""
    from repro.runtime.bank import MultiPointBank

    points = tuple(
        ExecutionPoint(n, PrecisionPolicy.accurate(FXP8))
        for n in ("cheap", "accurate", "hifi")
    )
    return MultiPointBank(
        mode="carmen",
        points=points,
        trees={n: {"w": n} for n in ("cheap", "accurate", "hifi")},
        cycles_per_token={"cheap": 50.0, "accurate": 100.0, "hifi": 200.0},
        reference="accurate",
    )


def test_controller_demotes_under_pressure_with_hysteresis():
    ctrl = ModeController(_toy_bank(), ControllerConfig(hysteresis=2))
    pressure = StepSignals(active=2, queue_depth=5, free_slots=0, min_margin=3.0)
    assert ctrl.point == "accurate"
    ctrl.observe(pressure)
    assert ctrl.point == "accurate"  # one vote is not enough
    ctrl.observe(pressure)
    assert ctrl.point == "cheap" and ctrl.switches == 1
    # already at the floor: more pressure cannot demote further
    ctrl.observe(pressure)
    ctrl.observe(pressure)
    assert ctrl.point == "cheap" and ctrl.switches == 1


def test_controller_promotes_on_low_margin_when_unloaded():
    ctrl = ModeController(
        _toy_bank(), ControllerConfig(hysteresis=2, start="cheap", margin_promote=1.5)
    )
    idle_uncertain = StepSignals(active=1, queue_depth=0, free_slots=2, min_margin=0.2)
    ctrl.observe(idle_uncertain)
    ctrl.observe(idle_uncertain)
    assert ctrl.point == "accurate" and ctrl.switches == 1


def test_controller_budget_blocks_promotion():
    cfg = ControllerConfig(hysteresis=1, cycle_budget=0.75, ema=0.5, start="accurate")
    ctrl = ModeController(_toy_bank(), cfg)
    uncertain = StepSignals(active=1, queue_depth=0, free_slots=2, min_margin=0.1)
    # rel EMA starts at 1.0 > budget: over budget demotes despite low margin
    ctrl.observe(uncertain)
    assert ctrl.point == "cheap"
    # EMA decays toward 0.5; once under budget, low margin promotes again
    trajectory = [ctrl.observe(uncertain) for _ in range(4)]
    assert "accurate" in trajectory
    assert ctrl.switches >= 2
    # but the budget keeps pulling back down: hifi is never reached
    assert "hifi" not in trajectory


def test_controller_hold_resets_streak():
    ctrl = ModeController(_toy_bank(), ControllerConfig(hysteresis=2))
    pressure = StepSignals(active=2, queue_depth=5, free_slots=0, min_margin=3.0)
    neutral = StepSignals(active=2, queue_depth=0, free_slots=1, min_margin=3.0)
    ctrl.observe(pressure)
    ctrl.observe(neutral)  # hold: streak resets
    ctrl.observe(pressure)
    assert ctrl.point == "accurate" and ctrl.switches == 0


def test_controller_pin_never_moves():
    ctrl = ModeController(_toy_bank(), ControllerConfig(pin="cheap", hysteresis=1))
    for sig in (
        StepSignals(active=1, queue_depth=9, free_slots=0, min_margin=0.0),
        StepSignals(active=1, queue_depth=0, free_slots=3, min_margin=0.0),
    ):
        for _ in range(5):
            ctrl.observe(sig)
    assert ctrl.point == "cheap" and ctrl.switches == 0
    assert ctrl.tree() == {"w": "cheap"}


def test_controller_rejects_unknown_points():
    with pytest.raises(ValueError, match="unknown execution point"):
        ModeController(_toy_bank(), ControllerConfig(pin="fp4"))


from _hypothesis_compat import given, settings, st  # noqa: E402

_margins = st.one_of(
    st.none(),
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False),
    st.sampled_from([float("nan"), float("inf"), float("-inf")]),
)


@given(
    margins=st.lists(_margins, min_size=1, max_size=40),
    queue_depth=st.integers(min_value=0, max_value=8),
    free_slots=st.integers(min_value=0, max_value=4),
    steps=st.integers(min_value=1, max_value=16),
)
@settings(max_examples=60, deadline=None)
def test_controller_robust_to_hostile_margins(margins, queue_depth,
                                              free_slots, steps):
    """Property (fault tolerance): arbitrary margin streams — including
    NaN/Inf from a faulted lane — never crash the controller, never drive
    a promotion off a non-finite margin, and keep the cycle EMA finite."""
    import math

    ctl = ModeController(
        _toy_bank(),
        ControllerConfig(margin_promote=1.5, margin_demote=6.0, hysteresis=1),
    )
    for m in margins:
        before = ctl.bank.index(ctl.point)
        ctl.observe(StepSignals(active=1, queue_depth=queue_depth,
                                free_slots=free_slots, min_margin=m,
                                steps=steps))
        after = ctl.bank.index(ctl.point)
        assert math.isfinite(ctl.rel_cycles_ema)
        if m is not None and not math.isfinite(m):
            # a non-finite margin must never read as "uncertain": the only
            # legal move it can contribute to is a demotion (pressure/budget)
            assert after <= before


@given(margins=st.lists(_margins, min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_controller_nonfinite_margins_never_promote(margins):
    """With every margin non-finite or None, the ladder index is
    monotonically non-increasing — garbage can only demote."""
    hostile = [m for m in margins] or [float("nan")]
    ctl = ModeController(_toy_bank(), ControllerConfig(hysteresis=1))
    idx = ctl.bank.index(ctl.point)
    for m in hostile:
        bad = float("nan") if m is None else (
            m if m != m or m in (float("inf"), float("-inf")) else float("inf"))
        ctl.observe(StepSignals(active=1, queue_depth=0, free_slots=2,
                                min_margin=bad))
        new = ctl.bank.index(ctl.point)
        assert new <= idx
        idx = new


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_telemetry_cycle_accounting_and_switches():
    rec = TelemetryRecorder({"cheap": 50.0, "accurate": 100.0}, "accurate")
    rec.record_prefill("accurate", tokens=4)
    rec.record_step("accurate", active=2, min_margin=1.0)
    rec.record_step("cheap", active=2, min_margin=2.0)
    rec.record_step("cheap", active=1, min_margin=0.5)
    s = rec.summary()
    assert s["steps"] == 3 and s["tokens"] == 9 and s["switches"] == 1
    assert s["est_mac_cycles"] == 4 * 100 + 2 * 100 + 2 * 50 + 1 * 50
    assert s["all_accurate_mac_cycles"] == 9 * 100
    assert s["est_cycle_savings_frac"] == pytest.approx(1 - 750 / 900, abs=1e-4)
    assert s["mode_occupancy"]["cheap"] == pytest.approx(3 / 9, abs=1e-4)


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def _requests(cfg, n, max_new=6, seed=3, **kw):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, cfg.vocab_size, 4).astype(np.int32), max_new, **kw)
        for i in range(n)
    ]


def test_pinned_controller_bit_identical_to_static(small_model):
    """Satellite contract: the adaptive machinery at a fixed execution point
    reproduces the static prepared backend token-for-token."""
    cfg, model, params = small_model
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    static = BatchedServer(model, ctx, params, slots=2, max_len=16)
    want = static.run(_requests(cfg, 4))

    bank = build_bank(params, "carmen", specs=model.specs())
    ctrl = ModeController(bank, ControllerConfig(pin="accurate"))
    adaptive = BatchedServer(model, ctx, params, slots=2, max_len=16, controller=ctrl)
    got = adaptive.run(_requests(cfg, 4))
    assert got == want
    assert adaptive.telemetry.summary()["mode_occupancy"]["accurate"] == 1.0
    assert adaptive.telemetry.summary()["switches"] == 0


def test_adaptive_server_switches_and_saves_cycles(small_model):
    """Under queue pressure + a cycle budget the controller demotes and the
    telemetry shows real savings."""
    cfg, model, params = small_model
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    bank = build_bank(params, "carmen", default_points(FXP8, hifi_fmt=None),
                      specs=model.specs())
    # margins disarmed: the budget + pressure signals drive the trajectory
    ctrl = ModeController(bank, ControllerConfig(
        cycle_budget=0.7, margin_promote=-1.0, margin_demote=float("inf")
    ))
    server = BatchedServer(model, ctx, params, slots=2, max_len=24, controller=ctrl)
    server.run(_requests(cfg, 8, max_new=10))
    s = server.telemetry.summary()
    assert s["switches"] >= 1
    assert s["mode_occupancy"]["approx"] > 0.5
    assert s["est_cycle_savings_frac"] >= 0.25
    # margins were observed for every decode step
    assert len(server.telemetry.min_margins) == s["steps"]


def test_temperature_and_seed_plumbing(small_model):
    cfg, model, params = small_model
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    serve = lambda reqs: BatchedServer(model, ctx, params, slots=2, max_len=16).run(reqs)

    # temp=0 requests are greedy regardless of the sampling seed
    a = serve(_requests(cfg, 2, seed=3))
    b = serve(_requests(cfg, 2, seed=3, temperature=0.0))
    assert a == b

    # same seed -> same stream (even across different slots/schedules)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    r = serve([Request(0, prompt, 8, temperature=3.0, seed=11),
               Request(1, prompt, 8, temperature=3.0, seed=11)])
    assert r[0] == r[1]

    # different seeds -> different streams (vocab 256, 7 sampled tokens)
    r2 = serve([Request(0, prompt, 8, temperature=3.0, seed=11),
                Request(1, prompt, 8, temperature=3.0, seed=12)])
    assert r2[0] != r2[1]

    # sampled neq greedy at high temperature
    r3 = serve([Request(0, prompt, 8, temperature=0.0),
                Request(1, prompt, 8, temperature=5.0, seed=1)])
    assert r3[0] != r3[1]


def test_margins_recorded_per_token(small_model):
    cfg, model, params = small_model
    ctx = EngineContext(mode="carmen", policy=PrecisionPolicy.accurate(FXP8),
                        compute_dtype=jnp.float32)
    reqs = _requests(cfg, 2, max_new=5)
    BatchedServer(model, ctx, params, slots=2, max_len=16).run(reqs)
    for req in reqs:
        assert len(req.margins) == len(req.generated) == 5
        assert all(m >= 0.0 for m in req.margins)


# ---------------------------------------------------------------------------
# calibration scan
# ---------------------------------------------------------------------------


def test_calibration_scan_covers_engine_dots(small_model):
    cfg, model, params = small_model
    tokens = np.arange(16, dtype=np.int32).reshape(2, 8)
    sens = calibration_scan(model, params, tokens, fmt=FXP8)
    assert set(sens) == {
        "layer.attn.q", "layer.attn.k", "layer.attn.v", "layer.attn.o",
        "layer.mlp.up", "layer.mlp.gate", "layer.mlp.down", "lm_head",
    }
    assert all(v > 0 for v in sens.values())
