"""Trace-driven cycle-accurate simulator of the paper's CARMEN PE array.

The repo's cycle numbers — ``estimate_point_cycles``' analytic K*(depth+1)
model, every ``est_cycle_savings_frac`` the serving loop reports — are made
auditable here, by replaying real serving traces through an explicit model
of the paper's hardware and comparing predictions against measurements:

* :mod:`repro.sim.array` — the array model: N iterative CORDIC PEs (default
  256, 64-PE variant for Table 5), per-MAC latency as a function of depth
  and format, time-multiplexed AF-block contention, weight-stream bandwidth,
  and mode-switch overhead. Pure cycle arithmetic, no jax.
* :mod:`repro.sim.replay` — consumes a ``carmen-serve-trace`` JSONL
  (streaming, via :func:`repro.obs.iter_trace`) and schedules every burst
  span, speculative draft/verify round, prefill bucket, and controller
  switch onto the array: per-layer / per-request / per-phase cycle and
  utilization attribution. CLI: ``python -m repro.sim.replay trace.jsonl``.
* :mod:`repro.sim.analyze` — the report layer: JSON + human-readable table
  of where cycles go, PE occupancy, AF stalls, and predicted-vs-measured
  comparisons (wall-clock ordering, savings fraction).
* :mod:`repro.sim.calibrate` — fits the model's per-stage constants against
  the Tables 2/3/5 benchmark measurements and exports a calibration JSON
  that ``estimate_point_cycles`` / ``build_bank`` load, so the
  ModeController's budget and the simulator optimize the same cost.

``benchmarks/bench_sim.py`` turns predicted-vs-measured drift into a CI
gate.
"""
from .array import ArrayConfig, CostBreakdown, dot_pass_cost
from .calibrate import (fit_calibration, load_calibration, run_calibration,
                        save_calibration)
from .replay import ReplayResult, replay_trace

__all__ = [
    "ArrayConfig",
    "CostBreakdown",
    "ReplayResult",
    "dot_pass_cost",
    "fit_calibration",
    "load_calibration",
    "replay_trace",
    "run_calibration",
    "save_calibration",
]
