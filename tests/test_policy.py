"""Accuracy-sensitivity metric and depth assignment (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FXP8,
    FXP16,
    LayerPrecision,
    PrecisionPolicy,
    approx_depth,
    assign_depths,
    full_depth,
    pin_critical,
    sensitivity_scan,
)


def _toy_apply(params, batch, noise):
    """Two-layer MLP with noise-injection taps after each layer."""
    h = batch @ params["w1"]
    h = h + noise.get("l1", 0.0) * jnp.ones_like(h)
    h = jnp.tanh(h)
    out = h @ params["w2"]
    out = out + noise.get("l2", 0.0) * jnp.ones_like(out)
    return out


def test_sensitivity_orders_layers(rng):
    # w2 large ==> perturbations at l1 are amplified; l2 taps the output directly.
    params = {
        "w1": rng.standard_normal((8, 16)).astype(np.float32) * 0.1,
        "w2": rng.standard_normal((16, 4)).astype(np.float32) * 10.0,
    }
    batch = rng.standard_normal((32, 8)).astype(np.float32)
    sens = sensitivity_scan(_toy_apply, params, batch, ["l1", "l2"], fmt=FXP8)
    assert sens["l1"] > sens["l2"] > 0


def test_assign_depths_meets_budget_and_pins_critical():
    sens = {"mlp.0": 0.01, "mlp.1": 0.02, "attn.router": 0.001, "head": 0.5}
    pol = assign_depths(sens, fmt=FXP8, cycle_reduction_target=0.20)
    # router never demoted despite lowest sensitivity
    assert pol.for_layer("attn.router").depth == full_depth(FXP8)
    # least-sensitive non-critical layers demoted first
    assert pol.for_layer("mlp.0").depth == approx_depth(FXP8)
    # most-sensitive stays accurate
    assert pol.for_layer("head").depth == full_depth(FXP8)


def test_policy_uniform_and_modes():
    acc = PrecisionPolicy.accurate(FXP8).default
    app = PrecisionPolicy.approximate(FXP8).default
    assert acc.mode == "accurate" and app.mode == "approximate"
    assert app.depth < acc.depth


def test_critical_never_demoted_even_at_full_budget():
    """router/norm/embed layers stay accurate no matter the budget."""
    sens = {
        "moe.router": 0.0001,
        "final_norm": 0.0002,
        "embed": 0.0003,
        "layer.attn.q": 0.01,
        "layer.mlp.up": 0.02,
    }
    pol = assign_depths(sens, fmt=FXP8, cycle_reduction_target=1.0)
    for critical in ("moe.router", "final_norm", "embed"):
        assert pol.for_layer(critical).depth == full_depth(FXP8), critical
    # non-critical layers all demoted under the unbounded budget
    assert pol.for_layer("layer.attn.q").depth == approx_depth(FXP8)
    assert pol.for_layer("layer.mlp.up").depth == approx_depth(FXP8)


def test_assign_depths_budget_monotone():
    """A larger cycle budget demotes a superset of layers."""
    rng = np.random.default_rng(0)
    sens = {f"layer{i}.mlp.up": float(s) for i, s in enumerate(rng.uniform(0.01, 1.0, 12))}
    prev: set = set()
    for target in (0.0, 0.1, 0.2, 0.3, 1.0):
        demoted = set(assign_depths(sens, fmt=FXP8, cycle_reduction_target=target).overrides)
        assert prev <= demoted, (target, prev, demoted)
        prev = demoted
    assert prev == set(sens)  # unbounded budget demotes everything non-critical


def test_for_layer_exact_override_beats_substring():
    approx = LayerPrecision(FXP8, approx_depth(FXP8))
    exact_lp = LayerPrecision(FXP8, 5)
    pol = PrecisionPolicy(
        LayerPrecision(FXP8, full_depth(FXP8)),
        {"mlp": approx, "layer.mlp.up": exact_lp},
    )
    # exact name match wins over the earlier-inserted substring key
    assert pol.for_layer("layer.mlp.up") is exact_lp
    # substring match applies to other members of the group
    assert pol.for_layer("layer.mlp.down") is approx
    # no match falls through to the default
    assert pol.for_layer("layer.attn.q").depth == full_depth(FXP8)


def test_for_layer_substring_insertion_order():
    first = LayerPrecision(FXP8, 3)
    second = LayerPrecision(FXP8, 5)
    pol = PrecisionPolicy(
        LayerPrecision(FXP8, full_depth(FXP8)), {"attn": first, "attn.q": second}
    )
    assert pol.for_layer("layer.attn.q") is first  # first matching key wins


def test_policy_json_roundtrip(tmp_path):
    pol = assign_depths(
        {"layer.mlp.up": 0.1, "layer.attn.q": 0.5, "moe.router": 0.01},
        fmt=FXP16,
        cycle_reduction_target=0.2,
    )
    path = tmp_path / "policy.json"
    pol.save(str(path))
    loaded = PrecisionPolicy.load(str(path))
    assert loaded == pol
    for name in ("layer.mlp.up", "layer.attn.q", "moe.router", "other"):
        assert loaded.for_layer(name) == pol.for_layer(name)


def test_pin_critical_floors_overrides_and_defaults():
    approx = LayerPrecision(FXP8, approx_depth(FXP8))
    pol = PrecisionPolicy(approx, {"moe.router": approx, "layer.mlp.up": approx})
    pinned = pin_critical(pol)
    # critical override promoted to full depth; non-critical untouched
    assert pinned.for_layer("moe.router").depth == full_depth(FXP8)
    assert pinned.for_layer("layer.mlp.up").depth == approx_depth(FXP8)
    # critical keyword floor catches layers the policy never listed
    assert pinned.for_layer("final_norm").depth == full_depth(FXP8)
    assert pinned.for_layer("embed").depth == full_depth(FXP8)
    # default (non-critical fallthrough) stays approximate
    assert pinned.for_layer("layer.attn.q").depth == approx_depth(FXP8)


def test_pin_critical_floor_beats_shadowing_override():
    """A non-critical override key substring-matching a critical layer name
    ("final" vs "final_norm") must not shadow the keyword floor."""
    approx = LayerPrecision(FXP8, approx_depth(FXP8))
    pinned = pin_critical(PrecisionPolicy(LayerPrecision(FXP8, full_depth(FXP8)),
                                          {"final": approx}))
    assert pinned.for_layer("final_norm").depth == full_depth(FXP8)
    # the override still applies to genuinely non-critical matches
    assert pinned.for_layer("final_proj").depth == approx_depth(FXP8)
