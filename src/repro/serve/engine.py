"""Serving engine: prefill/decode step builders, sampling, batched scheduler.

The decode step is the unit the decode-shape cells lower (one new token against
a seq_len-deep KV cache). The scheduler below implements simple continuous
batching over a fixed slot count — enough to drive the end-to-end serving
example honestly (admit/evict per step, per-slot positions), while the
distributed story (cache shardings) lives in sharding/partition.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import EngineContext
from repro.models import ModelApi


def make_prefill_step(model: ModelApi, ctx: EngineContext):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, ctx)
        return logits

    return prefill_step


def make_decode_step(model: ModelApi, ctx: EngineContext):
    def decode_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache, ctx)

    return decode_step


def sample(logits, key, *, temperature: float = 0.0):
    """logits (B, 1, V) -> tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int
    generated: Optional[List[int]] = None


@dataclasses.dataclass
class BatchedServer:
    """Continuous batching over ``slots`` concurrent sequences (greedy)."""

    model: ModelApi
    ctx: EngineContext
    params: object
    slots: int = 4
    max_len: int = 256

    def __post_init__(self):
        self.decode = jax.jit(make_decode_step(self.model, self.ctx))
        self.cache = self.model.make_cache(self.slots, self.max_len, dtype=jnp.float32)
        self.active: Dict[int, Request] = {}

    def _reset_slot(self, slot: int):
        """Zero this slot's per-row cache index: stale entries become invalid
        (masked by index) and get overwritten as the new request fills in."""

        def fix(v):
            if hasattr(v, "dtype") and v.dtype == jnp.int32 and v.ndim >= 2:
                return v.at[..., slot].set(0)
            return v

        self.cache = jax.tree.map(fix, self.cache)

    def _prefill_slot(self, slot: int, req: Request):
        """Feed prompt tokens through the decode path into this slot's cache.

        (Token-by-token teacher forcing — a dedicated batched prefill kernel is
        a serving optimization, same math.)
        """
        self._reset_slot(slot)
        tok = None
        for t in req.prompt:
            toks = np.zeros((self.slots, 1), np.int32)
            toks[slot, 0] = t
            logits, self.cache = self.decode(self.params, jnp.asarray(toks), self.cache)
            tok = int(np.asarray(logits[slot, 0]).argmax())
        req.generated = [tok]

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve requests to completion; returns rid -> generated tokens."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        slot_of: Dict[int, int] = {}
        free = list(range(self.slots))
        while queue or self.active:
            while queue and free:
                req = queue.pop(0)
                slot = free.pop(0)
                self._prefill_slot(slot, req)
                self.active[req.rid] = req
                slot_of[req.rid] = slot
            toks = np.zeros((self.slots, 1), np.int32)
            for rid, req in self.active.items():
                toks[slot_of[rid], 0] = req.generated[-1]
            logits, self.cache = self.decode(self.params, jnp.asarray(toks), self.cache)
            done = []
            for rid, req in self.active.items():
                nxt = int(np.asarray(logits[slot_of[rid], 0]).argmax())
                req.generated.append(nxt)
                if len(req.generated) >= req.max_new:
                    done.append(rid)
            for rid in done:
                req = self.active.pop(rid)
                results[rid] = req.generated
                free.append(slot_of.pop(rid))
        return results
