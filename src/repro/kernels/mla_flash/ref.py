"""Pure-jnp oracle for the MLA flash kernel: naive shared-latent attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mla_attention_ref(q_cat, k_cat, v, *, causal: bool = True):
    """q_cat: (B, Sq, H, Dk); k_cat: (B, Sk, Dk); v: (B, Sk, Dv)."""
    dk = q_cat.shape[-1]
    s = jnp.einsum(
        "bqhr,btr->bhqt", q_cat.astype(jnp.float32), k_cat.astype(jnp.float32)
    ) / math.sqrt(dk)
    if causal:
        sq, sk = q_cat.shape[1], k_cat.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,btr->bqhr", p, v.astype(jnp.float32)).astype(q_cat.dtype)
