"""Decoder-only LM covering the dense / moe / vlm / hybrid / ssm families.

Design constraints that shaped this file:

* **HLO is O(1) in depth**: every repeated layer stack is a ``lax.scan`` over
  stacked parameters (stacked leading 'layers' axis). MoE models with a dense
  prefix (deepseek) or interleaving (llama4) scan each homogeneous segment.
* **one code path for train / prefill / decode**: segments take an optional
  cache pytree (stacked along layers, consumed as scan xs, emitted as ys).
* **CARMEN everywhere**: all projections go through ``EngineContext``; MLP
  activations go through the multi-AF block mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import EngineContext

from repro.sharding.partition import constrain

from . import blocks, mamba2, mla
from .params import ParamSpec, stack_layers


# ---------------------------------------------------------------------------
# Layer specs per family
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig):
    return mla.mla_specs(cfg) if cfg.mla else blocks.attention_specs(cfg)


def _dense_layer_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    return {
        "attn_norm": blocks.norm_spec(cfg),
        "attn": _attn_specs(cfg),
        "mlp_norm": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_specs(cfg, d_ff),
    }


def _moe_layer_specs(cfg: ModelConfig):
    return {
        "attn_norm": blocks.norm_spec(cfg),
        "attn": _attn_specs(cfg),
        "mlp_norm": blocks.norm_spec(cfg),
        "moe": blocks.moe_specs(cfg),
    }


def _mamba_layer_specs(cfg: ModelConfig):
    return {"norm": blocks.norm_spec(cfg), "mixer": mamba2.mamba2_specs(cfg)}


def _segments(cfg: ModelConfig):
    """(kind, layer_count) segments; layer params stack within a segment."""
    if cfg.family in ("dense", "vlm"):
        return [("dense", cfg.num_layers)]
    if cfg.family == "moe":
        m = cfg.moe
        segs = []
        if m.first_dense_layers:
            segs.append(("dense_prefix", m.first_dense_layers))
        rest = cfg.num_layers - m.first_dense_layers
        if m.moe_every == 1:
            segs.append(("moe", rest))
        else:
            assert rest % m.moe_every == 0
            segs.append(("pair", rest // m.moe_every))
        return segs
    if cfg.family == "ssm":
        return [("mamba", cfg.num_layers)]
    if cfg.family == "hybrid":
        per = cfg.hybrid.attn_every
        assert cfg.num_layers % per == 0, (cfg.num_layers, per)
        return [("hybrid", cfg.num_layers // per)]  # groups of (per mamba + shared attn)
    raise ValueError(cfg.family)


def decoder_specs(cfg: ModelConfig):
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "final_norm": blocks.norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    for i, (kind, n) in enumerate(_segments(cfg)):
        key = f"seg{i}_{kind}"
        if kind == "dense":
            specs[key] = stack_layers(lambda: _dense_layer_specs(cfg), n)
        elif kind == "dense_prefix":
            specs[key] = stack_layers(lambda: _dense_layer_specs(cfg, cfg.moe.d_ff_dense), n)
        elif kind == "moe":
            specs[key] = stack_layers(lambda: _moe_layer_specs(cfg), n)
        elif kind == "pair":
            specs[key] = stack_layers(
                lambda: {
                    "dense": _dense_layer_specs(cfg, cfg.moe.d_ff_dense),
                    "moe": _moe_layer_specs(cfg),
                },
                n,
            )
        elif kind == "mamba":
            specs[key] = stack_layers(lambda: _mamba_layer_specs(cfg), n)
        elif kind == "hybrid":
            per = cfg.hybrid.attn_every
            specs[key] = stack_layers(
                lambda: stack_layers(lambda: _mamba_layer_specs(cfg), per), n
            )
            specs["shared_attn"] = {
                "attn_norm": blocks.norm_spec(cfg),
                "attn": blocks.attention_specs(cfg),
                "mlp_norm": blocks.norm_spec(cfg),
                "mlp": blocks.mlp_specs(cfg),
            }
    return specs


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _attn_block(p, h, cfg, ctx, positions, cache, name):
    h = constrain(h, "batch", None, None)
    x = blocks.apply_norm(p["attn_norm"], h, cfg)
    if cfg.mla:
        out, new_cache = mla.mla_attention(
            p["attn"], x, cfg, ctx, positions=positions, name=name, cache=cache
        )
    else:
        out, new_cache = blocks.attention(
            p["attn"], x, cfg, ctx, positions=positions, name=name, cache=cache
        )
    return h + out, new_cache


def _dense_layer(p, h, cfg, ctx, positions, cache, name="layer"):
    h, new_cache = _attn_block(p, h, cfg, ctx, positions, cache, f"{name}.attn")
    x = blocks.apply_norm(p["mlp_norm"], h, cfg)
    h = h + blocks.mlp(p["mlp"], x, cfg, ctx, name=f"{name}.mlp")
    return h, new_cache, {}


def _moe_layer(p, h, cfg, ctx, positions, cache, name="layer"):
    h, new_cache = _attn_block(p, h, cfg, ctx, positions, cache, f"{name}.attn")
    x = blocks.apply_norm(p["mlp_norm"], h, cfg)
    # cached decode gets the dropless short-block capacity (S>1 verify parity)
    out, aux = blocks.moe_ffn(p["moe"], x, cfg, ctx, name=f"{name}.moe",
                              dropless=cache is not None)
    return h + out, new_cache, aux


def _mamba_layer(p, h, cfg, ctx, state, name="layer"):
    h = constrain(h, "batch", None, None)
    x = blocks.apply_norm(p["norm"], h, cfg)
    out, new_state = mamba2.mamba2_forward(p["mixer"], x, cfg, ctx, name=f"{name}.mixer", state=state)
    return h + out, new_state


# ---------------------------------------------------------------------------
# Segment runners (scan over stacked layer params [+ caches])
# ---------------------------------------------------------------------------


def _scan_segment(layer_fn, stacked_params, h, caches, *, remat: bool):
    body = layer_fn
    if remat:
        body = jax.checkpoint(layer_fn, prevent_cse=False)

    def scan_fn(h, xs):
        p, cache = xs
        h, new_cache, aux = body(p, h, cache)
        return h, (new_cache, aux)

    h, (new_caches, auxs) = jax.lax.scan(scan_fn, h, (stacked_params, caches))
    return h, new_caches, auxs


def _run_segments(params, h, cfg, ctx, positions, caches, *, remat: bool):
    """caches: dict seg_key -> stacked cache (or None). Returns h, caches, aux."""
    new_caches = {}
    lb_loss = jnp.zeros((), jnp.float32)
    for i, (kind, n) in enumerate(_segments(cfg)):
        key = f"seg{i}_{kind}"
        seg_cache = caches.get(key) if caches else None
        if kind in ("dense", "dense_prefix"):
            fn = lambda p, h, c: _dense_layer(p, h, cfg, ctx, positions, c)
            h, nc, _ = _scan_segment(fn, params[key], h, seg_cache, remat=remat)
            new_caches[key] = nc
        elif kind == "moe":
            fn = lambda p, h, c: _moe_layer(p, h, cfg, ctx, positions, c)
            h, nc, aux = _scan_segment(fn, params[key], h, seg_cache, remat=remat)
            lb_loss = lb_loss + jnp.sum(aux.get("lb_loss", jnp.zeros((n,))))
            new_caches[key] = nc
        elif kind == "pair":

            def pair_fn(p, h, c):
                c_d, c_m = (c or {}).get("dense"), (c or {}).get("moe")
                h, nc_d, _ = _dense_layer(p["dense"], h, cfg, ctx, positions, c_d)
                h, nc_m, aux = _moe_layer(p["moe"], h, cfg, ctx, positions, c_m)
                return h, {"dense": nc_d, "moe": nc_m}, aux

            h, nc, aux = _scan_segment(pair_fn, params[key], h, seg_cache, remat=remat)
            lb_loss = lb_loss + jnp.sum(aux.get("lb_loss", jnp.zeros((n,))))
            new_caches[key] = nc
        elif kind == "mamba":

            def mamba_fn(p, h, c):
                h, ns = _mamba_layer(p, h, cfg, ctx, c)
                return h, ns, {}

            h, nc, _ = _scan_segment(mamba_fn, params[key], h, seg_cache, remat=remat)
            new_caches[key] = nc
        elif kind == "hybrid":
            shared = params["shared_attn"]

            def group_fn(p, h, c):
                c_ssm = (c or {}).get("ssm"), (c or {}).get("attn")

                def inner(h, xs):
                    pl, cl = xs
                    h, ns = _mamba_layer(pl, h, cfg, ctx, cl)
                    return h, ns

                h, new_ssm = jax.lax.scan(inner, h, (p, c_ssm[0]))
                h, new_attn = _attn_block(
                    shared, h, cfg, ctx, positions, c_ssm[1], "shared.attn"
                )
                x = blocks.apply_norm(shared["mlp_norm"], h, cfg)
                h = h + blocks.mlp(shared["mlp"], x, cfg, ctx, name="shared.mlp")
                return h, {"ssm": new_ssm, "attn": new_attn}, {}

            h, nc, _ = _scan_segment(group_fn, params[key], h, seg_cache, remat=remat)
            new_caches[key] = nc
    return h, new_caches, {"lb_loss": lb_loss}


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _seg_cache(cfg, kind, n, batch, max_len, dtype, abstract: bool):
    def attn_c():
        if cfg.mla:
            f = mla.mla_cache_specs if abstract else mla.init_mla_cache
        else:
            f = blocks.attn_cache_specs if abstract else blocks.init_attn_cache
        return f(cfg, batch, max_len, dtype)

    def mamba_c():
        f = mamba2.mamba_state_specs if abstract else mamba2.init_mamba_state
        return f(cfg, batch, dtype)

    def stack(tree, m):
        if abstract:
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((m,) + s.shape, s.dtype), tree
            )
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (m,) + a.shape).copy(), tree)

    if kind in ("dense", "dense_prefix", "moe"):
        return stack(attn_c(), n)
    if kind == "pair":
        return stack({"dense": attn_c(), "moe": attn_c()}, n)
    if kind == "mamba":
        return stack(mamba_c(), n)
    if kind == "hybrid":
        per = cfg.hybrid.attn_every
        return stack({"ssm": stack(mamba_c(), per), "attn": attn_c()}, n)
    raise ValueError(kind)


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16, abstract=False):
    return {
        f"seg{i}_{kind}": _seg_cache(cfg, kind, n, batch, max_len, dtype, abstract)
        for i, (kind, n) in enumerate(_segments(cfg))
    }


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------


def forward(params, batch, cfg: ModelConfig, ctx: EngineContext, *, remat: bool = False):
    """Train/prefill forward: batch['tokens'] (B, S) -> logits (B, S(+P), V).

    VLM/audio-lm families prepend batch['frontend_embeds'] (B, P, D) stub
    embeddings; logits cover the full concatenated sequence.
    """
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = constrain(h, "batch", None, None)
    if cfg.frontend == "vision":
        fe = batch["frontend_embeds"].astype(cfg.compute_dtype)
        h = jnp.concatenate([fe, h], axis=1)
    s = h.shape[1]
    positions = jnp.arange(s)
    h, _, aux = _run_segments(params, h, cfg, ctx, positions, None, remat=remat)
    h = constrain(h, "batch", None, None)
    h = blocks.apply_norm(params["final_norm"], h, cfg)
    logits = constrain(_lm_head(params, h, cfg, ctx), "batch", None, "model")
    return logits, aux


def _lm_head(params, h, cfg, ctx):
    # prepared trees carry an explicit lm_head even when embeddings are tied
    # (prepare_params materializes the transposed bank once), so decoding
    # never re-quantizes the output head
    if cfg.tie_embeddings and "lm_head" not in params:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return ctx.linear(h, w, name="lm_head").astype(jnp.float32)


def decode_step(params, tokens, cache, cfg: ModelConfig, ctx: EngineContext):
    """Cached decode: tokens (B, S) + cache -> (logits (B, S, V), cache).

    S = 1 is the classic one-token decode step; S > 1 writes a whole block
    (batched prefill: the serving engine feeds the full prompt in one call
    and scatters the resulting KV into its slot cache).
    """
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = constrain(h, "batch", None, None)
    index = _cache_index(cache)  # (B,) per-row decode positions
    positions = index[:, None] + jnp.arange(tokens.shape[1])[None, :]  # (B, S)
    h, new_caches, _ = _run_segments(params, h, cfg, ctx, positions, cache, remat=False)
    h = blocks.apply_norm(params["final_norm"], h, cfg)
    logits = _lm_head(params, h, cfg, ctx)
    return logits, new_caches


def _cache_index(cache):
    """Per-row decode positions: attn caches carry a stacked (L, B) index; all
    layers advance in lockstep so layer 0's row is authoritative. SSM-only
    models have no index (positions are unused by the mixer) -> zeros."""
    for v in jax.tree.leaves(cache):
        if hasattr(v, "dtype") and v.dtype == jnp.int32 and v.ndim >= 2:
            return v[0]  # (B,)
    # ssm-only: derive batch from any state leaf
    some = jax.tree.leaves(cache)[0]
    return jnp.zeros((some.shape[1],), jnp.int32)
