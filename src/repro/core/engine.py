"""The CARMEN vector engine: one entry point for every matmul in the framework.

Model code never calls ``jnp.dot`` directly — it calls ``EngineContext.linear``
so that the CARMEN execution point (precision format x CORDIC depth) is a
runtime configuration, exactly like the silicon engine's configuration
registers (paper §II-C "control engine ... configuration registers for runtime
parameter tuning").

Execution backends (``repro.core.backends`` — registry keyed by mode)
---------------------------------------------------------------------
exact       FP32/bf16 matmul — the paper's FP32 baseline.
carmen      Paper-faithful simulation: activations fake-quantized to the FxP
            format, weights rounded to the depth-d signed-digit grid
            (= linear-CORDIC multiplier), single real matmul. Differentiable
            via straight-through estimator so QAT/finetuning works.
int8        Production TPU path (beyond-paper): real int8 x int8 -> int32
            ``dot_general`` (2x MXU rate on v5e), per-output-channel weight
            scales, dynamic per-tensor activation scale. CORDIC depth maps to
            effective weight bits by zeroing trailing bits of the int8 grid.
kernel      The Pallas ``cordic_mac`` kernel (tests / small shapes; same math
            as ``carmen``).

Every backend has two lifecycles: the **per-call** path (raw float weights —
weight-side quantization re-traced every call; what QAT trains through, with
``depth`` allowed to be a traced scalar for runtime-adaptive switching) and
the **prepared** path (``prepare_params`` formats the weight bank once; the
forward then does zero weight-side rounding or scale computation — the
software analogue of CARMEN's pre-formatted PE array).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .backends import (
    carmen_dot,
    int8_dot,
    prepare_params,
    resolve,
    sd_round_traced,
)
from .backends.base import PreparedWeight
from .fxp import FXP8
from .precision_policy import LayerPrecision, PrecisionPolicy

__all__ = [
    "EngineContext",
    "PreparedWeight",
    "carmen_dot",
    "int8_dot",
    "prepare_params",
    "sd_round_traced",
]


@dataclasses.dataclass(frozen=True)
class EngineContext:
    """Static engine configuration threaded through model code.

    Hashable (usable as a jit static argument). ``mode`` selects the execution
    backend; ``policy`` supplies per-layer (fmt, depth). Prepared weight
    leaves (``prepare_params``) carry their own backend, which takes
    precedence over ``mode`` at dispatch.
    """

    mode: str = "exact"  # exact | carmen | int8 | kernel
    policy: Optional[PrecisionPolicy] = None
    compute_dtype: jnp.dtype = jnp.bfloat16
    # attention lowering: "xla" (query-chunked, scores materialize per chunk),
    # "flash" (KV-chunked online softmax; pure-JAX twin of the Pallas
    # flash kernel — bit-tested against it; scores never exceed tile size),
    # or "decode_kernel" (cache-decode path only: Pallas per-query-causal
    # GQA/MLA kernels over the slot KV cache — token streams identical to
    # the XLA chain, raw outputs ulp-close; falls back under a mesh)
    attn_impl: str = "xla"
    # emit dots in compute_dtype so TP partial-sums all-reduce in bf16
    # (Megatron-style; halves activation collective volume; MXU still
    # accumulates fp32 internally per tile)
    tp_reduce_bf16: bool = False
    # fused Pallas dot+AF path (kernel backend, prepared weights):
    #   "auto" — fuse on native TPU with no active mesh; CPU/interpret and
    #            mesh-sharded params run the bitwise-equal XLA chain
    #   "on"   — fuse wherever the kernel supports the shape (tests/bench
    #            exercise the interpret-mode kernel this way)
    #   "off"  — always the XLA chain
    fused: str = "auto"

    def layer_precision(self, name: str) -> LayerPrecision:
        policy = self.policy or PrecisionPolicy.accurate(FXP8)
        return policy.for_layer(name)

    def dot(self, x, w, *, name: str = ""):
        """Matmul along the last axis of x / first of w, backend-dispatched."""
        return resolve(w, self.mode).dot(self, x, w, name=name)

    def linear(self, x, w, b=None, *, name: str = ""):
        out = self.dot(x, w, name=name)
        if b is not None:
            out = out + b.astype(out.dtype)
        return out

    def activate(self, x, af: str):
        """Activation through the CARMEN multi-AF block (or the exact ref)."""
        if af == "identity":
            return x
        if self.mode == "exact":
            from .activations import af_ref

            return af_ref(x, af).astype(x.dtype)
        if self.mode == "kernel":
            from repro.kernels.cordic_af.ops import multi_af_pallas

            lp = self.layer_precision("af")
            return multi_af_pallas(
                x, af, depth=int(lp.depth), fmt=lp.fmt
            ).astype(x.dtype)
        from .activations import multi_af_float

        lp = self.layer_precision("af")
        return multi_af_float(x, af, lp.depth, lp.fmt).astype(x.dtype)

    def linear_af(self, x, w, b=None, *, af: str, name: str = ""):
        """Linear followed by an activation, fused into one kernel pass when
        the dispatched backend offers ``dot_af`` (kernel backend, prepared
        weights, elementwise AF); otherwise the unfused linear -> multi-AF
        chain with identical values."""
        backend = resolve(w, self.mode)
        dot_af = getattr(backend, "dot_af", None)
        if b is None and dot_af is not None:
            out = dot_af(self, x, w, af=af, name=name)
            if out is not NotImplemented:
                return out
        return self.activate(self.linear(x, w, b, name=name), af)
