"""Prepared-weight execution backends: quantize once, serve fast.

The contract under test: after ``prepare_params`` the forward performs zero
weight-side rounding/scale computation, and the results are *bit-identical*
to the per-call paths at the dot level — across depths and FxP formats.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (
    EngineContext,
    FXP8,
    FXP16,
    PrecisionPolicy,
    PreparedWeight,
    full_depth,
    prepare_params,
)
from repro.core.backends import get_backend, resolve
from repro.models import get_model
from repro.serve.engine import BatchedServer, Request

DEPTHS = {FXP8: (4, 6, full_depth(FXP8)), FXP16: (4, 6, full_depth(FXP16))}


def _xw(rng, m=8, k=64, n=16):
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    w = rng.uniform(-1, 1, (k, n)).astype(np.float32)
    return x, w


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_serves_all_modes():
    for mode in ("exact", "carmen", "int8", "kernel"):
        assert get_backend(mode).name == mode
    with pytest.raises(ValueError, match="unknown engine mode"):
        get_backend("fp4")


def test_prepared_leaf_pins_backend(rng):
    """A prepared bank carries its execution path regardless of ctx.mode."""
    x, w = _xw(rng)
    pol = PrecisionPolicy.accurate(FXP8)
    pw = get_backend("carmen").prepare(jnp.asarray(w), pol.for_layer("n"))
    assert resolve(pw, "int8").name == "carmen"
    assert resolve(jnp.asarray(w), "int8").name == "int8"


# ---------------------------------------------------------------------------
# dot-level bit parity: prepared == per-call
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", [FXP8, FXP16], ids=str)
@pytest.mark.parametrize("mode", ["carmen", "int8"])
def test_prepared_dot_bit_identical(mode, fmt, rng):
    x, w = _xw(rng)
    for depth in DEPTHS[fmt]:
        pol = PrecisionPolicy.uniform(fmt, depth)
        ctx = EngineContext(mode=mode, policy=pol, compute_dtype=jnp.float32)
        per_call = np.asarray(ctx.dot(x, w, name="mlp.up"))
        pw = get_backend(mode).prepare(jnp.asarray(w), pol.for_layer("mlp.up"))
        prepared = np.asarray(ctx.dot(x, pw, name="mlp.up"))
        np.testing.assert_array_equal(per_call, prepared, err_msg=f"{mode} d={depth}")


def test_prepared_kernel_dot_bit_identical(rng):
    x, w = _xw(rng, m=4, k=32, n=16)
    pol = PrecisionPolicy.uniform(FXP8, 5)
    ctx = EngineContext(mode="kernel", policy=pol, compute_dtype=jnp.float32)
    per_call = np.asarray(ctx.dot(x, w, name="n"))
    pw = get_backend("kernel").prepare(jnp.asarray(w), pol.for_layer("n"))
    prepared = np.asarray(ctx.dot(x, pw, name="n"))
    np.testing.assert_array_equal(per_call, prepared)


def test_prepared_dot_does_no_weight_side_work(rng):
    """The prepared int8 dot must consume the stored scale, not recompute it:
    hand it a deliberately wrong scale and the output must follow the lie."""
    x, w = _xw(rng)
    pol = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode="int8", policy=pol, compute_dtype=jnp.float32)
    pw = get_backend("int8").prepare(jnp.asarray(w), pol.for_layer("n"))
    doubled = PreparedWeight(pw.data, pw.scale * 2.0, pw.backend, pw.meta)
    base = np.asarray(ctx.dot(x, pw, name="n"))
    lied = np.asarray(ctx.dot(x, doubled, name="n"))
    np.testing.assert_allclose(lied, 2.0 * base, rtol=1e-6)


# ---------------------------------------------------------------------------
# prepare_params: tree lifecycle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("olmo-1b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prepared_leaves(tree):
    return [
        l
        for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, PreparedWeight))
        if isinstance(l, PreparedWeight)
    ]


def test_prepare_params_structure(small_model):
    cfg, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    prep = prepare_params(params, pol, "int8", specs=model.specs())
    seg = prep["seg0_dense"]
    # engine-routed weights become prepared banks (stacked layer axis intact)
    for group, name in (("attn", "wq"), ("attn", "wo"), ("mlp", "up"), ("mlp", "down")):
        leaf = seg[group][name]
        assert isinstance(leaf, PreparedWeight), (group, name)
        assert leaf.data.dtype == jnp.int8
        assert leaf.data.shape == params["seg0_dense"][group][name].shape
        assert leaf.scale.shape[0] == cfg.num_layers  # per-layer scales (scan xs)
    # criticality-pinned leaves stay float
    assert not _prepared_leaves(seg["attn_norm"])
    assert not _prepared_leaves(prep["final_norm"])
    assert not isinstance(prep["embed"], PreparedWeight)
    # tied embeddings get an explicit prepared head
    assert cfg.tie_embeddings and isinstance(prep["lm_head"], PreparedWeight)


def test_prepare_params_exact_is_passthrough(small_model):
    _, model, params = small_model
    prep = prepare_params(params, None, "exact", specs=model.specs())
    assert not _prepared_leaves(prep)


def test_prepare_params_idempotent(small_model):
    _, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    prep = prepare_params(params, pol, "carmen", specs=model.specs())
    again = prepare_params(prep, pol, "carmen", specs=model.specs())
    for a, b in zip(_prepared_leaves(prep), _prepared_leaves(again)):
        assert a.data is b.data  # already-prepared leaves pass through


@pytest.mark.parametrize("mode", ["carmen", "int8"])
def test_prepared_forward_matches_per_call(small_model, mode):
    cfg, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode=mode, policy=pol, compute_dtype=jnp.float32)
    prep = prepare_params(params, pol, mode, specs=model.specs())
    batch = {"tokens": jnp.asarray(np.arange(16, dtype=np.int32).reshape(2, 8))}
    lg_pc, _ = model.forward(params, batch, ctx)
    lg_pr, _ = model.forward(prep, batch, ctx)
    if mode == "carmen":  # no scale epilogue -> bitwise through the whole stack
        np.testing.assert_array_equal(np.asarray(lg_pc), np.asarray(lg_pr))
    else:  # int8: XLA may reassociate the (tiny) scale multiplies inside scan
        np.testing.assert_allclose(np.asarray(lg_pc), np.asarray(lg_pr), atol=1e-5)


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def test_server_prepared_matches_per_call(small_model):
    _, model, params = small_model
    ctx = EngineContext(
        mode="carmen", policy=PrecisionPolicy.accurate(FXP16), compute_dtype=jnp.float32
    )
    prompt = np.array([5, 17, 3], np.int32)
    reqs = lambda: [Request(0, prompt, 5), Request(1, prompt, 5)]
    fast = BatchedServer(model, ctx, params, slots=2, max_len=32).run(reqs())
    slow = BatchedServer(
        model, ctx, params, slots=2, max_len=32, prepare_weights=False
    ).run(reqs())
    assert fast == slow


def test_server_rejects_empty_prompt(small_model):
    _, model, params = small_model
    ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
    server = BatchedServer(model, ctx, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        server.run([Request(0, np.array([], np.int32), 4)])


def test_train_step_rejects_prepared_params(small_model):
    from repro.train import optimizer as opt
    from repro.train.train_loop import TrainConfig, make_train_step

    _, model, params = small_model
    pol = PrecisionPolicy.accurate(FXP8)
    ctx = EngineContext(mode="carmen", policy=pol, compute_dtype=jnp.float32)
    prep = prepare_params(params, pol, "carmen", specs=model.specs())
    step = make_train_step(model, ctx, TrainConfig(remat=False))
    state = opt.init_state(params)
    batch = {
        "tokens": jnp.zeros((2, 8), jnp.int32),
        "targets": jnp.zeros((2, 8), jnp.int32),
    }
    with pytest.raises(ValueError, match="prepared weight banks"):
        step(prep, state, batch)
