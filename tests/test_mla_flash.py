"""MLA flash kernel (shared-latent broadcast): sweeps vs the naive oracle and
vs the model's own MLA attention math."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mla_flash.kernel import mla_flash
from repro.kernels.mla_flash.ops import mla_flash_attention
from repro.kernels.mla_flash.ref import mla_attention_ref

CASES = [
    # b, sq, sk, h, dk, dv, causal
    (2, 128, 128, 4, 48, 32, True),
    (1, 256, 256, 8, 96, 64, True),
    (2, 64, 64, 2, 32, 32, False),
]


@pytest.mark.parametrize("b,sq,sk,h,dk,dv,causal", CASES)
def test_kernel_matches_ref(b, sq, sk, h, dk, dv, causal, rng):
    q = rng.standard_normal((b, sq, h, dk)).astype(np.float32)
    k = rng.standard_normal((b, sk, dk)).astype(np.float32)
    v = rng.standard_normal((b, sk, dv)).astype(np.float32)
    out = np.asarray(mla_flash(q, k, v, causal=causal, bq=32, bk=32, bh=2, interpret=True))
    ref = np.asarray(mla_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                       causal=causal))
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-4)


def test_block_shape_invariance(rng):
    q = rng.standard_normal((1, 128, 4, 64)).astype(np.float32)
    k = rng.standard_normal((1, 128, 64)).astype(np.float32)
    v = rng.standard_normal((1, 128, 32)).astype(np.float32)
    a = np.asarray(mla_flash(q, k, v, bq=16, bk=64, bh=1, interpret=True))
    b_ = np.asarray(mla_flash(q, k, v, bq=128, bk=128, bh=4, interpret=True))
    np.testing.assert_allclose(a, b_, atol=3e-5, rtol=1e-4)


def test_matches_model_mla_attention(rng):
    """End-to-end: kernel output == models/mla.py chunked-score path."""
    from repro.configs import get_config, reduced
    from repro.core import EngineContext
    from repro.models import mla as mla_mod
    from repro.models import params as P_

    cfg = reduced(get_config("deepseek-v3-671b"))
    m = cfg.mla
    specs = mla_mod.mla_specs(cfg)
    prms = P_.init(specs, jax.random.PRNGKey(0))
    ctx = EngineContext(mode="exact", compute_dtype=jnp.float32)
    b, s = 2, 64
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    positions = jnp.arange(s)
    ref_out, _ = mla_mod.mla_attention(prms, x, cfg, ctx, positions=positions, name="t")

    # rebuild the kernel's inputs from the same projections
    from repro.models.blocks import rope as rope_fn

    q = mla_mod._q_proj(prms, x, cfg, ctx, "t")
    nope = m.qk_nope_head_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_fn(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = mla_mod._kv_latent(prms, x, cfg, ctx, "t")
    k_rope = rope_fn(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       prms["wk_b"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + m.qk_rope_head_dim)
    o_lat = mla_flash_attention(
        q_lat, q_rope.astype(jnp.float32), c_kv.astype(jnp.float32),
        k_rope.astype(jnp.float32), scale=scale, bq=16, bk=16, bh=2,
    )
    out = jnp.einsum("bshr,rhv->bshv", o_lat.astype(jnp.float32),
                     prms["wv_b"].astype(jnp.float32))
    wo = prms["wo"].reshape(cfg.num_heads * m.v_head_dim, cfg.d_model)
    out = ctx.linear(out.reshape(b, s, -1), wo, name="t.o")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=5e-5, rtol=1e-4)
